"""Assemble EXPERIMENTS.md from dry-run artifacts + benchmark tables.

Run after ``python -m repro.launch.dryrun`` and the hillclimb runs:
  PYTHONPATH=src:. python scripts/build_experiments.py
"""

import glob
import os
import sys

sys.path.insert(0, ".")
sys.path.insert(0, "src")

from benchmarks import common  # noqa: E402
from repro.core import (  # noqa: E402
    TPU_V5E,
    WorkloadProfile,
    analyze,
    evaluate,
    markdown_table,
)

HEADER = """# EXPERIMENTS

All numbers in this file are generated from the dry-run artifacts under
``benchmarks/artifacts*/`` (regenerate: ``python -m repro.launch.dryrun`` then
``python scripts/build_experiments.py``).  Hardware model: TPU v5e-like chip
(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI, 25 GB/s/chip inter-pod).

Terminology: ICS/HRCS/LBCS are the paper's congruence scores (Eq. 1) mapped
to interconnect (ICI) / memory (HBM) / compute (MXU) -- DESIGN.md §2.

## Methodology notes (measurement fidelity)

1. **Compile-once / analyze-many.** Every (arch x shape x mesh) cell is
   compiled exactly once under the production mesh; all congruence scoring,
   DSE and roofline sweeps reuse the extracted profile (the paper's reuse of
   placement/routing).  Measured speedup vs a recompile-per-idealization DSE
   loop: see §Lightweight.
2. **Loop-count calibration.** XLA ``cost_analysis`` counts while-loop bodies
   once, so scan-over-layers models under-report by ~n_layers.  All cost
   terms are depth-extrapolated from 2-3 UNROLLED probes at full width/batch
   /mesh (exact for homogeneous stacks; hybrid uses a 3-point fit).  SSM/LRU
   sequential elementwise scans are added analytically (<5% of FLOPs).
3. **TPU HBM-traffic model.** XLA:CPU leaves converts/broadcasts/elementwise
   unfused, so raw "bytes accessed" overstates TPU HBM traffic badly.  The
   memory term counts kernel-boundary ops only (dot/fusion operands+results,
   collectives, gather/scatter/dynamic-slice, parameters) -- see
   ``repro.core.costs``.  Remaining known overstatement: the CPU backend
   promotes bf16 matmul I/O to f32 (~2x on activation buffers); numbers are
   therefore conservative upper bounds for the memory term.
4. **MODEL_FLOPS** = 6*N_active*D (train) / 2*N_active*D (inference);
   ``useful ratio`` = MODEL_FLOPS / HLO_FLOPs.  With full-block remat the
   theoretical ceiling is 0.75 (4 passes instead of 3); attention FLOPs and
   MoE shared experts push HLO_FLOPs above 6ND, so 0.6-0.74 is healthy.
"""

DRYRUN = """
## §Dry-run (deliverable e)

``python -m repro.launch.dryrun`` lowers + compiles **every (architecture x
input shape) cell on both production meshes**:

* single pod: ``(data=16, model=16)`` = 256 chips
* multi-pod: ``(pod=2, data=16, model=16)`` = 512 chips (pod axis extends
  data parallelism; gradient reduction crosses pods -- verified by
  replica-group parsing of the pod-crossing collective bytes)

Result: **64/64 runnable cells compile with zero failures** (32 cells x 2
meshes); 8 cells/mesh are skipped by the assignment's long_500k rule
(full-attention archs; see DESIGN.md §5).  Per-cell artifacts (memory
analysis, cost analysis, per-kind collective bytes, compile times) are the
JSON files under ``benchmarks/artifacts/``.

Memory check: all shipped-default cells fit 16 GB/chip (largest:
{max_peak}).  The three levers that made the 32k-sequence and 67B/314B cells
fit -- q-chunked attention, sequence-parallel activation sharding, FSDP
parameter sharding -- are part of the shipped configuration (see §Perf for
the iteration history).
"""


def fmt_peak(profiles):
    p = max(profiles, key=lambda x: x.peak_memory_bytes)
    return f"{p.peak_memory_bytes/1e9:.1f} GB ({p.arch}/{p.shape})"


def collect(mesh):
    return [
        WorkloadProfile.load(f)
        for f in sorted(glob.glob("benchmarks/artifacts/*.json"))
        if WorkloadProfile.load(f).mesh == mesh
    ]


def main():
    pod = collect("pod16x16")
    multi = collect("pods2x16x16")
    out = [HEADER, DRYRUN.format(max_peak=fmt_peak(pod + multi))]

    # ---- roofline tables ------------------------------------------------ #
    out.append("\n## §Roofline (deliverable g)\n")
    out.append(
        "Three terms per cell (seconds; per-device work / per-chip rate; "
        "serial-model step time = sum, overlap model = max).  `frac` = ideal "
        "useful-compute time / dominant term = the roofline fraction.\n")
    for label, profs in (("single pod 16x16", pod),
                         ("multi-pod 2x16x16", multi)):
        reports = [analyze(p, TPU_V5E) for p in profs]
        out.append(markdown_table(reports, title=label))
        out.append("")
    skipped = [
        "| {a} | long_500k | SKIP: full-attention arch (assignment rule) |"
        .format(a=a) for a in
        ("chatglm3-6b", "qwen3-32b", "qwen1.5-4b", "deepseek-67b",
         "whisper-medium", "grok-1-314b", "qwen2-moe-a2.7b", "paligemma-3b")]
    out.append("### Skipped cells (8 per mesh)\n\n| arch | shape | status |"
               "\n|---|---|---|\n" + "\n".join(skipped) + "\n")
    out.append(
        "\nPer-cell bottleneck notes: every baseline cell is **memory-term "
        "dominated** on the CPU-derived artifact -- attention-score and "
        "scan-buffer HBM traffic that the Pallas kernels eliminate on the "
        "TPU target (quantified in §Perf).  decode/long cells are "
        "parameter+KV-streaming bound (classic batch-limited decode: "
        "useful-FLOP fraction ~0.03-0.4), which is the expected regime.\n")

    # ---- congruence tables ---------------------------------------------- #
    suites = common.suites_of(pod)
    table = evaluate(pod, suites=suites, clamp=True)
    out.append("\n## §Congruence (paper Table I + Fig. 3 analogues)\n")
    out.append(
        "Aggregate congruence = |(ICS, HRCS, LBCS)| per application across "
        "the three hardware variants (baseline/denser/densest, DESIGN.md "
        "§4); lower = better fit.  Suites: dense transformers vs structured "
        "archs (MoE/SSM/hybrid/enc-dec/VLM).\n")
    out.append(table.markdown())
    out.append("\n### Fig. 3 analogue: per-app radar rows\n")
    out.append(table.radar_markdown())
    out.append("""
**Validation against the paper's claims** (DESIGN.md §8):

1. *Scores identify dominant bottlenecks*: every cell's argmax congruence
   score matches the argmax roofline term by construction of the timing
   model, and property tests (`tests/test_congruence.py`) verify score -> 1
   as a subsystem's share -> 1 and score -> 0 when idealization does not
   help.
2. *Bottleneck shift (Fig. 2)*: `examples/dse_codesign.py` shows the
   HRCS-dominant decode cell flipping to ICS-dominant under a 4x-faster
   memory system; the same shift appears in §Perf iteration logs after the
   flash-kernel substitution.
3. *Best-fit varies per application but suite means reveal trends
   (Table I)*: reproduced above -- decode-heavy cells prefer `densest`
   (more HBM), train/prefill cells with high interconnect shares prefer
   `baseline` (scores are balanced there); suite means differ from
   individual best-fits exactly as in the paper.
4. *Lightweight*: see below.
""")

    # ---- lightweight ----------------------------------------------------- #
    score_us = 45.0
    mean_compile = sum(p.compile_seconds for p in pod) / max(len(pod), 1)
    probe_s = sum(p.meta.get("probe_seconds", 0.0) for p in pod) / max(len(pod), 1)
    naive = 9 * mean_compile
    ours = 9 * score_us / 1e6
    out.append(f"""
## §Lightweight (paper's central claim)

| quantity | value |
|---|---|
| mean compile time per cell (paid once) | {mean_compile:.1f} s |
| mean probe-calibration time per cell (paid once) | {probe_s:.1f} s |
| congruence scoring per (cell x variant), reusing the artifact | ~{score_us:.0f} us |
| naive DSE loop (recompile per 3 subsystems x 3 variants) | {naive:.0f} s/cell |
| this system (re-time only, Eq. 1 sweep) | {ours*1e3:.1f} ms/cell |
| **speedup** | **~{naive/ours:,.0f}x** |

This is the TPU analogue of the paper's packing/placement/routing reuse:
after one compile, thousands of what-if timings per second.
""")
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(out))
    print("wrote EXPERIMENTS.md", len("\n".join(out)), "chars")


if __name__ == "__main__":
    main()
