"""Design-space sweep CLI -- score machine populations against profiles.

Generates a machine-variant population (grid or low-discrepancy random) from
``repro.core.sweep.ParamSpace``, scores every (app x variant) cell with the
batched congruence engine, and dumps the best-fit variants + Pareto front
(aggregate congruence vs. area proxy) as JSON and/or markdown.

  PYTHONPATH=src:. python scripts/sweep.py --num 2048 --out sweep
  PYTHONPATH=src:. python scripts/sweep.py --mode grid --num 1024 \
      --format md --timing-model overlap
  PYTHONPATH=src:. python scripts/sweep.py --num 100000 --backend jax

Profiles come from ``benchmarks/artifacts/*.json`` (the dry-run outputs)
when present, else the synthetic trio -- same policy as the benchmark
harness.
"""

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import common  # noqa: E402
from repro.core.machine import TPU_V5E, VARIANTS  # noqa: E402
from repro.core.sweep import ParamSpace, run_sweep  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="pod16x16",
                    help="artifact mesh filter ('' = all meshes)")
    ap.add_argument("--mode", choices=("random", "grid"), default="random")
    ap.add_argument("--num", type=int, default=1024,
                    help="population size (grid rounds up per-dim)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--span", type=float, default=4.0,
                    help="sweep each rate this many x below/above nominal")
    ap.add_argument("--max-links", type=int, default=8)
    ap.add_argument("--beta", type=float, default=None,
                    help="explicit target step time (s); default: per-app "
                         "ideal-compute beta against the baseline variant")
    ap.add_argument("--timing-model", choices=("serial", "overlap"),
                    default="serial")
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="kernel backend (default: $REPRO_SWEEP_BACKEND, "
                         "then numpy); jax jits + device-places the "
                         "batched kernels")
    ap.add_argument("--no-named", action="store_true",
                    help="do not prepend baseline/denser/densest")
    ap.add_argument("--top", type=int, default=16)
    ap.add_argument("--format", choices=("json", "md", "both"), default="both")
    ap.add_argument("--out", default=None,
                    help="output path stem (default: stdout); writes "
                         "<out>.json / <out>.md per --format")
    args = ap.parse_args(argv)
    if args.num < 1:
        ap.error("--num must be >= 1")

    profiles, synthetic = common.profiles_or_synthetic(args.mesh)
    space = ParamSpace.default(nominal=TPU_V5E, span=args.span,
                               max_links=args.max_links)
    result = run_sweep(
        profiles,
        space=space,
        n=args.num,
        mode=args.mode,
        seed=args.seed,
        include_named=() if args.no_named else VARIANTS,
        beta=args.beta,
        timing_model=args.timing_model,
        backend=args.backend,
    )

    print(f"swept {len(result.profiles)} apps x {len(result.machines)} "
          f"variants on the {result.backend} backend"
          f"{' (SYNTHETIC profiles)' if synthetic else ''}; "
          f"pareto front: {len(result.pareto_front())} variants "
          f"(3-D: {len(result.pareto_front_3d())})",
          file=sys.stderr)

    blob = json.dumps(result.to_json(top_k=args.top), indent=1, sort_keys=True)
    md = result.markdown(top_k=args.top)
    if args.out is None:
        if args.format in ("json", "both"):
            print(blob)
        if args.format in ("md", "both"):
            print(md)
    else:
        if args.format in ("json", "both"):
            with open(args.out + ".json", "w") as f:
                f.write(blob + "\n")
        if args.format in ("md", "both"):
            with open(args.out + ".md", "w") as f:
                f.write(md + "\n")
        print(f"wrote {args.out}.{{json,md}}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
