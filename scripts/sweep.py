"""Design-space sweep CLI -- score machine populations against profiles.

Generates a machine-variant population (grid or low-discrepancy random) from
``repro.core.sweep.ParamSpace``, scores every (app x variant) cell with the
batched congruence engine, and dumps the best-fit variants + Pareto front
(aggregate congruence vs. area proxy) as JSON and/or markdown.

  PYTHONPATH=src:. python scripts/sweep.py --num 2048 --out sweep
  PYTHONPATH=src:. python scripts/sweep.py --mode grid --num 1024 \
      --format md --timing-model overlap
  PYTHONPATH=src:. python scripts/sweep.py --num 100000 --backend jax
  PYTHONPATH=src:. python scripts/sweep.py --num 100000 --backend pallas
  PYTHONPATH=src:. python scripts/sweep.py --num 1000000 --shards 8 \
      --backend jax --format md
  PYTHONPATH=src:. python scripts/sweep.py --num 10000000 --stream \
      --shards 64 --backend pallas --checkpoint-dir /tmp/megasweep --resume

Profiles come from ``benchmarks/artifacts/*.json`` (the dry-run outputs)
when present, else the synthetic trio -- same policy as the benchmark
harness.
"""

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks import common  # noqa: E402
from repro.core.kernels_xp import validate_backend_arg as validate_backend  # noqa: E402
from repro.core.machine import TPU_V5E, VARIANTS  # noqa: E402
from repro.core.sweep import ParamSpace, run_sweep, shard_sweep  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="pod16x16",
                    help="artifact mesh filter ('' = all meshes)")
    ap.add_argument("--suite", default=None, metavar="SUITE",
                    help="score a model-zoo suite instead of the dry-run "
                         "artifacts: zoo | zoo-smoke, with an optional "
                         ":scenario (train | serve-prefill | serve-decode), "
                         "e.g. --suite zoo:train.  zoo-smoke extracts on a "
                         "cache miss; zoo requires the cache built by "
                         "`python -m repro.core.model_zoo`; generated "
                         "suites gen:<count>[:seed=S][:mode=halton|rng] "
                         "are accepted too")
    ap.add_argument("--gen", type=int, default=None, metavar="N",
                    help="score N generated stress workloads "
                         "(shorthand for --suite gen:N; AppSpace.default "
                         "sampled by Halton indices, seed 0)")
    ap.add_argument("--mode", choices=("random", "grid"), default="random")
    ap.add_argument("--num", type=int, default=1024,
                    help="population size (grid rounds up per-dim)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--span", type=float, default=4.0,
                    help="sweep each rate this many x below/above nominal")
    ap.add_argument("--max-links", type=int, default=8)
    ap.add_argument("--beta", type=float, default=None,
                    help="explicit target step time (s); default: per-app "
                         "ideal-compute beta against the baseline variant")
    ap.add_argument("--timing-model", choices=("serial", "overlap"),
                    default="serial")
    ap.add_argument("--backend", default=None,
                    help="kernel backend (default: $REPRO_SWEEP_BACKEND, "
                         "then numpy); 'jax' jits + device-places the "
                         "batched kernels, 'pallas' runs the fused TPU "
                         "kernel (interpreter mode off-TPU); any "
                         "register_backend() name is accepted")
    ap.add_argument("--shards", type=int, default=0, metavar="S",
                    help="score the population in S shards (shard_sweep): "
                         "mesh-sharded statistics + per-shard Pareto "
                         "pre-filter, for populations that outgrow one "
                         "device (0 = single-device run_sweep)")
    ap.add_argument("--stream", action="store_true",
                    help="regenerate each shard's variants on the fly "
                         "(PopulationStream): never materializes the full "
                         "population, so --num is bounded by patience, not "
                         "RAM; implies sharding (default shard count keeps "
                         "chunks ~64k variants)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="write resumable per-shard checkpoints to DIR "
                         "(repro.checkpoint.store; atomic renames)")
    ap.add_argument("--resume", action="store_true",
                    help="with --checkpoint-dir: skip shards already "
                         "completed by a previous (killed) run; results "
                         "are byte-identical to an uninterrupted sweep")
    ap.add_argument("--abort-after-shard", type=int, default=None,
                    metavar="S", help="exit(3) after shard S completes "
                         "(deterministic kill hook for the CI resume "
                         "round-trip smoke)")
    ap.add_argument("--no-named", action="store_true",
                    help="do not prepend baseline/denser/densest")
    ap.add_argument("--top", type=int, default=16)
    ap.add_argument("--format", choices=("json", "md", "both"), default="both")
    ap.add_argument("--out", default=None,
                    help="output path stem (default: stdout); writes "
                         "<out>.json / <out>.md per --format")
    args = ap.parse_args(argv)
    if args.num < 1:
        ap.error("--num must be >= 1")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    validate_backend(ap, args.backend)
    if args.gen is not None:
        if args.suite:
            ap.error("--gen and --suite are mutually exclusive")
        if args.gen < 1:
            ap.error("--gen must be >= 1")
        args.suite = f"gen:{args.gen}"

    if args.suite:
        from repro.core.model_zoo import resolve_suite, validate_suite_name
        try:
            validate_suite_name(args.suite)
        except ValueError as exc:
            ap.error(str(exc))
        profiles, synthetic = resolve_suite(args.suite), False
        print(f"suite {args.suite}: {len(profiles)} profiles",
              file=sys.stderr)
    else:
        profiles, synthetic = common.profiles_or_synthetic(args.mesh)
    space = ParamSpace.default(nominal=TPU_V5E, span=args.span,
                               max_links=args.max_links)
    sweep_kwargs = dict(
        space=space,
        n=args.num,
        mode=args.mode,
        seed=args.seed,
        include_named=() if args.no_named else VARIANTS,
        beta=args.beta,
        timing_model=args.timing_model,
        backend=args.backend,
    )
    if args.shards > 0 or args.stream or args.checkpoint_dir:
        progress = None
        if args.abort_after_shard is not None:
            class _Abort(Exception):
                pass

            def progress(s, num_shards, lo, hi):
                print(f"shard {s + 1}/{num_shards} done [{lo}, {hi})",
                      file=sys.stderr)
                if s >= args.abort_after_shard:
                    raise _Abort
        try:
            # keep_top must cover --top: each shard keeps its local top-k,
            # so a smaller keep would silently prune global ranks out of
            # the report.
            sharded = shard_sweep(
                profiles,
                num_shards=args.shards if args.shards > 0 else None,
                keep_top=max(16, args.top), stream=args.stream,
                checkpoint_dir=args.checkpoint_dir, resume=args.resume,
                progress=progress, **sweep_kwargs)
        except _Abort if args.abort_after_shard is not None else ():
            print(f"aborted after shard {args.abort_after_shard} "
                  f"(checkpoint in {args.checkpoint_dir})", file=sys.stderr)
            return 3
        result = sharded.result
        resumed = (f", {sharded.resumed_shards} shards resumed"
                   if sharded.resumed_shards else "")
        print(f"shard-swept {len(result.profiles)} apps x "
              f"{sharded.num_variants} variants in {sharded.num_shards} "
              f"shards ({sharded.mesh_axis}, {result.backend} backend"
              f"{', streamed' if sharded.streamed else ''}{resumed}"
              f"{', SYNTHETIC profiles' if synthetic else ''}); "
              f"{len(result.machines)} Pareto candidates kept; front: "
              f"{len(sharded.pareto_front())} variants "
              f"(3-D: {len(sharded.pareto_front_3d())})",
              file=sys.stderr)
        blob_source = sharded
    else:
        result = run_sweep(profiles, **sweep_kwargs)
        print(f"swept {len(result.profiles)} apps x {len(result.machines)} "
              f"variants on the {result.backend} backend"
              f"{' (SYNTHETIC profiles)' if synthetic else ''}; "
              f"pareto front: {len(result.pareto_front())} variants "
              f"(3-D: {len(result.pareto_front_3d())})",
              file=sys.stderr)
        blob_source = result

    blob = json.dumps(blob_source.to_json(top_k=args.top), indent=1,
                      sort_keys=True)
    md = blob_source.markdown(top_k=args.top)
    if args.out is None:
        if args.format in ("json", "both"):
            print(blob)
        if args.format in ("md", "both"):
            print(md)
    else:
        if args.format in ("json", "both"):
            with open(args.out + ".json", "w") as f:
                f.write(blob + "\n")
        if args.format in ("md", "both"):
            with open(args.out + ".md", "w") as f:
                f.write(md + "\n")
        print(f"wrote {args.out}.{{json,md}}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
