"""Benchmark harness package (see run.py)."""
