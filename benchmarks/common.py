"""Shared helpers for the benchmark harness.

Benchmarks consume the dry-run artifacts (benchmarks/artifacts/*.json,
produced by ``python -m repro.launch.dryrun``).  If artifacts are missing the
benchmarks fall back to a small set of synthetic profiles so the harness
always runs (clearly labelled ``synthetic``).
"""

from __future__ import annotations

import glob
import os
import time
from typing import Callable, Dict, List, Tuple

from repro.core import WorkloadProfile

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

# Smoke mode (run.py --smoke): tiny synthetic profiles, single repeat, small
# sweep populations -- CI exercises every benchmark function in seconds.
SMOKE = False

# Two-suite split for Table I / Fig. 3 analogues (DESIGN.md §2):
# dense transformers (Koios-like homogeneous compute) vs structured archs.
DENSE_SUITE = ("chatglm3-6b", "qwen3-32b", "qwen1.5-4b", "deepseek-67b")
STRUCTURED_SUITE = ("whisper-medium", "recurrentgemma-9b", "grok-1-314b",
                    "qwen2-moe-a2.7b", "paligemma-3b", "falcon-mamba-7b")


def load_profiles(mesh: str = "pod16x16") -> List[WorkloadProfile]:
    """mesh="" loads every mesh's artifacts."""
    profiles = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        p = WorkloadProfile.load(path)
        if mesh and p.mesh != mesh:
            continue
        profiles.append(p)
    return profiles


def synthetic_profiles() -> List[WorkloadProfile]:
    out = []
    mixes = [
        ("synthetic-compute", 2e14, 5e10, 5e9),
        ("synthetic-memory", 5e12, 8e11, 5e9),
        ("synthetic-collective", 5e12, 5e10, 8e10),
    ]
    for name, flops, hbm, coll in mixes:
        out.append(WorkloadProfile(
            name=name, arch=name, shape="train_4k", mesh="pod16x16",
            flops=flops, bytes_accessed=hbm, hbm_bytes=hbm,
            collective_bytes={"all-reduce": coll}, num_devices=256,
            model_flops=flops * 0.7 * 256, tokens=1 << 20))
    return out


def scaling_profiles(n: int) -> List[WorkloadProfile]:
    """``n`` deterministic synthetic apps spanning the bottleneck spectrum
    (used by the sweep_scaling benchmark and smoke runs)."""
    out = []
    for i in range(n):
        # Rotate dominance between compute / memory / interconnect while
        # varying magnitudes so no two apps score identically.
        f = 1e12 * (10.0 ** (i % 3)) * (1.0 + 0.13 * i)
        h = 1e9 * (10.0 ** ((i + 1) % 3)) * (1.0 + 0.07 * i)
        c = 1e9 * (10.0 ** ((i + 2) % 3)) * (1.0 + 0.11 * i)
        out.append(WorkloadProfile(
            name=f"scale-{i:03d}", arch=f"scale-{i:03d}", shape="train_4k",
            mesh="pod16x16", flops=f, bytes_accessed=h, hbm_bytes=h,
            collective_bytes={"all-reduce": c},
            pod_collective_bytes=0.25 * c if i % 4 == 0 else 0.0,
            num_devices=256, model_flops=f * 0.7 * 256, tokens=1 << 20))
    return out


def profiles_or_synthetic(mesh: str = "pod16x16"):
    if SMOKE:
        return synthetic_profiles(), True
    profs = load_profiles(mesh)
    if profs:
        return profs, False
    return synthetic_profiles(), True


def suites_of(profiles) -> Dict[str, List[str]]:
    names = {p.name for p in profiles}
    dense = [p.name for p in profiles if p.arch in DENSE_SUITE]
    structured = [p.name for p in profiles if p.arch in STRUCTURED_SUITE]
    if not dense or not structured:
        return {"all": sorted(names)}
    return {"dense-transformers": sorted(dense),
            "structured-archs": sorted(structured)}


def timeit(fn: Callable, *args, repeat: int = 5, **kw) -> Tuple[float, object]:
    if SMOKE:
        repeat = 1
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        result = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return dt * 1e6, result  # us


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}")


def write_out(fname: str, text: str) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, fname), "w") as f:
        f.write(text)
