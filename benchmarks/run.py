"""Benchmark harness -- one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the full markdown
tables to benchmarks/out/ (consumed by EXPERIMENTS.md).

  table1_congruence    -- paper Table I: aggregate congruence per
                          (application x machine variant), suite means,
                          best-fit variants.
  fig3_radar           -- paper Fig. 3: ICS/HRCS/LBCS triplets per app
                          across the three variants.
  roofline_table       -- required §Roofline: 3 terms / dominant /
                          MODEL_FLOPS ratio per (arch x shape) cell.
  profiler_overhead    -- paper's "lightweight" claim: congruence scoring
                          reuses the compiled artifact; measured speedup vs
                          the compile it avoids.
  sweep_scaling        -- vectorized sweep-engine throughput (cells/second)
                          at V in {3, 100, 1k, 10k} generated variants on
                          all three kernel backends (NumPy vs JAX vs
                          Pallas-fused, side by side), plus the
                          batched-vs-scalar speedup on 10 x 1k cells.
  stress_scaling       -- generated-workload stress populations: AppSpace
                          profile-generation throughput (Halton vs seeded
                          RNG) and full A x V gen-suite scoring on all
                          three kernel backends.
  packing              -- multi-tenant packing: pack_codesign over a
                          generated population vs the uniform fleet
                          baseline (best single constrained machine,
                          replicated) under the same total area budget.
  grad_codesign        -- jax.grad co-design: scalarized-objective descent
                          from the named-variant seeds (steps/second and
                          per-seed improvement).
  constrained_codesign -- budgeted co-design trade-off: unconstrained vs
                          projected-gradient vs augmented-Lagrangian
                          descent under a fixed area budget (objective,
                          feasibility, wall-clock side by side).
  frontier             -- feasibility frontier J*(budget): warm-started
                          continuation vs n cold constrained runs over the
                          same budget schedule (J* table, knee point,
                          wall-clock ratio -- the continuation pin).
  sensitivity          -- budget-gradient pricing: implicit custom-VJP vs
                          unrolled penalty descent vs central finite
                          differences (wall-clock per gradient + jaxpr
                          equation counts -- the implicit-graph pin).
  codesign_service     -- serving front door load test: requests/s and
                          p50/p99 latency for cold vs result-memo-cached
                          vs micro-batched sweep requests (one SoA pass
                          for N concurrent suites), threaded workers, and
                          frontier cold vs continuation-warm vs cached.

``--smoke`` runs every benchmark on tiny synthetic inputs with a single
repeat so CI can exercise the whole harness in seconds.
"""

from __future__ import annotations

import argparse
import sys

from benchmarks import common
from repro.core import (
    ParamSpace,
    TPU_V5E,
    VARIANTS,
    analyze,
    evaluate,
    markdown_table,
    profile_congruence,
)


def table1_congruence() -> None:
    profiles, synth = common.profiles_or_synthetic()
    suites = common.suites_of(profiles)
    us, table = common.timeit(
        evaluate, profiles, suites=suites, clamp=True, repeat=3)
    n_cells = len(profiles) * len(VARIANTS)
    for app in table.apps:
        best = table.best_fit(app)
        row = " ".join(
            f"{v}={table.cell(app, v).aggregate:.3f}" for v in table.variants)
        common.emit(f"table1/{app}", us / max(n_cells, 1),
                    f"{row} best={best}{' SYNTHETIC' if synth else ''}")
    for suite in suites:
        common.emit(
            f"table1/mean[{suite}]", us / max(n_cells, 1),
            " ".join(f"{v}={table.suite_mean(suite, v):.3f}"
                     for v in table.variants)
            + f" best={table.suite_best_fit(suite)}")
    common.emit("table1/aggregate", us / max(n_cells, 1),
                " ".join(f"{v}={table.aggregate_mean(v):.3f}"
                         for v in table.variants)
                + f" best={table.overall_best_fit()}")
    common.write_out("table1_congruence.md", table.markdown())


def fig3_radar() -> None:
    profiles, synth = common.profiles_or_synthetic()
    suites = common.suites_of(profiles)
    table = evaluate(profiles, suites=suites, clamp=True)
    for app in table.apps:
        rep = table.cell(app, "baseline").report
        us, _ = common.timeit(
            profile_congruence,
            next(p for p in profiles if p.name == app), TPU_V5E, repeat=10)
        common.emit(
            f"fig3/{app}", us,
            f"ICS={rep.ics:.3f} HRCS={rep.hrcs:.3f} LBCS={rep.lbcs:.3f} "
            f"dominant={rep.dominant}{' SYNTHETIC' if synth else ''}")
    common.write_out("fig3_radar.md", table.radar_markdown())


def roofline_table() -> None:
    for mesh in ("pod16x16", "pods2x16x16"):
        profiles, synth = common.profiles_or_synthetic(mesh)
        if synth and mesh == "pods2x16x16":
            continue
        reports = []
        for p in profiles:
            us, rep = common.timeit(analyze, p, TPU_V5E, repeat=10)
            reports.append(rep)
            common.emit(
                f"roofline/{mesh}/{p.arch}/{p.shape}", us,
                f"compute={rep.compute_s:.3e} memory={rep.memory_s:.3e} "
                f"collective={rep.collective_s:.3e} dominant={rep.dominant} "
                f"useful={rep.useful_ratio:.3f} frac={rep.roofline_fraction:.3f}"
                f"{' SYNTHETIC' if synth else ''}")
        common.write_out(f"roofline_{mesh}.md",
                         markdown_table(reports, title=f"mesh {mesh}"))


def zoo_calibration() -> None:
    """Eq.1 batched kernels vs scalar roofline on the model-zoo suites.

    Scores every cached zoo cell through both step-time code paths and
    reports the per-cell ratio + dominant-term agreement (the measurement
    anchor for congruence scores).  Smoke mode uses the checked-in
    zoo-smoke cache; the full run uses ``benchmarks/artifacts/zoo`` when
    populated (``python -m repro.core.model_zoo``), else falls back to the
    smoke suite with a note.
    """
    from repro.core.model_zoo import calibration_report, resolve_suite

    suite = "zoo-smoke"
    if not common.SMOKE:
        try:
            profiles = resolve_suite("zoo")
            suite = "zoo"
        except RuntimeError:
            profiles = resolve_suite("zoo-smoke")
    else:
        profiles = resolve_suite("zoo-smoke")
    us, report = common.timeit(calibration_report, profiles, TPU_V5E,
                               repeat=1 if common.SMOKE else 10)
    common.emit(
        f"zoo_calibration/{suite}", us,
        f"cells={len(report.cells)} "
        f"agreement={report.dominant_agreement:.3f} "
        f"worst={report.worst_offenders(1)[0].name}")
    common.write_out("zoo_calibration.md", report.markdown())


def profiler_overhead() -> None:
    """Lightweight claim: score-from-artifact vs recompile-per-idealization.

    VPR analogue: the paper reuses pack/place/route and re-runs only timing.
    We measure the congruence scoring cost per cell and compare with the
    recorded compile time of the same cell (what a naive re-compile-per-
    subsystem DSE loop would pay: 3 subsystems x 3 variants x compile).
    """
    profiles, synth = common.profiles_or_synthetic()
    total_score_us = 0.0
    total_compile_s = 0.0
    for p in profiles:
        us, _ = common.timeit(profile_congruence, p, TPU_V5E, repeat=10)
        total_score_us += us
        total_compile_s += p.compile_seconds or 10.0
    n = max(len(profiles), 1)
    naive_s = 9 * total_compile_s          # 3 subsystems x 3 variants
    ours_s = total_score_us * 9 / 1e6      # re-scoring is the whole cost
    speedup = naive_s / max(ours_s, 1e-9)
    common.emit("overhead/score_per_cell", total_score_us / n,
                f"compile_per_cell_s={total_compile_s / n:.1f}")
    common.emit("overhead/lightweight_speedup", total_score_us / n,
                f"{speedup:.0f}x vs recompile-per-idealization"
                f"{' SYNTHETIC' if synth else ''}")
    common.write_out(
        "profiler_overhead.md",
        f"| metric | value |\n|---|---|\n"
        f"| mean congruence-scoring time per cell | "
        f"{total_score_us / n:.0f} us |\n"
        f"| mean compile time per cell (paid once) | "
        f"{total_compile_s / n:.1f} s |\n"
        f"| naive DSE (recompile per subsystem x variant) | "
        f"{naive_s:.0f} s |\n"
        f"| congruence DSE (reuse artifact) | {ours_s:.3f} s |\n"
        f"| speedup | {speedup:.0f}x |\n")


def perf_hillclimb() -> None:
    """§Perf before/after: baseline artifacts vs hillclimbed profiles."""
    import glob
    import os

    from repro.core import WorkloadProfile

    opt_dir = os.path.join(os.path.dirname(__file__), "artifacts_opt")
    if not os.path.isdir(opt_dir):
        return
    baselines = {(p.arch, p.shape, p.mesh): p for p in common.load_profiles("")}
    rows = []
    for f in sorted(glob.glob(os.path.join(opt_dir, "*.json"))):
        opt = WorkloadProfile.load(f)
        tag = os.path.basename(f).rsplit("__", 1)[-1].replace(".json", "")
        base = baselines.get((opt.arch, opt.shape, opt.mesh))
        rep_o = analyze(opt, TPU_V5E)
        us, _ = common.timeit(analyze, opt, TPU_V5E, repeat=10)
        derived = (f"opt[{tag}] compute={rep_o.compute_s:.3e} "
                   f"memory={rep_o.memory_s:.3e} "
                   f"collective={rep_o.collective_s:.3e} "
                   f"frac={rep_o.roofline_fraction:.3f}")
        if base is not None:
            rep_b = analyze(base, TPU_V5E)
            derived += (f" (baseline frac={rep_b.roofline_fraction:.3f} "
                        f"serial={rep_b.step_time_serial_s:.2f}s ->"
                        f" {rep_o.step_time_serial_s:.2f}s)")
        common.emit(f"perf/{opt.arch}/{opt.shape}/{tag}", us, derived)
        rows.append((opt.name, tag, rep_o))
    common.write_out("perf_hillclimb.md", "\n".join(
        f"| {n} | {t} | {r.compute_s:.3e} | {r.memory_s:.3e} "
        f"| {r.collective_s:.3e} | {r.roofline_fraction:.3f} |"
        for n, t, r in rows))


def sweep_scaling() -> None:
    """Tentpole scaling claim: batched DSE throughput at population scale.

    Times ``evaluate(method="batched")`` over 10 apps x V generated variants
    for V in {3, 100, 1k, 10k} (cells/second) on all THREE kernel backends
    (NumPy eager vs JAX jitted vs the fused Pallas kernel -- interpreter
    mode when no TPU is attached), then the batched-vs-scalar speedup at
    V=1000 -- PR 1's >=50x acceptance gate.
    """
    from repro.core.sweep import shard_sweep

    profiles = common.scaling_profiles(10)
    space = ParamSpace.default()
    sizes = (3, 50) if common.SMOKE else (3, 100, 1000, 10000)
    backends = ("numpy", "jax", "pallas")
    rows = []
    table = None
    for v in sizes:
        machines = space.sample(v, seed=0)
        rates = {}
        for backend in backends:
            us, table = common.timeit(
                evaluate, profiles, variants=machines, method="batched",
                backend=backend, repeat=1 if v >= 1000 else 3)
            cells = len(profiles) * v
            rates[backend] = cells / (us / 1e6)
            common.emit(f"sweep/batched[{backend}]/V{v}", us / cells,
                        f"cells={cells} cells_per_s={rates[backend]:.0f} "
                        f"best={table.overall_best_fit()}")
        # streamed mega-sweep path: population regenerated per shard
        # (PopulationStream), end-to-end including the survivor re-score
        us, _ = common.timeit(
            shard_sweep, profiles, space=space, n=v, seed=0, stream=True,
            num_shards=max(2, min(8, v // 2)), backend="numpy",
            include_named=(), repeat=1)
        cells = len(profiles) * v
        rates["streamed"] = cells / (us / 1e6)
        common.emit(f"sweep/streamed/V{v}", us / cells,
                    f"cells={cells} cells_per_s={rates['streamed']:.0f}")
        rows.append((v, len(profiles) * v, rates))

    v_cmp = 50 if common.SMOKE else 1000
    machines = space.sample(v_cmp, seed=0)
    us_b, table_b = common.timeit(
        evaluate, profiles, variants=machines, method="batched", repeat=1)
    us_s, _ = common.timeit(
        evaluate, profiles, variants=machines, method="scalar", repeat=1)
    speedup = us_s / max(us_b, 1e-9)
    common.emit("sweep/speedup", us_b / (len(profiles) * v_cmp),
                f"batched_s={us_b / 1e6:.4f} scalar_s={us_s / 1e6:.3f} "
                f"speedup={speedup:.0f}x at V={v_cmp}")

    from repro.core import get_backend
    pallas_mode = ("interpret" if get_backend("pallas").interpret
                   else "compiled")
    res = table_b.result
    md = [f"| V | cells | numpy cells/s | jax cells/s "
          f"| pallas ({pallas_mode}) cells/s | streamed shard_sweep cells/s |",
          "|---|---|---|---|---|---|"]
    md += [f"| {v} | {c} | {r['numpy']:.0f} | {r['jax']:.0f} "
           f"| {r['pallas']:.0f} | {r['streamed']:.0f} |" for v, c, r in rows]
    md += ["", f"batched vs scalar at V={v_cmp}: {speedup:.0f}x",
           "(jax timings include jit-compile amortization at small V; "
           "the crossover vs NumPy moves with population size.  The pallas "
           "column runs the fused kernel -- in interpreter mode it measures "
           "correctness-path overhead, not TPU throughput.  The streamed "
           "column is the end-to-end mega-sweep path: per-shard population "
           "regeneration (PopulationStream) + gather-free statistics + "
           "survivor re-score, so V is bounded by disk/patience, not RAM -- "
           "at small V its fixed per-shard overhead dominates; throughput "
           "converges toward the numpy column as V grows)", "",
           res.markdown(top_k=10)]
    common.write_out("sweep_scaling.md", "\n".join(md))


def stress_scaling() -> None:
    """Generated-workload stress populations: generator + scoring scale.

    Times ``AppSpace.default()`` profile generation at A in {8, 64, 512,
    4096} apps (profiles/second; the generator must never be the sweep
    bottleneck), then full A x V congruence scoring of ``gen:A`` suites
    through ``run_sweep`` on every kernel backend side by side.  Halton
    vs seeded-RNG generation are timed separately -- both are
    index-addressed, so streamed shards regenerate identical rows.
    """
    import numpy as np

    from repro.core.genload import AppSpace
    from repro.core.sweep import run_sweep

    space = AppSpace.default()
    sizes = (8, 64) if common.SMOKE else (8, 64, 512, 4096)
    v = 16 if common.SMOKE else 128
    backends = ("numpy", "jax", "pallas")
    rows = []
    for a in sizes:
        idx = np.arange(a)
        rates = {}
        for mode in ("halton", "rng"):
            us, _ = common.timeit(space.profiles_at, idx, mode=mode,
                                  repeat=1 if a >= 512 else 3)
            rates[mode] = a / (us / 1e6)
            common.emit(f"stress/gen[{mode}]/A{a}", us / a,
                        f"profiles_per_s={rates[mode]:.0f}")
        for backend in backends:
            us, res = common.timeit(
                run_sweep, f"gen:{a}", n=v, include_named=(),
                backend=backend, repeat=1)
            cells = a * v
            rates[backend] = cells / (us / 1e6)
            common.emit(f"stress/score[{backend}]/A{a}", us / cells,
                        f"cells={cells} cells_per_s={rates[backend]:.0f} "
                        f"finite={bool(np.isfinite(res.aggregate).all())}")
        rows.append((a, rates))

    md = [f"generated-workload stress scaling: gen:A suites x V={v} "
          f"machine variants (AppSpace.default, Halton indices)",
          "",
          "| A apps | halton gen/s | rng gen/s | numpy cells/s "
          "| jax cells/s | pallas cells/s |",
          "|---|---|---|---|---|---|"]
    md += [f"| {a} | {r['halton']:.0f} | {r['rng']:.0f} | {r['numpy']:.0f} "
           f"| {r['jax']:.0f} | {r['pallas']:.0f} |" for a, r in rows]
    md += ["", "(generation is index-addressed: profiles_at(indices) is "
           "byte-identical to slicing the materialized suite, so streamed "
           "mega-sweeps regenerate shards instead of holding populations "
           "in RAM.  See docs/stress.md.)"]
    common.write_out("stress_scaling.md", "\n".join(md))


def packing_bench() -> None:
    """Multi-tenant packing vs the uniform-fleet baseline.

    Packs a generated stress population (``gen:A``) across M machine
    instances under a fleet-total area budget (``pack_codesign``) and
    compares the fleet objective against the uniform baseline: M copies
    of the best single machine from ``constrained_codesign`` at
    budget/M per machine -- the strategy a fleet without per-tenant
    specialization would deploy.  The improvement column is the
    acceptance claim pinned in tests/test_packing.py.
    """
    from repro.core.constrained import constrained_codesign
    from repro.core.model_zoo import resolve_suite
    from repro.core.packing import fleet_objective, pack_codesign
    from repro.core.sweep import MachineBatch

    num_apps, m = (12, 2) if common.SMOKE else (64, 4)
    steps = 8 if common.SMOKE else 60
    budget, beta = 2.0, 1.5
    apps = resolve_suite(f"gen:{num_apps}")
    seeds = MachineBatch.from_models(VARIANTS)

    us_u, uni = common.timeit(
        constrained_codesign, apps, seeds, steps=steps, beta=beta,
        area_budget=budget / m, repeat=1)
    uniform_fleet = MachineBatch.from_models([uni.best_model()] * m)
    j_uniform = fleet_objective(apps, uniform_fleet, beta=beta)
    common.emit("packing/uniform", us_u / max(steps, 1),
                f"J_fleet={j_uniform:.4f} (best single machine x {m})")

    us_p, pk = common.timeit(
        pack_codesign, apps, seeds, num_machines=m, steps=steps, beta=beta,
        area_budget=budget, repeat=1)
    j_pack = fleet_objective(apps, pk.machines, beta=beta)
    common.emit("packing/packed", us_p / max(steps, 1),
                f"J_fleet={j_pack:.4f} feasible={bool(pk.feasible)} "
                f"improvement={j_uniform - j_pack:.4f}")

    md = [f"multi-tenant packing: {num_apps} generated apps across {m} "
          f"machines, fleet area budget {budget:.1f} "
          f"(uniform baseline: best constrained single machine at "
          f"{budget / m:.2f} per machine, replicated)",
          "",
          "| strategy | fleet J | fleet area | feasible | wall s |",
          "|---|---|---|---|---|",
          f"| uniform x{m} | {j_uniform:.4f} "
          f"| {float(m * uni.area_final[int(uni.best)]):.3f} "
          f"| yes | {us_u / 1e6:.2f} |",
          f"| packed | {j_pack:.4f} | {pk.area_total:.3f} "
          f"| {'yes' if pk.feasible else 'NO'} | {us_p / 1e6:.2f} |",
          "",
          f"improvement: {j_uniform - j_pack:.4f} "
          f"({(j_uniform - j_pack) / max(abs(j_uniform), 1e-9) * 100:.1f}% "
          "of the uniform objective)",
          "",
          pk.markdown(top_k=6),
          "",
          "(packing specializes machines to tenant clusters -- compute-"
          "bound apps land on FLOPs-heavy instances, bandwidth-bound apps "
          "on HBM-heavy ones -- so the same silicon covers the population "
          "better than any replicated compromise design.  See "
          "docs/stress.md.)"]
    common.write_out("packing.md", "\n".join(md))


def grad_codesign_bench() -> None:
    """Gradient co-design throughput + improvement from the named seeds."""
    from repro.core import VARIANTS as SEEDS
    from repro.core.codesign import grad_codesign
    from repro.core.sweep import MachineBatch

    profiles = common.profiles_or_synthetic()[0]
    steps = 10 if common.SMOKE else 100
    us, res = common.timeit(
        grad_codesign, profiles, MachineBatch.from_models(SEEDS),
        steps=steps, repeat=1)
    for i, name in enumerate(res.names):
        common.emit(f"grad/{name}", us / max(steps, 1),
                    f"objective {res.objective_seed[i]:.4f} -> "
                    f"{res.objective_final[i]:.4f} in {steps} steps")
    common.write_out("grad_codesign.md", "\n".join(
        ["| seed | J(seed) | J(final) | improvement |", "|---|---|---|---|"]
        + [f"| {n} | {s:.4f} | {f:.4f} | {s - f:.4f} |"
           for n, s, f in zip(res.names, res.objective_seed,
                              res.objective_final)]))


def constrained_codesign_bench() -> None:
    """Budgeted co-design: objective vs feasibility vs wall-clock per mode.

    Runs the three descent modes from the named-variant seeds under a
    reference-chip area budget (area <= 1.0): unconstrained (`grad_codesign`,
    the PR 2 baseline -- free to inflate every subsystem), projected
    gradient, and augmented Lagrangian.  The table quantifies the price of
    feasibility: how much scalarized objective each constrained mode gives
    up to stay inside the budget, and what each costs in wall-clock.
    """
    from repro.core.codesign import grad_codesign
    from repro.core.constrained import constrained_codesign
    from repro.core.sweep import MachineBatch

    profiles = common.profiles_or_synthetic()[0]
    seeds = MachineBatch.from_models(VARIANTS)
    budget = 1.0  # the reference chip's area, by construction
    steps = 10 if common.SMOKE else 80

    def run_unconstrained():
        return grad_codesign(profiles, seeds, steps=steps)

    def run_projected():
        return constrained_codesign(profiles, seeds, steps=steps,
                                    area_budget=budget, mode="projected")

    def run_lagrangian():
        return constrained_codesign(profiles, seeds, steps=steps,
                                    area_budget=budget, mode="lagrangian")

    rows = []
    for mode, fn in (("unconstrained", run_unconstrained),
                     ("projected", run_projected),
                     ("lagrangian", run_lagrangian)):
        us, res = common.timeit(fn, repeat=1)
        area = res.area_final
        feas = ("n/a (no budget)" if res.feasible is None else
                f"{int(res.feasible.sum())}/{len(res.feasible)}")
        best_j = float(res.objective_final[res.best])
        common.emit(f"constrained/{mode}", us / max(steps, 1),
                    f"best_J={best_j:.4f} max_area={float(area.max()):.3f} "
                    f"feasible={feas}")
        rows.append((mode, res, us / 1e6))

    md = [f"constrained co-design: {len(profiles)} apps, "
          f"{len(seeds)} named seeds, area budget {budget:.1f} "
          f"(reference chip), {steps} steps",
          "",
          "| mode | best J(final) | mean J(final) | max area | max power "
          "| feasible | wall-clock s |",
          "|---|---|---|---|---|---|---|"]
    for mode, res, secs in rows:
        feas = ("n/a" if res.feasible is None
                else f"{int(res.feasible.sum())}/{len(res.feasible)}")
        md.append(
            f"| {mode} | {float(res.objective_final[res.best]):.4f} "
            f"| {float(res.objective_final.mean()):.4f} "
            f"| {float(res.area_final.max()):.3f} "
            f"| {float(res.power_final.max()):.3f} "
            f"| {feas} | {secs:.2f} |")
    md += ["",
           "(unconstrained is the PR 2 baseline: nothing stops it from "
           "exceeding the budget, so its area column is the price of "
           "ignoring silicon limits.  Projected keeps every iterate "
           "feasible; Lagrangian approaches from outside with a damped "
           "violation trace and a final safety projection.  See "
           "docs/codesign.md for the worked guide.)"]
    common.write_out("constrained_codesign.md", "\n".join(md))


def frontier_bench() -> None:
    """Feasibility frontier: continuation vs cold restarts, same schedule.

    Traces J*(budget) over a geometric budget schedule that actually BINDS
    on the synthetic suite (the unconstrained optima sit near area
    0.1-0.3, so the schedule spans the infeasible floor, the binding
    region and the flat tail past the knee).  Warm-started continuation
    and per-budget cold restarts run the same code path
    (``frontier_codesign(warm_start=...)``); the wall-clock ratio is the
    continuation pin -- the whole trace for little more than one run.
    """
    import numpy as np

    from repro.core.frontier import frontier_codesign
    from repro.core.sweep import MachineBatch

    profiles = common.profiles_or_synthetic()[0]
    seeds = MachineBatch.from_models(VARIANTS)
    if common.SMOKE:
        budgets = [0.1, 0.3, 1.0]
        steps, refine = 8, 2
    else:
        budgets = [float(b) for b in np.geomspace(0.05, 1.0, 8)]
        steps, refine = 120, 12
    us_warm, warm = common.timeit(
        frontier_codesign, profiles, seeds, budgets, steps=steps,
        refine_steps=refine, repeat=1)
    us_cold, cold = common.timeit(
        frontier_codesign, profiles, seeds, budgets, steps=steps,
        refine_steps=refine, warm_start=False, repeat=1)
    n = len(warm)
    steps_warm = steps + (n - 1) * refine
    steps_cold = n * steps
    ratio = us_cold / max(us_warm, 1e-9)
    for i in range(n):
        common.emit(
            f"frontier/b{warm.budgets[i]:.3g}", us_warm / n,
            f"J*={warm.objective[i]:.4f} cold_J*={cold.objective[i]:.4f} "
            f"best={warm.best_names[i]} area={warm.area[i]:.3f} "
            f"feasible={bool(warm.feasible[i])}")
    common.emit("frontier/continuation_speedup", us_warm / max(steps_warm, 1),
                f"warm_s={us_warm / 1e6:.2f} cold_s={us_cold / 1e6:.2f} "
                f"speedup={ratio:.2f}x steps {steps_warm} vs {steps_cold}")

    md = [f"feasibility frontier: {len(profiles)} apps, {len(seeds)} named "
          f"seeds, {n} area budgets, {steps} full + {refine} refine steps",
          "",
          "| area budget | J* (continuation) | J* (cold restarts) "
          "| best seed | area | power | feasible |",
          "|---" * 7 + "|"]
    for i in range(n):
        md.append(
            f"| {warm.budgets[i]:.4g} | {warm.objective[i]:.4f} "
            f"| {cold.objective[i]:.4f} | {warm.best_names[i]} "
            f"| {warm.area[i]:.3f} | {warm.power[i]:.3f} "
            f"| {'yes' if warm.feasible[i] else 'NO'} |")
    feas = warm.feasible
    knee = f"{warm.knee():.4g}" if bool(feas.any()) else "n/a"
    md += [
        "",
        f"knee (diminishing returns): budget {knee}",
        f"wall-clock: continuation {us_warm / 1e6:.2f} s vs cold restarts "
        f"{us_cold / 1e6:.2f} s -- **{ratio:.2f}x** ({steps_warm} vs "
        f"{steps_cold} descent steps; both share one jitted "
        f"objective/projection, the budget enters as a traced scalar)",
        "",
        "(J* is monotone non-increasing in the budget by construction -- "
        "tighter-budget winners propagate to looser budgets whenever they "
        "score better.  Infeasible rows mark budgets below the span-box "
        "floor: no machine in the feasible box fits.  See docs/frontier.md "
        "for the worked guide.)"]
    common.write_out("frontier_codesign.md", "\n".join(md))


def sensitivity_bench() -> None:
    """Implicit differentiation vs the alternatives it replaces.

    Prices one budget-gradient ``d min_v J*_v / d [area, power]`` three
    ways on the synthetic suite: the **implicit** custom-VJP (forward
    solve + one small ridge KKT solve -- graph size independent of
    ``steps``), the **unrolled** penalty-descent baseline (autodiff
    through every iteration -- graph grows linearly with ``steps``), and
    **central finite differences** (2 extra full solves per budget
    coordinate, no gradient graph at all).  Emits wall-clock per gradient
    and the traced jaxpr equation counts that the structure regression
    test pins.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.implicit import implicit_jstar_fn, unrolled_jstar_fn
    from repro.core.kernels_xp import get_backend
    from repro.core.sweep import MachineBatch

    profiles = common.profiles_or_synthetic()[0]
    seeds = MachineBatch.from_models(VARIANTS)
    backend = get_backend("jax")
    # 40 steps is the convergence floor for meaningful shadow prices on
    # the synthetic suite; smoke keeps it (jit compile dominates anyway)
    # and only trims the unrolled baseline, whose cost IS the point.
    steps = 40 if common.SMOKE else 80
    un_steps = 6 if common.SMOKE else 30
    budgets = np.array([0.18, 0.30])

    def count_eqns(jaxpr) -> int:
        # Recurse into sub-jaxprs (fori_loop bodies, custom_vjp calls):
        # top-level eqn counts would hide the solver behind one opaque
        # custom_vjp_call and make the structure pin vacuous.
        n = 0
        for eq in jaxpr.eqns:
            n += 1
            for v in eq.params.values():
                if hasattr(v, "jaxpr"):
                    n += count_eqns(v.jaxpr)
                elif hasattr(v, "eqns"):
                    n += count_eqns(v)
        return n

    f_imp = implicit_jstar_fn(profiles, seeds, steps=steps)
    f_unr = unrolled_jstar_fn(profiles, seeds, steps=un_steps)
    with backend._x64():
        b = jnp.asarray(budgets, dtype=jnp.float64)
        v_imp = jax.jit(lambda bb: jnp.min(f_imp(bb)))
        g_imp = jax.jit(jax.grad(lambda bb: jnp.min(f_imp(bb))))
        g_unr = jax.jit(jax.grad(lambda bb: jnp.min(f_unr(bb))))
        g_imp(b).block_until_ready()        # compile outside the timer
        g_unr(b).block_until_ready()
        v_imp(b).block_until_ready()
        us_imp, grad_imp = common.timeit(
            lambda: np.asarray(g_imp(b)), repeat=3)
        us_unr, grad_unr = common.timeit(
            lambda: np.asarray(g_unr(b)), repeat=3)

        def fd_grad():
            out = np.zeros(2)
            for j in range(2):
                h = 1e-3 * budgets[j]
                for sgn in (1.0, -1.0):
                    bp = budgets.copy()
                    bp[j] += sgn * h
                    out[j] += sgn * float(v_imp(jnp.asarray(bp))) / (2 * h)
            return out

        us_fd, grad_fd = common.timeit(fd_grad, repeat=3)

        # Structure pin: the implicit graph must not grow with steps.
        n_eq = {}
        for tag, fn in (("implicit", f_imp),
                        ("implicit_2x",
                         implicit_jstar_fn(profiles, seeds,
                                           steps=2 * steps)),
                        ("unrolled", f_unr)):
            jaxpr = jax.make_jaxpr(
                lambda bb, fn=fn: jnp.min(fn(bb)))(b)
            n_eq[tag] = count_eqns(jaxpr.jaxpr)

    err = float(np.max(np.abs(grad_imp - grad_fd))
                / max(np.max(np.abs(grad_fd)), 1e-12))
    common.emit("sensitivity/implicit_grad", us_imp,
                f"dJ*/db=({grad_imp[0]:.4f},{grad_imp[1]:.4f}) "
                f"eqns={n_eq['implicit']} steps={steps}")
    common.emit("sensitivity/unrolled_grad", us_unr,
                f"dJ*/db=({grad_unr[0]:.4f},{grad_unr[1]:.4f}) "
                f"eqns={n_eq['unrolled']} steps={un_steps}")
    common.emit("sensitivity/fd_grad", us_fd,
                f"dJ*/db=({grad_fd[0]:.4f},{grad_fd[1]:.4f}) "
                f"4 solves rel_err_implicit={err:.2e}")

    md = [f"budget-gradient pricing: {len(profiles)} apps, {len(seeds)} "
          f"named seeds, budgets (area, power) = ({budgets[0]:.3g}, "
          f"{budgets[1]:.3g})",
          "",
          "| method | us/gradient | dJ*/d(area) | dJ*/d(power) "
          "| jaxpr eqns | solver steps |",
          "|---" * 6 + "|",
          f"| implicit custom-VJP | {us_imp:.0f} | {grad_imp[0]:.4f} "
          f"| {grad_imp[1]:.4f} | {n_eq['implicit']} | {steps} |",
          f"| unrolled penalty | {us_unr:.0f} | {grad_unr[0]:.4f} "
          f"| {grad_unr[1]:.4f} | {n_eq['unrolled']} | {un_steps} |",
          f"| central FD (4 solves) | {us_fd:.0f} | {grad_fd[0]:.4f} "
          f"| {grad_fd[1]:.4f} | - | {4 * steps} |",
          "",
          f"implicit vs FD agreement: max rel err {err:.2e}; implicit "
          f"graph at 2x steps: {n_eq['implicit_2x']} eqns vs "
          f"{n_eq['implicit']} (steps-independent -- the fori_loop body "
          f"traces once); the unrolled graph grows linearly with steps "
          f"and its penalty gradient only approximates the shadow price.",
          "",
          "(dJ*/d(budget) is the negated shadow price: relaxing the area "
          "budget by db buys a first-order objective improvement of "
          "-dJ*/db * db.  See docs/frontier.md for reading sensitivities "
          "off a frontier and docs/codesign.md for the bilevel descent "
          "that consumes this gradient.)"]
    common.write_out("sensitivity.md", "\n".join(md))


def codesign_service_bench() -> None:
    """Load test for the micro-batched, compile-cached serving front door.

    Four sweep phases over the same population (identical kernel work per
    request) isolate each economy: **cold** sequential requests price the
    baseline; **cached** replays the identical requests (result memo --
    must be measurably cheaper, pinned in tests/test_serving.py);
    **batched** submits N distinct suites at once so they ride ONE
    struct-of-arrays pass; **threaded** drives real workers end-to-end.
    The frontier phase prices cold vs continuation-warm vs memo-cached
    schedules.  Writes the cold/cached/batched table to
    benchmarks/out/codesign_service.md.
    """
    import dataclasses as dc
    import time

    import numpy as np

    from repro.core.spec import CodesignSpec
    from repro.serving.codesign_service import (
        CodesignRequest,
        CodesignService,
    )

    base, synth = common.profiles_or_synthetic()
    if common.SMOKE:
        reqs, n, workers = 6, 64, 2
        budgets, steps, refine = [0.3, 1.0], 6, 2
    else:
        reqs, n, workers = 24, 512, 4
        budgets, steps, refine = [0.1, 0.3, 0.6, 1.0], 60, 12
    spec = CodesignSpec(n=n, seed=0)

    def suite(i, phase):
        # distinct per request (no accidental memo hits across suites),
        # identical shape (so batching and jit reuse both engage)
        return [dc.replace(p, name=f"{p.name}/{phase}{i}",
                           flops=p.flops * (1 + 0.003 * (i + 1)))
                for p in base[:3]]

    def req(i, phase):
        return CodesignRequest(kind="sweep", profiles=suite(i, phase),
                               spec=spec)

    def sequential(svc, phase):
        lat = []
        t0 = time.perf_counter()
        for i in range(reqs):
            t1 = time.perf_counter()
            svc.submit(req(i, phase))
            svc.drain()
            lat.append(time.perf_counter() - t1)
        return time.perf_counter() - t0, lat

    def stats_row(label, total, lat):
        p50 = float(np.percentile(lat, 50)) * 1e3
        p99 = float(np.percentile(lat, 99)) * 1e3
        common.emit(f"codesign_service/{label}", total / reqs * 1e6,
                    f"req_s={reqs / total:.1f} p50_ms={p50:.2f} "
                    f"p99_ms={p99:.2f}")
        return (label, reqs, total, reqs / total, p50, p99)

    svc = CodesignService(auto_start=False)
    rows = []
    cold_total, cold_lat = sequential(svc, "cold")       # misses everything
    rows.append(stats_row("cold", cold_total, cold_lat))
    cached_total, cached_lat = sequential(svc, "cold")   # memo replay
    rows.append(stats_row("cached", cached_total, cached_lat))

    t0 = time.perf_counter()
    jids = [svc.submit(req(i, "batch")) for i in range(reqs)]
    svc.drain()
    batched_total = time.perf_counter() - t0
    batched_lat = [svc.poll(j)["queued_s"] + svc.poll(j)["run_s"]
                   for j in jids]
    rows.append(stats_row("batched", batched_total, batched_lat))

    svc2 = CodesignService(workers=workers, max_pending=4 * reqs)
    t0 = time.perf_counter()
    tjids = [svc2.submit(req(i, "thread")) for i in range(reqs)]
    for j in tjids:
        svc2.result(j, timeout=600)
    threaded_total = time.perf_counter() - t0
    threaded_lat = [svc2.poll(j)["queued_s"] + svc2.poll(j)["run_s"]
                    for j in tjids]
    rows.append(stats_row(f"threaded_w{workers}", threaded_total,
                          threaded_lat))
    svc2.shutdown()

    # NOT common.timeit: its warm-up call would populate the result memo
    # and the continuation cache, making every "cold" timing a cache hit.
    def one_frontier(frontier_spec):
        t1 = time.perf_counter()
        svc.submit(CodesignRequest(kind="frontier", profiles=fsuite,
                                   spec=frontier_spec))
        svc.drain()
        return (time.perf_counter() - t1) * 1e6

    fspec = CodesignSpec(budgets=budgets, steps=steps, refine_steps=refine)
    fsuite = base[:1]
    tight = CodesignSpec(budgets=[min(budgets) * 0.8], steps=steps,
                         refine_steps=refine)
    us_fc = one_frontier(fspec)        # cold: full schedule from the seeds
    us_fw = one_frontier(tight)        # warm: continuation from 'cold' state
    us_fm = one_frontier(fspec)        # cached: identical repeat, memo hit
    common.emit("codesign_service/frontier_cold", us_fc,
                f"budgets={len(budgets)} steps={steps}")
    common.emit("codesign_service/frontier_warm", us_fw,
                f"speedup={us_fc / max(us_fw, 1e-9):.2f}x "
                f"(continuation warm start, {refine} refine steps)")
    common.emit("codesign_service/frontier_cached", us_fm,
                f"speedup={us_fc / max(us_fm, 1e-9):.2f}x (result memo)")

    label = "synthetic" if synth else "dry-run artifacts"
    md = [f"co-design service load test: {reqs} sweep requests x "
          f"{len(base[:3])} apps ({label}), population n={n}, numpy-default "
          "backend, one service instance",
          "",
          "| phase | requests | total s | req/s | p50 ms | p99 ms |",
          "|---|---|---|---|---|---|"]
    for (lbl, r, total, rps, p50, p99) in rows:
        md.append(f"| {lbl} | {r} | {total:.3f} | {rps:.1f} "
                  f"| {p50:.2f} | {p99:.2f} |")
    md += [
        "",
        "frontier schedule economics (same suite/seeds/constraints):",
        "",
        "| query | wall s | vs cold |",
        "|---|---|---|",
        f"| cold schedule ({len(budgets)} budgets, {steps} steps) "
        f"| {us_fc / 1e6:.3f} | 1.00x |",
        f"| tighter follow-up (continuation warm start) "
        f"| {us_fw / 1e6:.3f} | {us_fc / max(us_fw, 1e-9):.2f}x |",
        f"| identical repeat (result memo) "
        f"| {us_fm / 1e6:.3f} | {us_fc / max(us_fm, 1e-9):.2f}x |",
        "",
        f"service cache accounting: {dict(svc.stats)}",
        "",
        "(cold pays population build + beta resolution + scoring per "
        "request; cached replays hit the result memo; batched rides one "
        "SoA pass -- each scattered slice byte-identical to its solo run, "
        "pinned in tests/test_serving.py.  The threaded row is the same "
        "work through real worker threads, micro-batching "
        "opportunistically.  See docs/serving.md.)"]
    common.write_out("codesign_service.md", "\n".join(md))


BENCHMARKS = {
    "table1_congruence": table1_congruence,
    "fig3_radar": fig3_radar,
    "roofline_table": roofline_table,
    "zoo_calibration": zoo_calibration,
    "profiler_overhead": profiler_overhead,
    "perf_hillclimb": perf_hillclimb,
    "sweep_scaling": sweep_scaling,
    "stress_scaling": stress_scaling,
    "packing": packing_bench,
    "grad_codesign": grad_codesign_bench,
    "constrained_codesign": constrained_codesign_bench,
    "frontier": frontier_bench,
    "sensitivity": sensitivity_bench,
    "codesign_service": codesign_service_bench,
}


def main(argv=None) -> None:
    import os

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic profiles, single repeat (CI mode)")
    ap.add_argument("--backend", default=None,
                    help="default kernel backend for every benchmark "
                         "(numpy/jax/pallas or any registered name; "
                         "sweep_scaling always reports all side by side)")
    ap.add_argument("benchmarks", nargs="*", choices=[[], *BENCHMARKS],
                    help="subset to run (default: all)")
    args = ap.parse_args(argv)
    from repro.core.kernels_xp import validate_backend_arg
    validate_backend_arg(ap, args.backend)
    common.SMOKE = args.smoke
    if args.backend:
        os.environ["REPRO_SWEEP_BACKEND"] = args.backend
    print("name,us_per_call,derived")
    for name in (args.benchmarks or BENCHMARKS):
        BENCHMARKS[name]()


if __name__ == "__main__":
    main()
