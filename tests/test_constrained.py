"""Constrained + joint co-design: feasibility invariants and the
clip/projection order-of-operations regression.

The load-bearing properties (the ISSUE acceptance gates):
  * random budgets => the final machine is ALWAYS within budget to 1e-9
    (hypothesis-driven on the projection operator, parametrized end-to-end
    on full descents);
  * the span clip and the budget projection commute through the combined
    retraction (the order-of-operations bug class);
  * the Lagrangian violation trace is monotonically damped;
  * rounding-with-repair never returns an infeasible ``ici_links``.
"""

import numpy as np
import pytest

from conftest import hypothesis_shim

given, settings, st = hypothesis_shim(seed=0xBEEF, trials=32)

from repro.core import VARIANTS, WorkloadProfile
from repro.core.codesign import theta_box
from repro.core.constrained import (
    FEASIBLE_RTOL,
    budget_feasible,
    budget_violations_vector,
    constrained_codesign,
    joint_codesign,
    project_to_budgets,
    validate_area_envelope,
)
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.sweep import MachineBatch, run_sweep, shard_sweep
from test_sweep import random_profiles

SEEDS = MachineBatch.from_models(VARIANTS)
FIXED = SEEDS.arrays()
THETA0, LO, HI = theta_box(SEEDS, span=16.0)


def _machines_of(theta):
    from repro.core.codesign import machine_arrays_from_theta
    return machine_arrays_from_theta(np, np.asarray(theta), FIXED)


def _rng_theta(rng, scale=4.0):
    """Random log-rates around the seeds, deliberately allowed OUTSIDE the
    span box (the projection must absorb the clip)."""
    return THETA0 + rng.uniform(-scale, scale, size=THETA0.shape)


# --------------------------------------------------------------------------- #
# The projection operator (hypothesis: random budgets => feasible to 1e-9)
# --------------------------------------------------------------------------- #


@settings(max_examples=64, deadline=None)
@given(budget=st.floats(0.05, 4.0), jitter=st.floats(0.0, 6.0))
def test_projection_feasible_for_random_budgets(budget, jitter):
    """For ANY budget and any (even out-of-box) theta, the projected
    machine satisfies area <= budget * (1 + 1e-9) whenever the budget is
    attainable under the span floor."""
    rng = np.random.default_rng(int(jitter * 1e6) % (2 ** 31))
    theta = THETA0 + rng.uniform(-jitter, jitter, size=THETA0.shape)
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, budget)
    area = DEFAULT_COST_MODEL.area(_machines_of(proj))
    floor_area = DEFAULT_COST_MODEL.area(_machines_of(LO))
    attainable = floor_area <= budget
    assert np.array_equal(feasible, attainable)
    assert np.all(area[attainable] <= budget * (1.0 + FEASIBLE_RTOL))
    # Inside the box, always.
    assert np.all(proj >= LO - 1e-12) and np.all(proj <= HI + 1e-12)


@settings(max_examples=32, deadline=None)
@given(area_b=st.floats(0.3, 3.0), power_b=st.floats(0.3, 3.0))
def test_projection_respects_both_budgets(area_b, power_b):
    rng = np.random.default_rng(7)
    theta = _rng_theta(rng)
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, area_b, power_b)
    m = _machines_of(proj)
    ok = budget_feasible(np, m, DEFAULT_COST_MODEL, area_b, power_b)
    assert np.all(ok[feasible])


def test_projection_no_budget_is_plain_clip():
    rng = np.random.default_rng(3)
    theta = _rng_theta(rng)
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, None, None)
    np.testing.assert_array_equal(proj, np.clip(theta, LO, HI))
    assert np.all(feasible)


def test_projection_leaves_feasible_points_untouched():
    """Already-feasible in-box thetas pass through bit-exactly (t* = 0)."""
    theta = LO + 0.25 * (HI - LO)       # deep inside the box, small rates
    budget = float(DEFAULT_COST_MODEL.area(_machines_of(theta)).max()) * 2.0
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, budget)
    np.testing.assert_array_equal(proj, theta)
    assert np.all(feasible)


# --------------------------------------------------------------------------- #
# Clip/projection commute (the order-of-operations regression)
# --------------------------------------------------------------------------- #


def _P(theta, budget=1.0, method="shift"):
    return project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, budget,
        method=method)[0]


@pytest.mark.parametrize("method", ["shift", "euclidean"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("budget", [0.5, 1.0, 2.0])
def test_clip_and_projection_commute(seed, budget, method):
    """The combined retraction absorbs the span clip on either side:
    P(clip(x)) == P(x) == clip(P(x)).  Descent code may therefore order
    the two operators freely -- the bug class this pins is a projection
    that lands outside the box (clip-after breaks the budget) or a clip
    that re-inflates a projected design (budget-after breaks the box).
    Both retraction operators (uniform shift, true Euclidean) obey the
    same laws, so they are interchangeable in every descent mode."""
    rng = np.random.default_rng(seed)
    theta = _rng_theta(rng, scale=6.0)   # far outside the box on purpose
    p = _P(theta, budget, method)
    np.testing.assert_array_equal(
        p, _P(np.clip(theta, LO, HI), budget, method))
    np.testing.assert_array_equal(p, np.clip(p, LO, HI))
    # Idempotence: projecting a projected point is the identity.
    np.testing.assert_allclose(p, _P(p, budget, method), atol=1e-12)


# --------------------------------------------------------------------------- #
# The Euclidean projection (per-coordinate KKT solve)
# --------------------------------------------------------------------------- #


@settings(max_examples=64, deadline=None)
@given(budget=st.floats(0.05, 4.0), jitter=st.floats(0.0, 6.0))
def test_euclidean_projection_feasible_for_random_budgets(budget, jitter):
    """Same contract as the shift operator: for ANY budget and any (even
    out-of-box) theta, the Euclidean-projected machine satisfies
    area <= budget * (1 + 1e-9) whenever the budget is attainable."""
    rng = np.random.default_rng(int(jitter * 1e6) % (2 ** 31))
    theta = THETA0 + rng.uniform(-jitter, jitter, size=THETA0.shape)
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, budget,
        method="euclidean")
    area = DEFAULT_COST_MODEL.area(_machines_of(proj))
    floor_area = DEFAULT_COST_MODEL.area(_machines_of(LO))
    attainable = floor_area <= budget
    assert np.array_equal(feasible, attainable)
    assert np.all(area[attainable] <= budget * (1.0 + FEASIBLE_RTOL))
    assert np.all(proj >= LO - 1e-12) and np.all(proj <= HI + 1e-12)


@pytest.mark.parametrize("budget", [0.3, 0.8, 1.5])
def test_euclidean_moves_no_farther_than_shift(budget):
    """The point of the true projection: it returns the CLOSEST feasible
    point, so its L2 move from the (clipped) input never exceeds the
    uniform shift's -- a binding budget on one subsystem no longer drags
    every other rate down with it."""
    rng = np.random.default_rng(17)
    theta = _rng_theta(rng, scale=4.0)
    clipped = np.clip(theta, LO, HI)
    d_euc = np.linalg.norm(_P(theta, budget, "euclidean") - clipped, axis=1)
    d_shift = np.linalg.norm(_P(theta, budget, "shift") - clipped, axis=1)
    assert np.all(d_euc <= d_shift + 1e-9)


def test_euclidean_projection_respects_both_budgets():
    rng = np.random.default_rng(7)
    theta = _rng_theta(rng)
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, 0.8,
        power_budget=1.0, method="euclidean")
    m = _machines_of(proj)
    ok = budget_feasible(np, m, DEFAULT_COST_MODEL, 0.8, 1.0)
    assert np.all(ok[feasible])


def test_euclidean_rejects_links_column_and_mask():
    """The Euclidean path owns only the 4 rate columns; the links
    relaxation and the masked rounding repair stay on the shift
    operator (an explicit error, not silent wrong math)."""
    theta5 = np.concatenate([THETA0, np.log(FIXED.ici_links)[:, None]],
                            axis=1)
    lo5 = np.concatenate([LO, np.zeros((len(LO), 1))], axis=1)
    hi5 = np.concatenate([HI, np.log(FIXED.ici_links)[:, None] + 1], axis=1)
    with pytest.raises(ValueError, match="4 rate columns"):
        project_to_budgets(np, theta5, lo5, hi5, FIXED, DEFAULT_COST_MODEL,
                           1.0, method="euclidean")
    with pytest.raises(ValueError, match="4 rate columns"):
        project_to_budgets(np, THETA0, LO, HI, FIXED, DEFAULT_COST_MODEL,
                           1.0, mask=np.array([True] * 4),
                           method="euclidean")
    with pytest.raises(ValueError, match="unknown projection"):
        project_to_budgets(np, THETA0, LO, HI, FIXED, DEFAULT_COST_MODEL,
                           1.0, method="manhattan")


def test_euclidean_constrained_codesign_end_to_end():
    apps = random_profiles(3, seed=41)
    res = constrained_codesign(apps, SEEDS, area_budget=0.8, steps=10,
                               projection="euclidean")
    assert np.all(res.area_final <= 0.8 * (1.0 + FEASIBLE_RTOL))
    assert np.all(res.feasible)
    assert np.all(res.violation_trace == 0.0)
    with pytest.raises(ValueError, match="optimize_links"):
        constrained_codesign(apps, SEEDS, area_budget=0.8, steps=2,
                             projection="euclidean", optimize_links=True)
    with pytest.raises(ValueError, match="unknown projection"):
        constrained_codesign(apps, SEEDS, area_budget=0.8, steps=2,
                             projection="taxicab")


# --------------------------------------------------------------------------- #
# Per-subsystem area envelopes (multi-constraint budgets)
# --------------------------------------------------------------------------- #


def test_validate_area_envelope():
    assert validate_area_envelope(None) is None
    assert validate_area_envelope({}) is None
    assert validate_area_envelope({"hbm_bw": 1.5}) == {"hbm_bw": 1.5}
    with pytest.raises(ValueError, match="unknown area_envelope field"):
        validate_area_envelope({"sram": 1.0})
    with pytest.raises(ValueError, match="must be positive"):
        validate_area_envelope({"hbm_bw": 0.0})


def test_violations_vector_one_column_per_constraint():
    m = _machines_of(THETA0 + np.log(4.0))   # 4x the seeds: everything over
    vv = budget_violations_vector(np, m, DEFAULT_COST_MODEL, 1.0, 1.0,
                                  {"hbm_bw": 0.5, "peak_flops": 0.5})
    assert vv.shape == (len(SEEDS), 4)       # area, power, 2 envelope keys
    assert np.all(vv >= 0.0) and np.all(vv[:, 0] > 0.0)
    only_env = budget_violations_vector(np, m, DEFAULT_COST_MODEL, None,
                                        None, {"hbm_bw": 0.5})
    assert only_env.shape == (len(SEEDS), 1)


@pytest.mark.parametrize("method", ["shift", "euclidean"])
def test_envelope_projection_caps_each_subsystem(method):
    rng = np.random.default_rng(5)
    theta = _rng_theta(rng, scale=4.0)
    env = {"peak_flops": 0.7, "hbm_bw": 1.2}
    proj, feasible = project_to_budgets(
        np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, None,
        area_envelope=env, method=method)
    m = _machines_of(proj)
    for field, b in env.items():
        sub = DEFAULT_COST_MODEL.subsystem_area(m, field)
        assert np.all(sub[feasible] <= b * (1.0 + FEASIBLE_RTOL)), field


@pytest.mark.parametrize("mode", ["projected", "lagrangian"])
def test_envelope_constrained_codesign_end_to_end(mode):
    """Envelopes are honoured by both descent modes, composed with the
    scalar area budget; the Lagrangian carries one multiplier per
    constraint and its damped-trace law still holds."""
    apps = random_profiles(3, seed=43)
    env = {"hbm_bw": 0.6}
    res = constrained_codesign(apps, SEEDS, area_budget=0.9,
                               area_envelope=env, mode=mode, steps=12,
                               outer_iters=3)
    assert np.all(res.feasible)
    assert np.all(res.area_final <= 0.9 * (1.0 + FEASIBLE_RTOL))
    for m in res.models():
        assert (DEFAULT_COST_MODEL.subsystem_area(m, "hbm_bw")
                <= 0.6 * (1.0 + FEASIBLE_RTOL))
    assert np.all(np.diff(res.violation_trace, axis=0) <= 1e-12)
    rep = res.feasibility_report()
    assert rep["constrained"] and rep["area_envelope"] == env


def test_envelope_with_links_relaxation_keeps_integer_links():
    """The ici_bw_total envelope is re-checked against the ROUNDED link
    count during the repair, so returned models satisfy it with integer
    links."""
    apps = random_profiles(2, seed=47)
    res = constrained_codesign(apps, SEEDS, area_budget=1.0,
                               area_envelope={"ici_bw_total": 0.7},
                               steps=10, optimize_links=True)
    for m in res.models():
        assert m.ici_links >= 1 and isinstance(m.ici_links, int)
        assert (DEFAULT_COST_MODEL.subsystem_area(m, "ici_bw_total")
                <= 0.7 * (1.0 + FEASIBLE_RTOL))
    assert np.all(res.feasible)


def test_envelope_only_constraint_set_is_valid():
    """An envelope alone is a legitimate constraint set (no scalar budget
    required) -- and an empty constraint set still raises."""
    apps = random_profiles(2, seed=53)
    res = constrained_codesign(apps, SEEDS,
                               area_envelope={"peak_flops": 0.8}, steps=6)
    for m in res.models():
        assert (DEFAULT_COST_MODEL.subsystem_area(m, "peak_flops")
                <= 0.8 * (1.0 + FEASIBLE_RTOL))
    with pytest.raises(ValueError, match="area_envelope"):
        constrained_codesign(apps, SEEDS, steps=2)


# --------------------------------------------------------------------------- #
# End-to-end feasibility: projected + Lagrangian descents
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def suite():
    return random_profiles(4, seed=11)


@pytest.mark.parametrize("mode", ["projected", "lagrangian"])
@pytest.mark.parametrize("budget", [0.6, 1.0, 2.5])
def test_constrained_final_machines_within_budget(suite, mode, budget):
    """The ISSUE acceptance gate: both modes return machines with
    CostModel.area(m) <= budget * (1 + 1e-9) on all named seeds."""
    res = constrained_codesign(suite, SEEDS, area_budget=budget, mode=mode,
                               steps=12, outer_iters=3)
    cm = DEFAULT_COST_MODEL
    for m in res.models():
        assert cm.area(m) <= budget * (1.0 + FEASIBLE_RTOL)
    assert np.all(res.feasible)
    assert np.all(res.area_final <= budget * (1.0 + FEASIBLE_RTOL))
    rep = res.feasibility_report()
    assert rep["constrained"] and rep["all_feasible"]
    assert rep["mode"] == mode


def test_projected_trajectory_feasible_and_monotone(suite):
    """Projected mode: EVERY accepted iterate is feasible (violation trace
    identically zero) and the objective never increases."""
    res = constrained_codesign(suite, SEEDS, area_budget=0.8, steps=15)
    assert np.all(res.violation_trace == 0.0)
    assert np.all(np.diff(res.trajectory, axis=0) <= 1e-12)


def test_lagrangian_violation_trace_monotonically_damped(suite):
    """Lagrangian mode may wander outside the budget, but the recorded
    per-round violation never increases (damped by construction) and ends
    at zero after the final safety projection."""
    res = constrained_codesign(suite, SEEDS, area_budget=0.7,
                               mode="lagrangian", steps=24, outer_iters=4)
    trace = res.violation_trace
    assert trace.shape[1] == len(SEEDS)
    assert np.all(np.diff(trace, axis=0) <= 1e-12)
    assert np.all(trace[-1] <= FEASIBLE_RTOL)
    # denser/densest seeds start above a 0.7 budget: the trace must have
    # something to damp, or this test pins nothing.
    assert float(trace[0].max()) > 0.0


def test_constrained_with_power_budget(suite):
    res = constrained_codesign(suite, SEEDS, power_budget=1.2, steps=10)
    assert np.all(res.power_final <= 1.2 * (1.0 + FEASIBLE_RTOL))
    assert res.area_budget is None and res.power_budget == 1.2


def test_constrained_validates_inputs(suite):
    with pytest.raises(ValueError,
                       match="area_budget, power_budget and/or area_envelope"):
        constrained_codesign(suite, SEEDS, steps=2)
    with pytest.raises(ValueError, match="must be positive"):
        constrained_codesign(suite, SEEDS, area_budget=-1.0, steps=2)
    with pytest.raises(ValueError, match="unknown constraint mode"):
        constrained_codesign(suite, SEEDS, area_budget=1.0, mode="hope",
                             steps=2)


def test_custom_cost_model_budget(suite):
    """Budgets are enforced under the CALLER's cost model, not the default."""
    cm = CostModel(area_weights={"peak_flops": 3.0, "hbm_bw": 1.0})
    res = constrained_codesign(suite, SEEDS, area_budget=0.9, steps=10,
                               cost_model=cm)
    for m in res.models():
        assert cm.area(m) <= 0.9 * (1.0 + FEASIBLE_RTOL)


# --------------------------------------------------------------------------- #
# Integer relaxation: rounding-with-repair for ici_links
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("budget", [0.8, 1.5])
def test_rounding_with_repair_feasible_integer_links(suite, budget):
    """optimize_links relaxes ici_links continuously; the final models must
    carry INTEGER link counts >= 1 and still satisfy the budget."""
    res = constrained_codesign(suite, SEEDS, area_budget=budget, steps=12,
                               optimize_links=True)
    cm = DEFAULT_COST_MODEL
    for params, m in zip(res.final_params, res.models()):
        assert m.ici_links >= 1
        # The repaired theta carries log(integer): exact after round-trip.
        assert abs(params["ici_links"] - round(params["ici_links"])) < 1e-9
        assert cm.area(m) <= budget * (1.0 + FEASIBLE_RTOL)
    assert np.all(res.feasible)


def test_rounding_repair_integer_even_with_fractional_box_floor(suite):
    """Regression: a seed with many links makes the span box's lower edge
    fractional (ici_links=24, span=16 => continuous floor 1.5).  The
    repair must clamp rounded counts to the INTEGER sub-range, never to
    the fractional box edge -- otherwise models() silently re-rounds and
    the returned machine diverges from the reported feasibility fields."""
    from repro.core.machine import TPU_V5E

    seeds = MachineBatch.from_models(
        [TPU_V5E.with_rates(name="linky", ici_links=24)])
    res = constrained_codesign(suite, seeds, area_budget=1.0, steps=10,
                               optimize_links=True)
    links = res.final_params[0]["ici_links"]
    assert links == round(links), links          # exactly integral
    assert links >= 2                            # ceil(24/16) = 2, not 1.5
    m = res.models()[0]
    assert m.ici_links == int(links)
    # Reported feasibility must describe the RETURNED model exactly.
    assert abs(DEFAULT_COST_MODEL.area(m) - res.area_final[0]) < 1e-12
    assert DEFAULT_COST_MODEL.area(m) <= 1.0 * (1.0 + FEASIBLE_RTOL)


def test_rounding_repair_rescues_ceil_violation(suite):
    """A budget that binds exactly at the continuous optimum: rounding up
    would violate it, so the repair must re-project the rates.  Whatever
    the rounding direction, the result stays feasible."""
    res = constrained_codesign(suite, SEEDS, area_budget=0.55, steps=15,
                               optimize_links=True)
    assert np.all(res.area_final <= 0.55 * (1.0 + FEASIBLE_RTOL))
    assert all(m.ici_links >= 1 for m in res.models())


# --------------------------------------------------------------------------- #
# Joint (machine, sharding-variant) descent
# --------------------------------------------------------------------------- #


def _sharding_groups(n=4, seed=23, members=3):
    """Synthetic sharding-variant groups: member 0 is the 'default' layout;
    the others trade collective traffic against memory traffic the way
    tp/zero1/fsdp layouts do."""
    apps = random_profiles(n, seed=seed)
    groups = []
    for p in apps:
        group = [p]
        for k in range(1, members):
            q = WorkloadProfile(
                name=f"{p.name}/v{k}",
                flops=p.flops,
                hbm_bytes=max(p.hbm_bytes, p.bytes_accessed) * (1 + 0.3 * k),
                bytes_accessed=p.bytes_accessed * (1 + 0.3 * k),
                collective_bytes={"all-reduce":
                                  p.total_collective_bytes / (2.0 ** k)},
                num_devices=p.num_devices,
                model_flops=p.model_flops,
            )
            group.append(q)
        groups.append(group)
    return groups


@pytest.mark.parametrize("mode", ["alternate", "softmax"])
def test_joint_selection_valid_and_monotone(mode):
    groups = _sharding_groups(3)
    res = joint_codesign(groups, SEEDS, mode=mode, rounds=2, steps=9)
    assert res.mode == f"joint-{mode}"
    assert len(res.selection_names) == len(SEEDS)
    for picks in res.selection_names:
        assert len(picks) == len(groups)
        for g, name in enumerate(picks):
            assert name in [p.name for p in groups[g]]
    assert np.all(res.improvement >= 0)


def test_joint_under_budget_is_feasible():
    groups = _sharding_groups(3)
    res = joint_codesign(groups, SEEDS, rounds=2, steps=9, area_budget=0.9)
    assert np.all(res.feasible)
    assert np.all(res.area_final <= 0.9 * (1.0 + FEASIBLE_RTOL))


def test_joint_flat_profiles_degrade_to_singletons():
    """A flat profile list means singleton groups: selection is trivial and
    the run reduces to machine-only descent."""
    apps = random_profiles(2, seed=31)
    res = joint_codesign(apps, SEEDS, rounds=1, steps=6)
    assert all(picks == [p.name for p in apps]
               for picks in res.selection_names)


def test_joint_validates_mode():
    with pytest.raises(ValueError, match="unknown joint mode"):
        joint_codesign(random_profiles(1), SEEDS, mode="psychic", steps=2)


# --------------------------------------------------------------------------- #
# Sweep -> descent bridge (seed_codesign warm starts)
# --------------------------------------------------------------------------- #


def test_seed_codesign_bridge(suite):
    res = run_sweep(suite, n=96, seed=5, include_named=VARIANTS)
    seeds = res.seed_codesign(k=4)
    assert 1 <= len(seeds) <= 4
    assert set(seeds.names) <= set(res.variant_names)
    # Survivors are ordered by suite-mean aggregate.
    agg = {n: a for n, a in zip(res.variant_names, res.aggregate_mean())}
    vals = [agg[n] for n in seeds.names]
    assert vals == sorted(vals)
    # And they warm-start a constrained descent end-to-end.
    cd = constrained_codesign(suite, seeds, area_budget=1.0, steps=6)
    assert np.all(cd.feasible)
    assert np.all(cd.improvement >= 0)


def test_seed_codesign_sharded_matches_single_device(suite):
    single = run_sweep(suite, n=128, seed=2)
    sharded = shard_sweep(suite, n=128, seed=2, num_shards=4)
    assert sharded.seed_codesign(k=6).names == \
        single.seed_codesign(k=6).names


def test_seed_codesign_contains_fronts(suite):
    res = run_sweep(suite, n=96, seed=5)
    names = set(res.seed_codesign().names)
    for i in res.pareto_front():
        assert res.variant_names[i] in names
    for i in res.pareto_front_3d():
        assert res.variant_names[i] in names
    for a in res.best_fit_indices():
        assert res.variant_names[int(a)] in names


# --------------------------------------------------------------------------- #
# CLI parse-time validation (hillclimb co-design flags)
# --------------------------------------------------------------------------- #


def test_hillclimb_validates_codesign_args_at_parse_time():
    import argparse

    from repro.launch.hillclimb import validate_codesign_args

    def args_of(**kw):
        base = dict(grad=0, area_budget=None, power_budget=None,
                    constraint_mode=None, opt_links=False, joint=False)
        base.update(kw)
        return argparse.Namespace(**base)

    class Boom(Exception):
        pass

    class P(argparse.ArgumentParser):
        def error(self, message):
            raise Boom(message)

    p = P()
    validate_codesign_args(p, args_of())                       # no flags: ok
    validate_codesign_args(p, args_of(grad=5, area_budget=1.0))
    validate_codesign_args(p, args_of(grad=5, joint=True))
    with pytest.raises(Boom, match="positive"):
        validate_codesign_args(p, args_of(grad=5, area_budget=0.0))
    with pytest.raises(Boom, match="require --grad"):
        validate_codesign_args(p, args_of(area_budget=1.0))
    with pytest.raises(Boom, match="require --grad"):
        validate_codesign_args(p, args_of(joint=True))
    with pytest.raises(Boom, match="area-budget and/or"):
        validate_codesign_args(p, args_of(grad=5, opt_links=True))
    with pytest.raises(Boom, match="area-budget and/or"):
        validate_codesign_args(p, args_of(grad=5,
                                          constraint_mode="lagrangian"))
    # --joint composes with budgets only through the projected retraction;
    # silently ignoring the other knobs would misreport the algorithm run.
    with pytest.raises(Boom, match="projected retraction"):
        validate_codesign_args(p, args_of(grad=5, joint=True,
                                          area_budget=1.0, opt_links=True))
    with pytest.raises(Boom, match="projected retraction"):
        validate_codesign_args(p, args_of(grad=5, joint=True,
                                          area_budget=1.0,
                                          constraint_mode="lagrangian"))


# --------------------------------------------------------------------------- #
# CodesignSpec: the one request object (round-trip + legacy equivalence)
# --------------------------------------------------------------------------- #


def test_spec_json_roundtrip():
    from repro.core.machine import TPU_V5E
    from repro.core.spec import CodesignSpec

    cm = CostModel(reference=TPU_V5E,
                   area_weights={"peak_flops": 2.0},
                   power_weights={"hbm_bw": 1.5})
    spec = CodesignSpec(area_budget=1.2, power_budget=0.9,
                        area_envelope={"hbm_bw": 0.8}, budgets=(0.5, 1.0),
                        mode="projected", projection="euclidean", steps=7,
                        refine_steps=2, lr=0.05, span=8.0, warm_start=True,
                        w_area=0.2, beta=1.5, timing_model="overlap",
                        cost_model=cm, backend="numpy", clamp=False,
                        n=64, sweep_mode="grid", seed=3)
    blob = spec.to_json()
    import json

    json.dumps(blob)                             # plain data only
    back = CodesignSpec.from_json(blob)
    assert back == spec
    assert back.cost_model.area_weights == {"peak_flops": 2.0}
    # None fields stay omitted and default on the way back
    assert "optimize_links" not in blob
    # unknown fields are rejected, not silently dropped
    with pytest.raises(ValueError, match="unknown CodesignSpec fields"):
        CodesignSpec.from_json({"stepz": 3})


def test_spec_one_validation_path():
    from repro.core.spec import CodesignSpec

    with pytest.raises(ValueError, match="unknown projection"):
        CodesignSpec(projection="diagonal").validate()
    with pytest.raises(ValueError, match="unknown mode"):
        CodesignSpec(mode="sideways").validate()
    with pytest.raises(ValueError, match="unknown backend"):
        CodesignSpec(backend="tpu9000").validate()
    with pytest.raises(ValueError, match="positive"):
        CodesignSpec(area_budget=0.0).validate()
    with pytest.raises(ValueError, match="positive"):
        CodesignSpec(budgets=[0.5, -1.0]).validate()
    with pytest.raises(ValueError):
        CodesignSpec(area_envelope={"not_a_field": 1.0}).validate()
    with pytest.raises(ValueError, match="unknown sweep_mode"):
        CodesignSpec(sweep_mode="sobol").validate()
    # validate() normalizes: budgets ascending + deduplicated
    norm = CodesignSpec(budgets=[1.0, 0.5, 1.0]).validate()
    assert norm.budgets == (0.5, 1.0)


def test_spec_legacy_kwarg_equivalence_constrained(suite):
    """Byte-identical pin: spec-carried parameters produce the same
    descent as the historical keyword call, and an explicit keyword
    always beats the spec's field."""
    from repro.core.spec import CodesignSpec

    spec = CodesignSpec(area_budget=1.0, steps=6, lr=0.1,
                        mode="projected").validate()
    via_spec = constrained_codesign(suite, SEEDS, spec=spec)
    via_kwargs = constrained_codesign(suite, SEEDS, area_budget=1.0,
                                      steps=6, lr=0.1, mode="projected")
    np.testing.assert_array_equal(via_spec.objective_final,
                                  via_kwargs.objective_final)
    np.testing.assert_array_equal(via_spec.trajectory, via_kwargs.trajectory)
    assert via_spec.steps == via_kwargs.steps == 6
    # explicit keyword wins over the spec field
    override = constrained_codesign(suite, SEEDS, spec=spec, steps=3)
    assert override.steps == 3


def test_spec_legacy_kwarg_equivalence_joint_and_frontier(suite):
    from repro.core.frontier import frontier_codesign
    from repro.core.spec import CodesignSpec

    groups = [[p] for p in suite[:2]]
    jspec = CodesignSpec(mode="alternate", steps=4).validate()
    j1 = joint_codesign(groups, SEEDS, spec=jspec, rounds=2)
    j2 = joint_codesign(groups, SEEDS, mode="alternate", steps=4, rounds=2)
    np.testing.assert_array_equal(j1.objective_final, j2.objective_final)

    fspec = CodesignSpec(budgets=[0.8, 1.4], steps=4,
                         refine_steps=2).validate()
    f1 = frontier_codesign(suite[:1], SEEDS, spec=fspec)
    f2 = frontier_codesign(suite[:1], SEEDS, budgets=[0.8, 1.4], steps=4,
                           refine_steps=2)
    np.testing.assert_array_equal(f1.objective, f2.objective)
    assert f1.budgets.tolist() == f2.budgets.tolist()
    # budgets may come from the spec alone; omitting both is an error
    with pytest.raises(ValueError, match="budget schedule"):
        frontier_codesign(suite[:1], SEEDS)
