"""Implicit differentiation through the co-design optimum (ISSUE 10).

The acceptance gates, each pinned by the shared finite-difference
harness (``conftest.gradcheck`` + ``repro.core.implicit.polish_theta``
as the warm-started re-solver):

  * the implicit ``dJ*/d(budget)`` matches central finite differences to
    rtol 1e-3 on every named seed machine AND every feasible
    ``FrontierResult`` point;
  * KKT structure holds under random budget schedules (multipliers
    nonnegative, ~zero for inactive constraints -- complementary
    slackness -- and ``dJ*/db <= 0``, i.e. J* monotone in the budget);
  * the implicit multipliers agree with the augmented-Lagrangian
    estimate wherever that path converges to the same optimum;
  * the implicit custom-VJP's traced graph does NOT grow with solver
    ``steps`` (the unrolled baseline's does -- that is the point);
  * ``bilevel_codesign`` strictly improves on the uniform 50/50 budget
    split on the default profile suite.
"""

import argparse
import os
import sys
import types

import numpy as np
import pytest

from conftest import gradcheck, hypothesis_shim

given, settings, st = hypothesis_shim(seed=0x1CC7, trials=4)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import common  # noqa: E402

from repro.core import VARIANTS, VARIANTS_BY_NAME  # noqa: E402
from repro.core.codesign import OPT_FIELDS  # noqa: E402
from repro.core.constrained import (  # noqa: E402
    constrained_codesign,
    constraint_labels,
)
from repro.core.frontier import frontier_codesign  # noqa: E402
from repro.core.implicit import (  # noqa: E402
    BilevelResult,
    SensitivityReport,
    bilevel_codesign,
    implicit_jstar_fn,
    implicit_sensitivities,
    polish_theta,
    sensitivities_of,
    unrolled_jstar_fn,
)
from repro.core.kernels_xp import get_backend  # noqa: E402
from repro.core.sweep import MachineBatch  # noqa: E402

PROFILES = common.synthetic_profiles()
SEEDS = MachineBatch.from_models(VARIANTS)
B_AREA = 0.18  # binds on every named seed for the synthetic suite


def _theta_of(params_list):
    return np.log(np.array(
        [[p[f] for f in OPT_FIELDS] for p in params_list]))


@pytest.fixture(scope="module")
def res_proj():
    return constrained_codesign(PROFILES, SEEDS, steps=200,
                                area_budget=B_AREA, mode="projected")


@pytest.fixture(scope="module")
def rep(res_proj):
    # The IFT formulas hold AT the optimum; polish the 200-step descent
    # point to stationarity before reading multipliers off it.
    return sensitivities_of(res_proj, PROFILES, polish_steps=100)


@pytest.fixture(scope="module")
def fr():
    # 0.05 sits below the span-box area floor (~0.0625 for the smallest
    # named seed) -- the infeasible-row NaN contract needs a floor row.
    return frontier_codesign(PROFILES, SEEDS, [0.05, 0.15, 0.25, 0.5],
                             steps=80, refine_steps=30)


# --------------------------------------------------------------------------- #
# The tentpole gate: implicit dJ*/db == central FD (named seeds + frontier)
# --------------------------------------------------------------------------- #


def test_implicit_matches_fd_on_every_named_seed(res_proj, rep):
    """dJ*/d(area budget) from the linearized KKT system must match a
    warm-started central-difference re-solve to rtol 1e-3 PER SEED.

    The seeds descend independently, so summing per-variant objectives
    at per-variant budgets turns the (V,) check into one scalar
    gradcheck: coordinate v of the FD gradient is variant v's dJ*/db.
    """
    theta_star = _theta_of(res_proj.final_params)

    def jstar_sum(budgets):
        _, f = polish_theta(PROFILES, SEEDS, theta_star,
                            area_budget=budgets, steps=120, lr=0.05)
        return float(np.sum(f))

    assert list(rep.constraint_names) == ["area"]
    worst = gradcheck(jstar_sum, np.full(len(VARIANTS), B_AREA),
                      rep.dJ_dbudget[:, 0], rtol=1e-3, atol=1e-7,
                      h=1e-3, log_space=True)
    assert worst <= 1e-3
    # Shadow prices are the negated sensitivities and the budget binds.
    np.testing.assert_allclose(rep.multipliers[:, 0],
                               -rep.dJ_dbudget[:, 0], rtol=0, atol=0)
    assert np.all(rep.multipliers[:, 0] > 0) and np.all(rep.active[:, 0])


def test_implicit_matches_fd_on_every_feasible_frontier_point(fr):
    """Every feasible frontier row's attached dJ*/d(area budget) must
    survive the same FD harness -- including the propagated flat-segment
    rows, whose slack area constraint prices at exactly zero."""
    rows = [i for i in range(len(fr))
            if fr.feasible[i] and np.isfinite(fr.dJ_dbudget[i])]
    assert len(rows) >= 2  # the binding knee AND the flat tail
    row_seeds = MachineBatch.from_models(
        [VARIANTS_BY_NAME[fr.best_names[i]] for i in rows])
    theta = _theta_of([fr.best_params[i] for i in rows])

    def jstar_sum(budgets):
        _, f = polish_theta(PROFILES, row_seeds, theta,
                            area_budget=budgets, steps=100, lr=0.05)
        return float(np.sum(f))

    worst = gradcheck(jstar_sum, fr.budgets[rows], fr.dJ_dbudget[rows],
                      rtol=1e-3, atol=1e-6, h=1e-3, log_space=True)
    assert worst <= 1e-3
    # The flat tail exists and prices at zero (slack => lambda == 0).
    assert np.any(fr.dJ_dbudget[rows] == 0.0)
    assert np.any(fr.dJ_dbudget[rows] < 0.0)


def test_infeasible_frontier_rows_carry_nan_sensitivities(fr):
    bad = ~fr.feasible
    assert bad.any()
    assert np.all(np.isnan(fr.dJ_dbudget[bad]))


# --------------------------------------------------------------------------- #
# Cross-check: augmented-Lagrangian multipliers vs the implicit ones
# --------------------------------------------------------------------------- #


def test_lagrangian_multipliers_agree_where_al_converges(res_proj, rep):
    """The AL path maintains running multiplier estimates; wherever its
    descent reaches the same optimum as the projected path, those
    estimates must agree with the implicit shadow prices (same KKT
    point, two independent derivations)."""
    res_al = constrained_codesign(PROFILES, SEEDS, steps=200,
                                  area_budget=B_AREA, mode="lagrangian")
    assert res_al.constraint_names == ("area",)
    lam_al = res_al.multipliers[:, 0]
    assert np.all(lam_al >= 0.0)
    # Condition on actual convergence: the objective is flat near the
    # optimum, so only variants whose AL descent lands on the SAME point
    # (objective equal to 1e-6) carry converged multiplier estimates --
    # the others stall nearby with a stale running lambda.
    same = np.isclose(res_al.objective_final, res_proj.objective_final,
                      rtol=1e-6)
    assert same.any(), "AL never matched the projected optimum"
    np.testing.assert_allclose(lam_al[same], rep.multipliers[same, 0],
                               rtol=0.1)


# --------------------------------------------------------------------------- #
# KKT property suite under random budget schedules
# --------------------------------------------------------------------------- #


@settings(max_examples=4, deadline=None)
@given(area=st.floats(0.16, 0.5), power=st.floats(0.2, 0.6))
def test_kkt_structure_for_random_budgets(area, power):
    """For any budget schedule: multipliers nonnegative, zero on slack
    constraints (complementary slackness), and dJ*/db nonpositive."""
    rep = implicit_sensitivities(PROFILES, SEEDS, area_budget=area,
                                 power_budget=power, polish_steps=60)
    assert list(rep.constraint_names) == constraint_labels(area, power)
    assert np.all(rep.multipliers >= 0.0)
    assert np.all(rep.multipliers[~rep.active] == 0.0)
    assert np.all(rep.dJ_dbudget <= 0.0)
    np.testing.assert_allclose(rep.dJ_dbudget, -rep.multipliers)
    for i in range(len(rep.names)):
        best = rep.best_relaxation(i)
        if best is not None:
            j = list(rep.constraint_names).index(best)
            assert rep.multipliers[i, j] == rep.multipliers[i].max()


@settings(max_examples=3, deadline=None)
@given(budget=st.floats(0.16, 0.35), widen=st.floats(0.05, 0.3))
def test_jstar_monotone_nonincreasing_in_budget(budget, widen):
    """Relaxing the budget can only help: J*(b) >= J*(b + widen) per
    seed (the global sign condition behind dJ*/db <= 0)."""
    theta0 = np.log(np.stack([[getattr(m, f) for f in OPT_FIELDS]
                              for m in VARIANTS]))
    _, tight = polish_theta(PROFILES, SEEDS, theta0,
                            area_budget=np.full(3, budget), steps=80)
    _, loose = polish_theta(PROFILES, SEEDS, theta0,
                            area_budget=np.full(3, budget + widen),
                            steps=80)
    assert np.all(tight >= loose - 1e-9)


def test_sensitivities_need_a_constraint():
    with pytest.raises(ValueError, match="at least one"):
        implicit_sensitivities(PROFILES, SEEDS)


def test_envelope_prices_route_to_named_subsystem():
    """A binding per-subsystem envelope gets its own named column; slack
    scalar budgets price at ~0 next to it."""
    rep = implicit_sensitivities(PROFILES, SEEDS, area_budget=0.2,
                                 power_budget=0.28,
                                 area_envelope={"hbm_bw": 0.25},
                                 polish_steps=80)
    assert list(rep.constraint_names) == ["area", "power", "hbm_bw"]
    j = rep.constraint_names.index("hbm_bw")
    assert np.any(rep.multipliers[:, j] > 0.0)
    md = rep.markdown()
    assert "hbm_bw" in md and "relax first" in md


# --------------------------------------------------------------------------- #
# The custom-VJP jstar map: gradient correctness + structure regression
# --------------------------------------------------------------------------- #


def test_custom_vjp_budget_gradient_matches_fd():
    """jax.grad through implicit_jstar_fn == central FD of its own value
    path (the gradient jax sees is the envelope-theorem cotangent)."""
    backend = get_backend("jax")
    jax, jnp = backend._jax, backend._jnp
    f = implicit_jstar_fn(PROFILES, SEEDS, steps=60)
    with backend._x64():
        b = jnp.asarray([B_AREA, 0.30], dtype=jnp.float64)
        grad = np.asarray(jax.jit(
            jax.grad(lambda bb: jnp.min(f(bb))))(b))
        v = jax.jit(lambda bb: jnp.min(f(bb)))

        def value(bvec):
            with backend._x64():
                return float(v(jnp.asarray(bvec, dtype=jnp.float64)))

    worst = gradcheck(value, np.array([B_AREA, 0.30]), grad,
                      rtol=1e-3, atol=1e-8, log_space=True)
    assert worst <= 1e-3
    assert grad[0] < 0.0  # area binds on the synthetic suite


def test_implicit_graph_size_is_steps_independent():
    """The memory/structure regression: the implicit map's traced graph
    must be IDENTICAL at steps=10 and steps=200 (one fori_loop body +
    one ridge solve), while the unrolled baseline's grows linearly."""
    backend = get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    def count_eqns(jaxpr):
        n = 0
        for eq in jaxpr.eqns:
            n += 1
            for v in eq.params.values():
                if hasattr(v, "jaxpr"):
                    n += count_eqns(v.jaxpr)
                elif hasattr(v, "eqns"):
                    n += count_eqns(v)
        return n

    def size_of(fn):
        with backend._x64():
            b = jnp.asarray([B_AREA, 0.30], dtype=jnp.float64)
            return count_eqns(jax.make_jaxpr(
                lambda bb: jnp.min(fn(bb)))(b).jaxpr)

    imp10 = size_of(implicit_jstar_fn(PROFILES, SEEDS, steps=10))
    imp200 = size_of(implicit_jstar_fn(PROFILES, SEEDS, steps=200))
    assert imp10 == imp200
    unr10 = size_of(unrolled_jstar_fn(PROFILES, SEEDS, steps=10))
    unr30 = size_of(unrolled_jstar_fn(PROFILES, SEEDS, steps=30))
    assert unr30 > 1.5 * unr10  # grows with steps
    assert unr30 > 2 * imp200   # the graph the implicit VJP avoids


# --------------------------------------------------------------------------- #
# Result surfacing: frontier columns, CodesignResult shadow prices
# --------------------------------------------------------------------------- #


def test_frontier_markdown_and_json_carry_sensitivities(fr):
    md = fr.markdown()
    assert "dJ*/db" in md and "shadow price" in md
    blob = fr.to_json()
    assert blob["sensitivity_constraints"][0] == "area"
    feas = [p for p in blob["points"] if p["feasible"]]
    assert all("dJ_dbudget" in p and "shadow_prices" in p for p in feas)
    infeas = [p for p in blob["points"] if not p["feasible"]]
    assert all("dJ_dbudget" not in p for p in infeas)
    import json
    json.dumps(blob)


def test_frontier_sensitivities_opt_out():
    fr2 = frontier_codesign(PROFILES, SEEDS, [0.25], steps=20,
                            sensitivities=False)
    assert fr2.dJ_dbudget is None
    assert "dJ*/db" not in fr2.markdown()


def test_lagrangian_result_reports_shadow_prices():
    res = constrained_codesign(PROFILES, SEEDS, steps=60,
                               area_budget=0.2, power_budget=0.3,
                               mode="lagrangian")
    rep = res.feasibility_report()
    assert set(rep["shadow_prices"]) == {"area", "power"}
    assert res.multipliers.shape == (len(VARIANTS), 2)
    assert np.all(res.multipliers >= 0.0)


def test_sensitivity_report_json_and_markdown(rep):
    import json
    blob = rep.to_json(top_k=2)
    json.dumps(blob)
    assert len(blob["variants"]) == 2
    v0 = blob["variants"][0]
    assert v0["shadow_prices"]["area"] == -v0["dJ_dbudget"]["area"]
    assert "| variant |" in rep.markdown()


def test_sensitivities_of_rejects_joint_results(res_proj):
    fake = types.SimpleNamespace(mode="joint-alternation")
    with pytest.raises(ValueError, match="joint"):
        sensitivities_of(fake, PROFILES)


# --------------------------------------------------------------------------- #
# Bilevel budget descent: the outer consumer of the implicit gradient
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def bl():
    return bilevel_codesign(common.scaling_profiles(10), SEEDS,
                            total_budget=0.35, steps=40, outer_steps=4)


def test_bilevel_beats_uniform_split_on_default_suite(bl):
    """The acceptance pin: on the 10 default profiles the learned split
    strictly improves the scalarized objective over the fixed 50/50
    split of the same total budget."""
    assert isinstance(bl, BilevelResult)
    assert bl.improvement_over_uniform > 1e-3
    assert bl.split_final != 0.5
    assert np.all(np.diff(bl.objective_trajectory) <= 1e-12)
    assert abs(bl.area_budget + bl.power_budget - 0.35) < 1e-12
    assert bool(bl.inner.feasible[bl.inner.best])
    assert isinstance(bl.sensitivity, SensitivityReport)


def test_bilevel_result_protocol(bl):
    import json
    blob = bl.to_json()
    json.dumps(blob)
    assert blob["improvement_over_uniform"] > 0
    md = bl.markdown()
    assert "split" in md and "uniform" in md


def test_bilevel_validates_inputs():
    with pytest.raises(ValueError, match="total_budget"):
        bilevel_codesign(PROFILES, SEEDS)
    with pytest.raises(ValueError, match="split0"):
        bilevel_codesign(PROFILES, SEEDS, total_budget=0.4, split0=1.5)


def test_bilevel_through_spec_funnel():
    from repro.core.spec import CodesignSpec
    spec = CodesignSpec(total_budget=0.4, split0=0.5, outer_steps=2,
                        steps=15, lr=0.1)
    spec.validate()
    bl = bilevel_codesign(PROFILES, SEEDS, spec=spec)
    assert bl.total_budget == 0.4
    assert bl.outer_steps == 2
    rt = CodesignSpec.from_json(spec.to_json())
    assert rt.total_budget == spec.total_budget


# --------------------------------------------------------------------------- #
# CLI surface: --sensitivities / --bilevel parse-time validation
# --------------------------------------------------------------------------- #


class _Boom(argparse.ArgumentParser):
    def error(self, message):
        raise RuntimeError(message)


def _args_of(**kw):
    base = dict(grad=0, area_budget=None, power_budget=None,
                constraint_mode=None, opt_links=False, joint=False,
                budget_sweep=None, area_envelope=None, pack=0,
                pack_gen=0, sensitivities=False, bilevel=None)
    base.update(kw)
    return types.SimpleNamespace(**base)


@pytest.mark.parametrize("kw,frag", [
    (dict(grad=5, bilevel=-1.0), "positive"),
    (dict(bilevel=0.4), "requires --grad"),
    (dict(grad=5, bilevel=0.4, area_budget=0.2), "derives"),
    (dict(grad=5, bilevel=0.4, joint=True), "own co-design mode"),
    (dict(grad=5, bilevel=0.4, pack=2), "own co-design mode"),
    (dict(sensitivities=True), "requires --grad"),
    (dict(grad=5, sensitivities=True), "needs a constraint"),
    (dict(grad=5, sensitivities=True, joint=True, area_budget=0.2),
     "joint"),
])
def test_cli_rejects_inconsistent_flags(kw, frag):
    from repro.launch.hillclimb import validate_codesign_args
    with pytest.raises(RuntimeError, match=frag):
        validate_codesign_args(_Boom(), _args_of(**kw))


@pytest.mark.parametrize("kw", [
    dict(grad=5, bilevel=0.4),
    dict(grad=5, bilevel=0.4, sensitivities=True),
    dict(grad=5, bilevel=0.4, area_envelope={"hbm_bw": 0.5}),
    dict(grad=5, sensitivities=True, area_budget=0.2),
    dict(grad=5, sensitivities=True, budget_sweep=[0.1, 0.2]),
])
def test_cli_accepts_consistent_flags(kw):
    from repro.launch.hillclimb import validate_codesign_args
    validate_codesign_args(_Boom(), _args_of(**kw))
