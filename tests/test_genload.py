"""Generated-workload stress populations (core/genload.py).

Property pins:
  1. index-addressed sampling: ``sample_at(indices)`` is byte-identical
     to slicing the materialized draw, in BOTH generation modes -- the
     streamed == materialized property mega-sweeps rely on;
  2. every generated profile is physically coherent (bytes follow from
     FLOPs and intensity, collective split sums exactly, model FLOPs
     below the global HLO count, power-of-two meshes);
  3. congruence scores of generated populations are finite on every
     kernel backend across the whole knob space;
  4. the ``gen:<count>`` suite grammar parses/validates through the ONE
     suite funnel (``model_zoo.validate_suite_name``/``resolve_suite``),
     so gen suites are accepted by ``run_sweep``, the co-design entry
     points, ``CodesignSpec`` and the CLIs without special cases.
"""

import numpy as np
import pytest

from conftest import hypothesis_shim

given, settings, st = hypothesis_shim(seed=0x9E7040, trials=16)

from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.genload import (
    APP_PARAMS,
    AppSpace,
    GEN_MODES,
    is_gen_suite,
    parse_gen_suite,
    resolve_gen_suite,
)
from repro.core.model_zoo import resolve_suite, validate_suite_name
from repro.core.spec import CodesignSpec
from repro.core.sweep import Dim, ParamSpace, run_sweep

# --------------------------------------------------------------------------- #
# suite grammar (the gen:* arm of the ONE funnel)
# --------------------------------------------------------------------------- #


def test_gen_suite_grammar():
    assert parse_gen_suite("gen:64") == (64, 0, "halton")
    assert parse_gen_suite("gen:8:seed=3") == (8, 3, "halton")
    assert parse_gen_suite("gen:8:mode=rng") == (8, 0, "rng")
    assert parse_gen_suite("gen:32:seed=7:mode=rng") == (32, 7, "rng")
    for bad in ("gen", "gen:", "gen:x", "gen:0", "gen:-3",
                "gen:8:seed=x", "gen:8:mode=bogus", "gen:8:foo=1",
                "gen:8:seed"):
        with pytest.raises(ValueError):
            parse_gen_suite(bad)


def test_is_gen_suite_dispatch():
    assert is_gen_suite("gen:8")
    assert is_gen_suite("gen")          # dispatches; parse then rejects
    assert not is_gen_suite("zoo")
    assert not is_gen_suite("zoo-smoke:train")
    assert not is_gen_suite(None)
    assert not is_gen_suite(["gen:8"])


def test_suite_funnel_accepts_gen():
    validate_suite_name("gen:8")                  # must not raise
    validate_suite_name("gen:8:seed=1:mode=rng")
    with pytest.raises(ValueError, match="count"):
        validate_suite_name("gen")
    with pytest.raises(ValueError, match="mode"):
        validate_suite_name("gen:8:mode=bogus")
    # zoo names still route to the zoo arm
    with pytest.raises(ValueError):
        validate_suite_name("zoo:bogus")
    profiles = resolve_suite("gen:5")
    assert [p.name for p in profiles] == [f"gen-{i:05d}" for i in range(5)]
    assert all(p.arch == "genload" for p in profiles)


def test_gen_suite_is_deterministic_in_the_string():
    a = resolve_suite("gen:6:seed=2")
    b = resolve_suite("gen:6:seed=2")
    for pa, pb in zip(a, b):
        assert pa.to_json() == pb.to_json()
    c = resolve_suite("gen:6:seed=3")
    assert any(pa.to_json() != pc.to_json() for pa, pc in zip(a, c))


def test_codesign_spec_validates_gen_suite():
    assert CodesignSpec(suite="gen:8").validate().suite == "gen:8"
    with pytest.raises(ValueError, match="count"):
        CodesignSpec(suite="gen").validate()
    with pytest.raises(ValueError):
        CodesignSpec(suite="gen:0").validate()


# --------------------------------------------------------------------------- #
# AppSpace construction + physical coherence
# --------------------------------------------------------------------------- #


def test_app_space_validates_knobs():
    with pytest.raises(KeyError, match="missing"):
        AppSpace(dims={"flops": Dim(1e12, 1e15)})
    dims = dict(AppSpace.default().dims)
    dims["bogus_knob"] = Dim(0.0, 1.0, log=False)
    with pytest.raises(KeyError, match="unknown workload knob"):
        AppSpace(dims=dims)
    assert sorted(AppSpace.default().dims) == sorted(APP_PARAMS)


@pytest.mark.parametrize("mode", GEN_MODES)
def test_generated_profiles_are_physically_coherent(mode):
    space = AppSpace.default()
    for p in space.profiles_at(range(64), seed=4, mode=mode):
        lo, hi = space.dims["flops"].lo, space.dims["flops"].hi
        assert lo <= p.flops <= hi
        assert p.hbm_bytes == p.bytes_accessed > 0.0
        intensity = p.flops / p.hbm_bytes
        assert 8.0 * (1 - 1e-12) <= intensity <= 2048.0 * (1 + 1e-12)
        coll = sum(p.collective_bytes.values())
        assert 0.0 <= coll <= 0.5 * p.hbm_bytes * (1 + 1e-12)
        assert all(v >= 0.0 for v in p.collective_bytes.values())
        assert 0.0 <= p.pod_collective_bytes <= coll * (1 + 1e-12)
        # power-of-two mesh inside the declared range
        assert p.num_devices & (p.num_devices - 1) == 0
        assert 8 <= p.num_devices <= 4096
        # analytic model FLOPs never exceed the global HLO count
        assert 0.0 < p.model_flops < p.flops * p.num_devices
        assert p.step_kind == "train"


# --------------------------------------------------------------------------- #
# streamed == materialized (index-addressed sampling)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("mode", GEN_MODES)
def test_sample_at_equals_slicing(mode):
    space = AppSpace.default()
    full = space.sample(32, seed=5, mode=mode)
    # contiguous shard, scattered indices, and a single row
    for idx in ([7, 8, 9, 10], [0, 31, 3, 17], [13]):
        shard = space.sample_at(idx, seed=5, mode=mode)
        assert shard.names == [full.names[i] for i in idx]
        for field in ("flops", "mem_bytes", "num_devices", "model_flops",
                      "pod_collective_bytes"):
            np.testing.assert_array_equal(getattr(shard, field),
                                          getattr(full, field)[idx])
    # profiles_at round-trips through WorkloadProfile identically
    again = space.profiles_at([13], seed=5, mode=mode)[0]
    assert again.to_json() == space.profiles_at(
        range(32), seed=5, mode=mode)[13].to_json()


def test_modes_and_seeds_decorrelate():
    space = AppSpace.default()
    h = space.sample(16, seed=0, mode="halton")
    r = space.sample(16, seed=0, mode="rng")
    assert not np.array_equal(h.flops, r.flops)
    h2 = space.sample(16, seed=1, mode="halton")
    assert not np.array_equal(h.flops, h2.flops)


# --------------------------------------------------------------------------- #
# scores finite on every backend, across the knob space
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_gen_suite_scores_finite_every_backend(backend):
    res = run_sweep("gen:12", n=8, seed=0, backend=backend)
    assert res.aggregate.shape == (12, 8)
    assert np.isfinite(res.aggregate).all()
    assert np.isfinite(res.beta).all() and (res.beta > 0).all()


@given(seed_f=st.floats(0.0, 1e6))
@settings(max_examples=16, deadline=None)
def test_gen_population_always_scores_finite(seed_f):
    """Any seed's population scores finite -- no knob corner (zero
    collectives, max intensity, tiny mesh) can produce NaN/inf."""
    res = run_sweep(f"gen:6:seed={int(seed_f)}", n=4, include_named=())
    assert np.isfinite(res.aggregate).all()
    assert np.isfinite(DEFAULT_COST_MODEL.area(res.machines)).all()


# --------------------------------------------------------------------------- #
# ParamSpace.scale_space preset (machine-side satellite)
# --------------------------------------------------------------------------- #


def test_scale_space_preset():
    from repro.core.sweep import SWEEP_PARAMS

    space = ParamSpace.scale_space(scale_span=2.0)
    assert sorted(space.dims) == sorted(SWEEP_PARAMS)
    assert sorted(ParamSpace.default().dims) == sorted(
        set(SWEEP_PARAMS) - {"scale_compute", "scale_memory",
                             "scale_interconnect"})
    for knob in ("scale_compute", "scale_memory", "scale_interconnect"):
        assert space.dims[knob].lo == pytest.approx(0.5)
        assert space.dims[knob].hi == pytest.approx(2.0)
    pop = space.sample(8, seed=0)
    assert len(pop) == 8
    res = run_sweep("gen:4", space=space, n=8, include_named=())
    assert np.isfinite(res.aggregate).all()
