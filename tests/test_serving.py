"""Co-design service: equality pins, cache accounting, queue semantics,
and the continuous-batching engine regressions.

The load-bearing properties (the ISSUE acceptance gates):
  * micro-batched concurrent sweeps are BYTE-IDENTICAL to per-request
    ``run_sweep`` (the kernels are app-rowwise independent; admission
    concatenates suites, scoring runs once, results scatter back);
  * byte-identical repeat requests hit the result memo (same object out,
    cache accounting visible) -- cached frontier == cold frontier;
  * overload rejects at submit (429-style), timeouts expire jobs, and
    cancellation lands between mega-sweep shards -- never a hang;
  * every result type renders through the one protocol
    (``markdown(top_k)`` / ``to_json(top_k)``);
  * ``BatchedEngine`` regressions: empty-prompt admission and staggered
    admissions with per-slot KV positions.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import CodesignSpec, VARIANTS, WorkloadProfile, run_sweep
from repro.core.frontier import frontier_codesign
from repro.core.sweep import MachineBatch, ParamSpace
from repro.serving.codesign_service import (
    CANCELLED,
    DONE,
    TIMEOUT,
    CodesignRequest,
    CodesignService,
    JobCancelled,
    JobTimeout,
    ServiceOverloadError,
    render_result,
)
from test_sweep import random_profiles


def suite(tag: str, k: int = 2):
    """Deterministic per-tag synthetic suite (distinct across tags)."""
    base = abs(hash(tag)) % 7 + 1
    return [WorkloadProfile(
        name=f"{tag}/app{i}", flops=2e14 * (base + i),
        hbm_bytes=1.5e11 * (1 + 0.4 * i),
        collective_bytes={"all-reduce": 2e10 * (i + 1)},
        num_devices=256, model_flops=5e16) for i in range(k)]


SPEC32 = CodesignSpec(n=32, seed=0)


def sweep_req(tag, k=2, **kw):
    return CodesignRequest(kind="sweep", profiles=suite(tag, k),
                           spec=SPEC32, **kw)


# --------------------------------------------------------------------------- #
# Micro-batching equality pins
# --------------------------------------------------------------------------- #


def assert_sweep_equal(a, b):
    assert a.apps == b.apps
    assert a.machines.names == b.machines.names
    np.testing.assert_array_equal(a.beta, b.beta)
    np.testing.assert_array_equal(a.gamma, b.gamma)
    np.testing.assert_array_equal(a.aggregate, b.aggregate)
    for key in b.scores:
        np.testing.assert_array_equal(a.scores[key], b.scores[key])
    for key in b.alphas:
        np.testing.assert_array_equal(a.alphas[key], b.alphas[key])


def test_batched_sweeps_byte_identical_to_direct():
    """THE tentpole pin: three concurrent suites ride one SoA pass and
    each scattered result equals its solo run_sweep bit for bit."""
    svc = CodesignService(auto_start=False)
    tags = ("alpha", "bravo", "charlie")
    jids = [svc.submit(sweep_req(t, k=1 + i)) for i, t in enumerate(tags)]
    svc.drain()
    assert svc.stats["batched_groups"] == 1
    assert svc.stats["batched_requests"] == len(tags)
    for i, (t, jid) in enumerate(zip(tags, jids)):
        got = svc.result(jid, timeout=5)
        direct = run_sweep(suite(t, k=1 + i), n=32, seed=0)
        assert_sweep_equal(got, direct)


def test_batched_sweeps_resolve_beta_per_request():
    """Distinct explicit beta targets don't block batching: each request's
    per-app beta vector is resolved independently and concatenated."""
    svc = CodesignService(auto_start=False)
    j1 = svc.submit(CodesignRequest(
        kind="sweep", profiles=suite("x"), spec=CodesignSpec(n=32, beta=0.5)))
    j2 = svc.submit(CodesignRequest(
        kind="sweep", profiles=suite("y"), spec=CodesignSpec(n=32, beta=2.0)))
    svc.drain()
    assert svc.stats["batched_requests"] == 2
    assert_sweep_equal(svc.result(j1, timeout=5),
                       run_sweep(suite("x"), n=32, beta=0.5))
    assert_sweep_equal(svc.result(j2, timeout=5),
                       run_sweep(suite("y"), n=32, beta=2.0))


def test_incompatible_sweeps_do_not_batch():
    svc = CodesignService(auto_start=False)
    svc.submit(sweep_req("p"))
    svc.submit(CodesignRequest(kind="sweep", profiles=suite("q"),
                               spec=CodesignSpec(n=64)))   # different pop
    svc.drain()
    assert svc.stats["batched_groups"] == 0
    assert svc.stats["pop_misses"] == 2


def test_single_sweep_matches_direct_and_population_cache_hits():
    svc = CodesignService(auto_start=False)
    j1 = svc.submit(sweep_req("solo"))
    svc.drain()
    assert svc.stats["pop_misses"] == 1
    j2 = svc.submit(sweep_req("other", k=3))   # same space/n/seed, new suite
    svc.drain()
    assert svc.stats["pop_hits"] == 1          # population regenerated 0x
    assert svc.stats["artifact_hits"] == 0     # different A -> new shapes
    assert_sweep_equal(svc.result(j1, timeout=5),
                       run_sweep(suite("solo"), n=32, seed=0))
    assert_sweep_equal(svc.result(j2, timeout=5),
                       run_sweep(suite("other", 3), n=32, seed=0))


# --------------------------------------------------------------------------- #
# Result memo + artifact accounting
# --------------------------------------------------------------------------- #


def test_repeat_request_hits_memo_and_is_same_result():
    svc = CodesignService(auto_start=False)
    j1 = svc.submit(sweep_req("memo"))
    svc.drain()
    assert svc.stats["memo_hits"] == 0
    j2 = svc.submit(sweep_req("memo"))
    svc.drain()
    assert svc.stats["memo_hits"] == 1
    assert svc.result(j2, timeout=5) is svc.result(j1, timeout=5)
    assert svc.poll(j2)["cache"] == "memo"
    assert svc.poll(j1)["cache"] is None


def test_cached_repeat_is_measurably_cheaper():
    """The cache economics pin: a memo'd repeat skips population build,
    beta resolution, and scoring entirely -- orders of magnitude faster
    than the cold run that populated it."""
    svc = CodesignService(auto_start=False)
    svc.submit(sweep_req("econ", k=3))
    t0 = time.perf_counter()
    svc.drain()
    cold_s = time.perf_counter() - t0
    svc.submit(sweep_req("econ", k=3))
    t0 = time.perf_counter()
    svc.drain()
    cached_s = time.perf_counter() - t0
    assert cached_s < cold_s  # measurably cheaper (typically >100x)


def test_cached_frontier_equals_cold_frontier():
    """Frontier memo pin: repeat frontier request returns the identical
    result object the cold run produced (byte-identical by identity)."""
    svc = CodesignService(auto_start=False)
    spec = CodesignSpec(budgets=[0.6, 1.2], steps=4, refine_steps=2)
    req = lambda: CodesignRequest(kind="frontier", profiles=suite("fr", 1),
                                  spec=spec)
    j_cold = svc.submit(req())
    svc.drain()
    j_cached = svc.submit(req())
    svc.drain()
    cold = svc.result(j_cold, timeout=5)
    cached = svc.result(j_cached, timeout=5)
    assert cached is cold
    np.testing.assert_array_equal(cached.objective, cold.objective)
    assert svc.stats["memo_hits"] == 1


def test_bilevel_kind_runs_through_the_funnel():
    """kind="bilevel" rides the same spec funnel: resolves the outer
    budget-split descent, memoizes repeats, renders via the uniform
    result protocol, and rejects a spec with no total_budget."""
    svc = CodesignService(auto_start=False)
    spec = CodesignSpec(total_budget=0.8, outer_steps=2, steps=8, lr=0.1)
    req = lambda: CodesignRequest(kind="bilevel", profiles=suite("bi", 1),
                                  spec=spec)
    j1 = svc.submit(req())
    svc.drain()
    res = svc.result(j1, timeout=5)
    assert res.total_budget == 0.8
    assert res.improvement_over_uniform >= 0.0
    assert abs(res.area_budget + res.power_budget - 0.8) < 1e-12
    json.dumps(res.to_json(top_k=1))
    assert "split" in res.markdown()
    assert "split" in render_result(res, "markdown", top_k=1)
    j2 = svc.submit(req())
    svc.drain()
    assert svc.result(j2, timeout=5) is res  # memo hit
    j3 = svc.submit(CodesignRequest(kind="bilevel",
                                    profiles=suite("bi", 1),
                                    spec=CodesignSpec(steps=2)))
    svc.drain()
    with pytest.raises(ValueError, match="total_budget"):
        svc.result(j3, timeout=5)


def test_frontier_warm_start_from_cached_continuation():
    """A NEW schedule over the same suite/seeds resumes from the nearest
    already-solved budget (cheaper: refine_steps instead of steps)."""
    svc = CodesignService(auto_start=False)
    j1 = svc.submit(CodesignRequest(
        kind="frontier", profiles=suite("warm", 1),
        spec=CodesignSpec(budgets=[0.6, 1.2], steps=4, refine_steps=2)))
    svc.drain()
    assert svc.stats["frontier_warm_hits"] == 0
    tight = CodesignSpec(budgets=[0.5], steps=4, refine_steps=2)
    j2 = svc.submit(CodesignRequest(
        kind="frontier", profiles=suite("warm", 1), spec=tight))
    svc.drain()
    assert svc.stats["frontier_warm_hits"] == 1
    assert svc.poll(j2)["cache"] == "warm"
    warm = svc.result(j2, timeout=5)
    assert warm.budgets.tolist() == [0.5]
    assert bool(warm.feasible.all())
    # the warm seed came from solved state: never worse than running the
    # same schedule cold from the seeds (both deterministic)
    cold = frontier_codesign(suite("warm", 1),
                             MachineBatch.from_models(VARIANTS),
                             spec=tight)
    assert float(warm.objective[0]) <= float(cold.objective[0]) + 1e-9

    # opting out (warm=False) runs cold and skips the cache
    j3 = svc.submit(CodesignRequest(kind="frontier",
                                    profiles=suite("warm", 1), spec=tight,
                                    warm=False))
    svc.drain()
    np.testing.assert_array_equal(svc.result(j3, timeout=5).objective,
                                  cold.objective)


def test_artifact_cache_accounting_same_shape_hits():
    svc = CodesignService(auto_start=False)
    svc.submit(sweep_req("art1", k=2))
    svc.drain()
    svc.submit(sweep_req("art2", k=2))     # same (A, V, backend, constraints)
    svc.drain()
    assert svc.stats["artifact_misses"] == 1
    assert svc.stats["artifact_hits"] == 1


# --------------------------------------------------------------------------- #
# Queue semantics: overload / timeout / cancellation / streaming
# --------------------------------------------------------------------------- #


def test_overload_rejects_429_style():
    svc = CodesignService(auto_start=False, max_pending=2)
    svc.submit(sweep_req("o1"))
    svc.submit(sweep_req("o2"))
    with pytest.raises(ServiceOverloadError) as ei:
        svc.submit(sweep_req("o3"))
    assert ei.value.status_code == 429
    assert svc.stats["rejected"] == 1
    svc.drain()                       # queue drains; capacity frees up
    svc.submit(sweep_req("o3"))
    svc.drain()
    assert svc.stats[DONE] == 3


def test_expired_job_times_out_at_dispatch():
    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(kind="sweep", profiles=suite("t"),
                                     spec=SPEC32, timeout=1e-9))
    time.sleep(0.01)
    svc.drain()
    assert svc.poll(jid)["state"] == TIMEOUT
    with pytest.raises(JobTimeout):
        svc.result(jid, timeout=1)


def test_cancel_pending_job():
    svc = CodesignService(auto_start=False)
    jid = svc.submit(sweep_req("c"))
    assert svc.cancel(jid)
    assert svc.poll(jid)["state"] == CANCELLED
    svc.drain()                            # removed from queue: nothing runs
    assert svc.stats[DONE] == 0
    with pytest.raises(JobCancelled):
        svc.result(jid, timeout=1)
    assert not svc.cancel(jid)             # already terminal


def test_cancel_running_mega_sweep_aborts_between_shards():
    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(kind="mega_sweep", profiles=suite("mc"),
                                     spec=CodesignSpec(n=64), num_shards=4))
    # simulate the cancel landing while the job runs: the progress callback
    # observes the flag at the next shard boundary and unwinds gracefully
    svc._jobs[jid].cancel_requested = True
    svc.drain()
    assert svc.poll(jid)["state"] == CANCELLED
    events = list(svc.stream(jid))
    assert events[-1]["event"] == CANCELLED
    assert sum(e["event"] == "shard" for e in events) <= 1


def test_mega_sweep_streams_shard_progress():
    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(kind="mega_sweep", profiles=suite("ms"),
                                     spec=CodesignSpec(n=64, seed=1),
                                     num_shards=4))
    svc.drain()
    events = list(svc.stream(jid))
    shards = [e for e in events if e["event"] == "shard"]
    assert [s["shard"] for s in shards] == [0, 1, 2, 3]
    assert shards[-1]["hi"] == 64
    assert events[-1]["event"] == DONE
    # stream after completion replays and still terminates
    assert list(svc.stream(jid))[-1]["event"] == DONE


def test_threaded_service_end_to_end():
    """Real worker threads: submit from the test thread, block on results.
    Also covers submit-notify wakeup and concurrent result() waiters."""
    svc = CodesignService(workers=2, max_pending=16, auto_start=True)
    try:
        jids = [svc.submit(sweep_req(f"th{i}")) for i in range(4)]
        results = {}

        def wait(jid):
            results[jid] = svc.result(jid, timeout=60)

        waiters = [threading.Thread(target=wait, args=(j,)) for j in jids]
        for t in waiters:
            t.start()
        for t in waiters:
            t.join(timeout=60)
        assert len(results) == 4
        for i, jid in enumerate(jids):
            assert_sweep_equal(results[jid],
                               run_sweep(suite(f"th{i}"), n=32, seed=0))
    finally:
        svc.shutdown()


# --------------------------------------------------------------------------- #
# Uniform result protocol + renderers
# --------------------------------------------------------------------------- #


def test_every_result_type_implements_the_protocol():
    from repro.core import evaluate
    from repro.core.constrained import constrained_codesign

    profiles = random_profiles(2, seed=3)
    results = [
        run_sweep(profiles, n=8, seed=0),
        evaluate(profiles),
        constrained_codesign(profiles, MachineBatch.from_models(VARIANTS),
                             area_budget=1.0, steps=2),
        frontier_codesign(profiles, MachineBatch.from_models(VARIANTS),
                          budgets=[1.0], steps=2, refine_steps=1),
    ]
    for res in results:
        md_all = render_result(res, "markdown")
        md_top = render_result(res, "markdown", top_k=1)
        assert isinstance(md_all, str) and md_all.count("|") > 3
        assert len(md_top) <= len(md_all)
        blob = render_result(res, "json", top_k=1)
        json.dumps(blob)               # plain data, no numpy leakage


def test_render_rejects_non_protocol_results():
    with pytest.raises(TypeError, match="result protocol"):
        render_result(object(), "markdown")
    with pytest.raises(ValueError, match="unknown render format"):
        render_result(run_sweep(random_profiles(1, seed=0), n=4), "yaml")


def test_sharded_result_renders_through_service():
    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(kind="mega_sweep", profiles=suite("r"),
                                     spec=CodesignSpec(n=64), num_shards=2))
    svc.drain()
    md = svc.render(jid, fmt="markdown", top_k=3, timeout=5)
    assert isinstance(md, str) and "|" in md
    json.dumps(svc.render(jid, fmt="json", top_k=3, timeout=5))


def test_request_validates_at_construction():
    with pytest.raises(ValueError, match="unknown request kind"):
        CodesignRequest(kind="bogus", profiles=suite("v"))
    with pytest.raises(ValueError, match="unknown backend"):
        CodesignRequest(kind="sweep", profiles=suite("v"),
                        spec=CodesignSpec(backend="tpu9000"))


def test_constrained_and_joint_through_the_service():
    svc = CodesignService(auto_start=False)
    jc = svc.submit(CodesignRequest(
        kind="constrained", profiles=suite("cc", 1),
        spec=CodesignSpec(area_budget=1.0, steps=3)))
    jj = svc.submit(CodesignRequest(
        kind="joint", profiles=[suite("jj", 2)],
        spec=CodesignSpec(mode="alternate", steps=4)))
    svc.drain()
    cc = svc.result(jc, timeout=5)
    assert bool(cc.feasible.all())
    jr = svc.result(jj, timeout=5)
    assert jr.mode == "joint-alternate"
    assert "| variant |" in svc.render(jc, fmt="markdown")


# --------------------------------------------------------------------------- #
# BatchedEngine regressions (empty prompt + per-slot KV positions)
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def engine_setup():
    import jax

    from repro import configs as C
    from repro.models import transformer as T

    cfg = C.get_config("chatglm3-6b", smoke=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _solo_generate(params, cfg, prompt, new_tokens):
    from repro.serving.engine import BatchedEngine, Request

    eng = BatchedEngine(params, cfg, slots=1, max_len=32)
    req = Request(rid=0, prompt=list(prompt), max_new_tokens=new_tokens)
    eng.submit(req)
    eng.run_to_completion()
    return req.generated


def test_engine_empty_prompt_admission(engine_setup):
    """Regression: _admit crashed with UnboundLocalError on an empty
    prompt; now it pads with token 0 and still generates."""
    from repro.serving.engine import BatchedEngine, Request

    params, cfg = engine_setup
    eng = BatchedEngine(params, cfg, slots=2, max_len=32)
    req = Request(rid=0, prompt=[], max_new_tokens=3)
    eng.submit(req)
    eng.run_to_completion()
    assert len(req.generated) == 3


def test_engine_staggered_admissions_match_solo(engine_setup):
    """Regression: step() decoded every slot at the SHARED max position,
    corrupting KV for staggered admissions.  Each slot now carries its own
    position vector, so mid-flight admission of new requests leaves
    in-flight generations bit-identical to solo runs."""
    from repro.serving.engine import BatchedEngine, Request

    params, cfg = engine_setup
    prompts = [[1, 2, 3], [4, 5], [7, 8, 9, 10]]
    new_tokens = [5, 5, 3]
    solo = [_solo_generate(params, cfg, p, n)
            for p, n in zip(prompts, new_tokens)]

    eng = BatchedEngine(params, cfg, slots=3, max_len=32)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, new_tokens))]
    eng.submit(reqs[0])
    eng.step()                       # r0 in flight before r1/r2 admit
    eng.submit(reqs[1])
    eng.submit(reqs[2])
    eng.run_to_completion()
    for req, expect in zip(reqs, solo):
        assert req.generated == expect


def test_engine_slot_reuse_after_completion(engine_setup):
    """A freed slot's stale KV never leaks into the next request."""
    from repro.serving.engine import BatchedEngine, Request

    params, cfg = engine_setup
    solo = _solo_generate(params, cfg, [11, 12], 4)
    eng = BatchedEngine(params, cfg, slots=1, max_len=32)
    first = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3)
    eng.submit(first)
    eng.run_to_completion()
    second = Request(rid=1, prompt=[11, 12], max_new_tokens=4)
    eng.submit(second)
    eng.run_to_completion()
    assert second.generated == solo


# --------------------------------------------------------------------------- #
# Bounded population cache (LRU) + streamed / resumable mega-sweeps
# --------------------------------------------------------------------------- #


def _pop_size_bytes():
    """Bytes one n=32 cached population costs (measured, not assumed)."""
    probe = CodesignService(auto_start=False)
    probe.submit(sweep_req("probe"))
    probe.drain()
    return probe._pop_bytes


def test_population_cache_evicts_lru_under_byte_budget():
    """The cache is bounded: with room for exactly two populations, a
    third insert evicts the least-recently-used one, the byte ledger
    never exceeds the budget, and results are unaffected."""
    size = _pop_size_bytes()
    assert size > 0
    svc = CodesignService(auto_start=False, pop_cache_bytes=2 * size)
    for seed in (0, 1, 2):   # three same-shape, distinct-seed populations
        svc.submit(CodesignRequest(kind="sweep", profiles=suite(f"s{seed}"),
                                   spec=CodesignSpec(n=32, seed=seed)))
        svc.drain()
    assert svc.stats["pop_evictions"] == 1
    assert len(svc._populations) == 2
    assert svc._pop_bytes <= 2 * size
    # seed=0 was evicted -> regenerating is a miss; seed=2 is still hot
    svc.submit(CodesignRequest(kind="sweep", profiles=suite("again0"),
                               spec=CodesignSpec(n=32, seed=0)))
    svc.drain()
    assert svc.stats["pop_misses"] == 4 and svc.stats["pop_hits"] == 0
    svc.submit(CodesignRequest(kind="sweep", profiles=suite("again2"),
                               spec=CodesignSpec(n=32, seed=2)))
    svc.drain()
    assert svc.stats["pop_hits"] == 1


def test_population_cache_serves_oversized_without_caching():
    svc = CodesignService(auto_start=False, pop_cache_bytes=64)
    jid = svc.submit(sweep_req("big"))
    svc.drain()
    assert svc.stats["pop_uncacheable"] == 1
    assert len(svc._populations) == 0 and svc._pop_bytes == 0
    assert_sweep_equal(svc.result(jid, timeout=5),
                       run_sweep(suite("big"), n=32, seed=0))


def test_streamed_mega_sweep_matches_direct_shard_sweep():
    from repro.core import shard_sweep

    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(kind="mega_sweep",
                                     profiles=suite("str"),
                                     spec=CodesignSpec(n=96, seed=2),
                                     num_shards=4, stream=True))
    svc.drain()
    got = svc.result(jid, timeout=5)
    assert got.streamed
    direct = shard_sweep(suite("str"), n=96, seed=2, num_shards=4,
                         stream=True)
    assert got.markdown(top_k=8) == direct.markdown(top_k=8)
    assert got.best_fit_map == direct.best_fit_map
    np.testing.assert_array_equal(got.result.aggregate,
                                  direct.result.aggregate)
    shards = [e for e in svc.stream(jid) if e["event"] == "shard"]
    assert [s["shard"] for s in shards] == [0, 1, 2, 3]
    assert shards[-1]["hi"] == 96


def test_jax_mega_sweep_per_shard_progress_and_cancel():
    """Regression (the distributed-stats path used to emit ONE
    progress(0, 1, 0, V) event): jax-backed mega-sweeps stream one event
    per shard, so cancellation has real boundaries to land on."""
    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(
        kind="mega_sweep", profiles=suite("jx"),
        spec=CodesignSpec(n=64, backend="jax"), num_shards=4))
    svc.drain()
    shards = [e for e in svc.stream(jid) if e["event"] == "shard"]
    assert [s["shard"] for s in shards] == [0, 1, 2, 3]
    assert all(s["num_shards"] == 4 for s in shards)
    # and a cancelled jax job unwinds at a shard boundary, never hangs
    jid2 = svc.submit(CodesignRequest(
        kind="mega_sweep", profiles=suite("jx2"),
        spec=CodesignSpec(n=64, backend="jax"), num_shards=4))
    svc._jobs[jid2].cancel_requested = True
    svc.drain()
    assert svc.poll(jid2)["state"] == CANCELLED
    assert sum(e["event"] == "shard" for e in svc.stream(jid2)) <= 1


def test_cancelled_checkpointed_mega_sweep_resumes(tmp_path):
    """Cancellation + checkpoint_dir compose: the aborted job's last
    completed shard is on disk, and a resume=True resubmission finishes
    from there with a result identical to an uninterrupted run."""
    from repro.core import shard_sweep

    ck = str(tmp_path / "ck")
    kw = dict(kind="mega_sweep", profiles=suite("rs"),
              spec=CodesignSpec(n=96, seed=4), num_shards=4, stream=True)
    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(checkpoint_dir=ck, **kw))
    svc._jobs[jid].cancel_requested = True   # lands at the first boundary
    svc.drain()
    assert svc.poll(jid)["state"] == CANCELLED

    jid2 = svc.submit(CodesignRequest(checkpoint_dir=ck, resume=True, **kw))
    svc.drain()
    resumed = svc.result(jid2, timeout=5)
    assert resumed.resumed_shards == 1       # shard 0 checkpointed pre-abort
    straight = shard_sweep(suite("rs"), n=96, seed=4, num_shards=4,
                           stream=True)
    assert resumed.markdown(top_k=8) == straight.markdown(top_k=8)
    assert resumed.best_fit_map == straight.best_fit_map
    # only the remaining shards streamed on the resumed job
    shards = [e for e in svc.stream(jid2) if e["event"] == "shard"]
    assert [s["shard"] for s in shards] == [1, 2, 3]
