"""Model zoo behaviour: forward/loss, decode==forward equivalence, chunked
attention, pallas attention, feature flags."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import (
    Family,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

KEY = jax.random.PRNGKey(1)


def dense_cfg(**kw):
    base = dict(name="dense", family=Family.DENSE, n_layers=3, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                remat="none", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


ALL_CFGS = {
    "dense": dense_cfg(),
    "dense-qk-bias-halfrope": dense_cfg(
        name="dq", qk_norm=True, qkv_bias=True, rope_style="half"),
    "moe": ModelConfig(
        name="moe", family=Family.MOE, n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=64, remat="none",
        compute_dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=48,
                      n_shared_experts=2, d_ff_shared=16)),
    "ssm": ModelConfig(
        name="ssm", family=Family.SSM, n_layers=2, d_model=32, n_heads=1,
        n_kv_heads=1, d_ff=0, vocab_size=64, remat="none", rope_style="none",
        compute_dtype="float32", ssm=SSMConfig(state_dim=4)),
    "hybrid": ModelConfig(
        name="hyb", family=Family.HYBRID, n_layers=5, d_model=32, n_heads=4,
        n_kv_heads=1, d_ff=64, vocab_size=64, remat="none", attn_window=6,
        compute_dtype="float32", hybrid=HybridConfig(lru_width=32)),
    "audio": ModelConfig(
        name="aud", family=Family.AUDIO, n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=64, remat="none", rope_style="none",
        norm="layernorm", mlp="gelu", compute_dtype="float32",
        n_encoder_layers=2, encoder_seq_len=8, decoder_pos_len=32),
    "vlm": ModelConfig(
        name="vlm", family=Family.VLM, n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=1, d_ff=64, vocab_size=64, remat="none",
        compute_dtype="float32", n_vision_tokens=4, tie_embeddings=True),
}


def make_batch(cfg, B=2, S=12, key=jax.random.PRNGKey(2)):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == Family.AUDIO:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq_len, cfg.d_model))
    if cfg.family == Family.VLM:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(4), (B, cfg.n_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("name", list(ALL_CFGS))
def test_forward_loss_finite(name):
    cfg = ALL_CFGS[name]
    params, axes = T.init_model(KEY, cfg)
    batch = make_batch(cfg)
    loss, metrics = T.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss)
    assert 0 <= float(metrics["accuracy"]) <= 1
    # axes mirror params
    jax.tree.map(lambda p, a: None, params, axes)


@pytest.mark.slow
@pytest.mark.parametrize("name", list(ALL_CFGS))
def test_decode_matches_forward(name):
    cfg = ALL_CFGS[name]
    params, _ = T.init_model(KEY, cfg)
    B, S = 2, 12
    batch = make_batch(cfg, B, S)
    hidden, _ = T.forward(params, cfg, batch)
    full_logits = L.unembed_apply(params["embed"], cfg, hidden)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : S - 1]
    pre["labels"] = pre["tokens"]
    cache, _ = T.init_cache(cfg, B, S)
    cache, _ = T.prefill(params, cfg, pre, cache)
    _, dec_logits = T.decode_step(
        params, cfg, cache, batch["tokens"][:, S - 1: S], jnp.int32(S - 1))
    want, got = full_logits[:, -1], dec_logits[:, 0]
    err = float(jnp.max(jnp.abs(want - got))
                / (jnp.max(jnp.abs(want)) + 1e-6))
    assert err < 1e-4, f"{name}: decode mismatch {err}"


def test_chunked_attention_equivalence():
    cfg = ALL_CFGS["dense"]
    params, _ = T.init_model(KEY, cfg)
    batch = make_batch(cfg, 2, 16)
    h1, _ = T.forward(params, cfg, batch)
    h2, _ = T.forward(params, cfg.replace(attn_q_chunk=4), batch)
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-5


def test_pallas_attention_equivalence():
    cfg = ALL_CFGS["dense"]
    params, _ = T.init_model(KEY, cfg)
    batch = make_batch(cfg, 2, 32)
    h1, _ = T.forward(params, cfg, batch)
    h2, _ = T.forward(params, cfg.replace(attn_impl="pallas"), batch)
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


def test_logits_chunk_equivalence():
    cfg = ALL_CFGS["dense"]
    params, _ = T.init_model(KEY, cfg)
    batch = make_batch(cfg, 2, 16)
    l1, _ = T.loss_fn(params, cfg, batch)
    l2, _ = T.loss_fn(params, cfg.replace(logits_chunk=4), batch)
    assert float(jnp.abs(l1 - l2)) < 1e-5


def test_grad_flows_everywhere():
    cfg = ALL_CFGS["dense"]
    params, _ = T.init_model(KEY, cfg)
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
    norms = [float(jnp.linalg.norm(g)) for g in jax.tree.leaves(grads)]
    assert all(jnp.isfinite(n) for n in norms)
    assert sum(1 for n in norms if n > 0) > len(norms) * 0.9


def test_sliding_window_masks_history():
    """Token attends to at most `window` positions."""
    cfg = dense_cfg(name="w", n_layers=1, attn_window=4)
    params, _ = T.init_model(KEY, cfg)
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    h1, _ = T.forward(params, cfg, batch)
    # perturbing a token outside every later window must not change outputs
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    h2, _ = T.forward(params, cfg, {"tokens": toks2, "labels": toks2})
    # positions >= 4 can't see position 0
    assert float(jnp.max(jnp.abs(h1[0, 4:] - h2[0, 4:]))) < 1e-5
    # position 0 itself obviously changes
    assert float(jnp.max(jnp.abs(h1[0, 0] - h2[0, 0]))) > 1e-6


def test_moe_dense_vs_gmm_impl():
    import dataclasses
    cfg = ALL_CFGS["moe"]
    params, _ = T.init_model(KEY, cfg)
    batch = make_batch(cfg)
    h1, _ = T.forward(params, cfg, batch)
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="dense"))
    h2, _ = T.forward(params, cfg2, batch)
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-4


def test_vlm_prefix_is_bidirectional():
    """Text token changes must not affect... vision positions are dropped,
    but a LATER vision patch must influence EARLIER text (prefix-LM)."""
    cfg = ALL_CFGS["vlm"]
    params, _ = T.init_model(KEY, cfg)
    batch = make_batch(cfg, 1, 8)
    h1, _ = T.forward(params, cfg, batch)
    patches2 = batch["patches"].at[0, -1].add(1.0)
    b2 = dict(batch, patches=patches2)
    h2, _ = T.forward(params, cfg, b2)
    # all text positions see all vision tokens
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-6


@pytest.mark.slow
def test_param_counts_match_instantiated():
    from repro.configs import SMOKE_REGISTRY
    for name, cfg in SMOKE_REGISTRY.items():
        params, _ = T.init_model(KEY, cfg)
        actual = sum(p.size for p in jax.tree.leaves(params))
        total, _ = cfg.param_counts()
        extra = cfg.decoder_pos_len * cfg.d_model \
            + (cfg.encoder_seq_len * cfg.d_model
               if cfg.family == Family.AUDIO else 0)
        # analytic count covers >= 95% (frontends/pos tables are extra)
        assert abs(actual - total) <= 0.08 * actual + extra + 4 * cfg.d_model, (
            name, actual, total)
