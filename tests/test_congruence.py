"""The paper's core: Eq. 1 congruence scores, idealization, DSE.

Validates the paper's own claims (DESIGN.md §8):
  1. score ~ 1 <=> dominant bottleneck, ~ 0 <=> minimal impact
  2. bottleneck shifts as the dominant subsystem improves (Fig. 2)
  3. aggregate = L2 magnitude; lower = better fit; Table I structure
  4. compile-once/analyze-many: scoring never needs recompilation
"""

import math

import pytest

from conftest import hypothesis_shim

given, settings, st = hypothesis_shim(seed=0xC0FFEE, trials=32)

from repro.core import (
    ALL_SUBSYSTEMS,
    IDEAL_EPS,
    Subsystem,
    TPU_V5E,
    VARIANTS,
    WorkloadProfile,
    congruence_score,
    evaluate,
    profile_congruence,
    step_time,
    subsystem_times,
)


def make_profile(flops=1e12, hbm=1e9, coll=1e9, name="app", **kw):
    return WorkloadProfile(
        name=name, flops=flops, hbm_bytes=hbm, bytes_accessed=hbm,
        collective_bytes={"all-reduce": coll}, num_devices=256,
        model_flops=flops * 0.8 * 256, tokens=1000, **kw,
    )


# --------------------------------------------------------------------------- #
# Eq. 1 properties (hypothesis)
# --------------------------------------------------------------------------- #


@given(
    gamma=st.floats(1e-6, 1e3),
    alpha_frac=st.floats(0.0, 1.0),
    beta_frac=st.floats(0.0, 0.99),
)
@settings(max_examples=200, deadline=None)
def test_eq1_bounds(gamma, alpha_frac, beta_frac):
    """With beta <= alpha <= gamma, Eq. 1 lands in [0, 1]."""
    beta = beta_frac * gamma
    alpha = beta + alpha_frac * (gamma - beta)
    s = congruence_score(alpha, gamma, beta)
    assert -1e-9 <= s <= 1.0 + 1e-9


@given(gamma=st.floats(1e-6, 1e3), beta_frac=st.floats(0.0, 0.99))
@settings(max_examples=100, deadline=None)
def test_eq1_extremes(gamma, beta_frac):
    beta = beta_frac * gamma
    # idealization does nothing -> alpha == gamma -> score 0
    assert congruence_score(gamma, gamma, beta) == pytest.approx(0.0)
    # idealization reaches the target -> alpha == beta -> score 1
    assert congruence_score(beta, gamma, beta) == pytest.approx(1.0)


@given(
    gamma=st.floats(1e-3, 1e3),
    a1=st.floats(0.0, 1.0),
    a2=st.floats(0.0, 1.0),
)
@settings(max_examples=100, deadline=None)
def test_eq1_monotone(gamma, a1, a2):
    """Lower idealized delay => higher congruence score."""
    beta = 0.0
    lo, hi = sorted((a1 * gamma, a2 * gamma))
    assert congruence_score(lo, gamma, beta) >= congruence_score(hi, gamma, beta)


def test_eq1_degenerate():
    assert congruence_score(1.0, 1.0, 1.0) == 0.0


# --------------------------------------------------------------------------- #
# profiling semantics
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize(
    "dominant,profile",
    [
        (Subsystem.COMPUTE, make_profile(flops=1e15, hbm=1e6, coll=1e6)),
        (Subsystem.MEMORY, make_profile(flops=1e9, hbm=1e12, coll=1e6)),
        (Subsystem.INTERCONNECT, make_profile(flops=1e9, hbm=1e6, coll=1e12)),
    ],
)
def test_dominant_subsystem_scores_highest(dominant, profile):
    rep = profile_congruence(profile, TPU_V5E, beta=0.0)
    names = {Subsystem.COMPUTE: "LBCS", Subsystem.MEMORY: "HRCS",
             Subsystem.INTERCONNECT: "ICS"}
    assert rep.dominant == names[dominant]
    assert rep.scores[names[dominant]] > 0.9
    others = [v for k, v in rep.scores.items() if k != names[dominant]]
    assert all(v < 0.1 for v in others)


@given(ratio=st.floats(2.0, 1e4))
@settings(max_examples=50, deadline=None)
def test_score_grows_with_dominance(ratio):
    """More dominant subsystem -> its score approaches 1 (paper claim 1)."""
    base = make_profile(flops=1e9, hbm=1e6, coll=1e6)
    dom = make_profile(flops=1e9 * ratio, hbm=1e6, coll=1e6)
    s_base = profile_congruence(base, TPU_V5E, beta=0.0).scores["LBCS"]
    s_dom = profile_congruence(dom, TPU_V5E, beta=0.0).scores["LBCS"]
    assert s_dom >= s_base - 1e-9


def test_bottleneck_shift():
    """Fig. 2: improving the dominant subsystem migrates the bottleneck."""
    profile = make_profile(flops=1e12, hbm=1e9, coll=1e10)  # ICS-dominated
    rep = profile_congruence(profile, TPU_V5E, beta=0.0)
    assert rep.dominant == "ICS"
    # co-design response: 100x faster interconnect
    better = TPU_V5E.with_scales(interconnect=0.01)
    rep2 = profile_congruence(profile, better, beta=0.0)
    assert rep2.dominant == "LBCS"


def test_idealization_is_near_zero_not_zero():
    m = TPU_V5E.idealized(Subsystem.COMPUTE)
    assert m.scale_for(Subsystem.COMPUTE) == IDEAL_EPS
    assert m.scale_for(Subsystem.MEMORY) == 1.0
    p = make_profile()
    t_full = step_time(p, TPU_V5E)
    t_ideal = step_time(p, m)
    assert 0 < t_ideal < t_full


def test_alpha_never_exceeds_gamma():
    p = make_profile(flops=3e12, hbm=2e10, coll=7e9)
    rep = profile_congruence(p, TPU_V5E)
    for alpha in rep.alphas.values():
        assert alpha <= rep.gamma + 1e-12


def test_extended_decomposition_sums_sensibly():
    p = make_profile()
    p.collective_bytes = {"all-reduce": 5e9, "all-gather": 5e9}
    rep = profile_congruence(p, TPU_V5E, beta=0.0)
    assert "ICS[all-reduce]" in rep.extended
    assert "ICS[all-gather]" in rep.extended
    # equal traffic -> equal sub-scores, each below the total ICS
    assert rep.extended["ICS[all-reduce]"] == pytest.approx(
        rep.extended["ICS[all-gather]"])
    assert rep.extended["ICS[all-reduce]"] <= rep.scores["ICS"] + 1e-9
    assert "LBCS[mxu]" in rep.extended or p.dot_flops == 0


# --------------------------------------------------------------------------- #
# aggregate + DSE (Table I analogue)
# --------------------------------------------------------------------------- #


def test_aggregate_is_l2_magnitude():
    p = make_profile()
    rep = profile_congruence(p, TPU_V5E)
    want = math.sqrt(rep.ics ** 2 + rep.hrcs ** 2 + rep.lbcs ** 2)
    assert rep.aggregate == pytest.approx(want)


def test_dse_table_structure():
    # mixed: densest balances the three terms best (smallest radar area)
    apps = [
        make_profile(name="mixed", flops=1e14, hbm=1e12, coll=5e9),
        make_profile(name="coll-bound", flops=1e9, hbm=1e6, coll=1e12),
    ]
    suites = {"suiteA": ["mixed"], "suiteB": ["coll-bound"]}
    table = evaluate(apps, suites=suites, beta=0.0)
    assert set(table.variants) == {m.name for m in VARIANTS}
    for app in ("mixed", "coll-bound"):
        assert table.best_fit(app) in table.variants
    # the balanced-at-densest app fits best on the densest variant
    assert table.best_fit("mixed") == "densest"
    md = table.markdown()
    assert "best fit" in md and "aggregate" in md
    radar = table.radar_markdown()
    assert "ICS" in radar


def test_dse_needs_no_recompilation():
    """The whole sweep operates on frozen profiles (lightweight claim)."""
    p = make_profile()
    import time
    t0 = time.perf_counter()
    for _ in range(200):
        evaluate([p])
    dt = time.perf_counter() - t0
    # 200 sweeps x 3 variants x 3 subsystems in well under a second each
    assert dt < 10.0


def test_timing_models_ordering():
    p = make_profile(flops=1e12, hbm=1e10, coll=1e10)
    tb = subsystem_times(p, TPU_V5E)
    assert tb.total_overlap <= tb.total_serial
    assert tb.total(("serial")) == tb.total_serial
    with pytest.raises(ValueError):
        tb.total("bogus")
