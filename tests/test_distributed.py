"""Sharding rules + multi-device integration (8 fake devices, subprocess).

The in-process tests exercise pure rule logic (no devices); the subprocess
tests set XLA_FLAGS for 8 host devices and run real sharded compiles,
an end-to-end sharded train step, elastic checkpoint resharding (8 -> 4
device mesh), and a mini dry-run with profile extraction.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# --------------------------------------------------------------------------- #
# rule logic (no devices needed beyond the default one)
# --------------------------------------------------------------------------- #


def test_spec_rules():
    out = run_sub("""
        from repro.distributed import sharding as SH
        from repro.launch import mesh as MESH
        from jax.sharding import PartitionSpec as P
        mesh = MESH.make_mesh((2, 4), ("data", "model"))
        sc = SH.ShardingConfig(variant="tp")
        # mlp dim sharded on model
        s = SH.spec_for_tensor((64, 128), ("embed", "mlp"), mesh, sc)
        assert s == P(None, "model"), s
        # kv_heads=2 not divisible by model=4 -> head_dim fallback
        s = SH.spec_for_tensor((64, 2, 16), ("embed", "kv_heads", "head_dim"),
                               mesh, sc)
        assert s == P(None, None, "model"), s
        # kv_heads divisible -> sharded, head_dim left alone
        s = SH.spec_for_tensor((64, 4, 16), ("embed", "kv_heads", "head_dim"),
                               mesh, sc)
        assert s == P(None, "model", None), s
        # batch axis across data
        s = SH.spec_for_tensor((8, 128), ("batch", None), mesh, sc)
        assert s == P("data", None), s
        # batch not divisible -> replicated
        s = SH.spec_for_tensor((3, 128), ("batch", None), mesh, sc)
        assert s == P(None, None), s
        # fsdp shards the biggest replicated dim over data
        s = SH.spec_for_tensor((64, 128), ("embed", "mlp"), mesh,
                               SH.ShardingConfig(variant="fsdp"),
                               fsdp_this=True)
        assert s == P("data", "model"), s
        print("RULES-OK")
    """)
    assert "RULES-OK" in out


def test_sharded_train_step_runs():
    """End-to-end numerically-executed sharded train step on 8 devices."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding as SH, ctx as CTX
        from repro.launch import mesh as MESH
        from repro.optim import adamw
        from repro.training.step import init_state, make_train_step
        from repro.data.pipeline import DataConfig, SyntheticLM

        mesh = MESH.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("chatglm3-6b", smoke=True).replace(
            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
        oc = adamw.OptimizerConfig(warmup_steps=1, total_steps=10)
        sc = SH.ShardingConfig(variant="zero1")
        state, axes = init_state(jax.random.PRNGKey(0), cfg, oc)
        p_sh = SH.param_specs(state["params"], axes, mesh, sc)
        o_sh = {"m": SH.opt_state_specs(state["opt"]["m"], axes, mesh, sc),
                "v": SH.opt_state_specs(state["opt"]["v"], axes, mesh, sc),
                "step": SH.scalar_spec(mesh)}
        st_sh = {"params": p_sh, "opt": o_sh}
        state = jax.tree.map(jax.device_put, state, st_sh)
        data = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        step = jax.jit(make_train_step(cfg, oc), donate_argnums=0)
        with MESH.use_mesh(mesh), CTX.use_rules(
                SH.activation_rules(mesh, sc, kind="train")):
            state, metrics = step(state, batch)
            l1 = float(metrics["loss"])
            state, metrics = step(state, batch)
            l2 = float(metrics["loss"])
        assert np.isfinite(l1) and np.isfinite(l2)
        assert l2 < l1  # same batch twice -> loss drops
        print("TRAIN-OK", l1, l2)
    """)
    assert "TRAIN-OK" in out


def test_sharded_matches_single_device():
    """Sharded loss == unsharded loss (same params, same batch)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding as SH, ctx as CTX
        from repro.launch import mesh as MESH
        from repro.models import transformer as T
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_config("qwen3-32b", smoke=True).replace(compute_dtype="float32")
        params, axes = T.init_model(jax.random.PRNGKey(0), cfg)
        data = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        base, _ = T.loss_fn(params, cfg, batch)

        mesh = MESH.make_mesh((2, 4), ("data", "model"))
        sc = SH.ShardingConfig(variant="tp")
        p_sh = SH.param_specs(params, axes, mesh, sc)
        params_sh = jax.tree.map(jax.device_put, params, p_sh)
        with MESH.use_mesh(mesh), CTX.use_rules(
                SH.activation_rules(mesh, sc, kind="train")):
            sharded, _ = jax.jit(lambda p, b: T.loss_fn(p, cfg, b))(params_sh, batch)
        assert abs(float(base) - float(sharded)) < 1e-3, (base, sharded)
        print("MATCH-OK", float(base), float(sharded))
    """)
    assert "MATCH-OK" in out


def test_elastic_checkpoint_reshard():
    """Save on an 8-device (2,4) mesh; restore onto a 4-device (2,2) mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import store
        import tempfile, os

        devs = jax.devices()
        mesh8 = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        sh8 = {"w": NamedSharding(mesh8, P("data", "model"))}
        tree = jax.tree.map(jax.device_put, tree, sh8)
        d = tempfile.mkdtemp()
        store.save(d, 5, tree)

        mesh4 = Mesh(np.array(devs[:4]).reshape(2, 2), ("data", "model"))
        sh4 = {"w": NamedSharding(mesh4, P("data", "model"))}
        restored, extra = store.restore(d, tree, shardings=sh4)
        assert extra["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64).reshape(8, 8))
        assert restored["w"].sharding.mesh.devices.size == 4
        print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out


def test_mini_dryrun_profile_extraction():
    """Mini dry-run: multi-pod mesh compile + profile + congruence report."""
    out = run_sub("""
        import jax
        from repro import configs as C
        from repro.configs.shapes import ShapeSpec
        from repro.core import TPU_V5E, profile_congruence, analyze
        from repro.distributed import sharding as SH, ctx as CTX
        from repro.launch import mesh as MESH
        from repro.launch.specs import input_specs
        from repro.core import costs as CO

        mesh = MESH.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = C.get_config("grok-1-314b", smoke=True)
        shape = ShapeSpec("t", 32, 4, "train")
        sc = SH.ShardingConfig(variant="fsdp", multi_pod=True)
        cell = input_specs(cfg, shape, mesh, sc)
        with MESH.use_mesh(mesh), CTX.use_rules(
                SH.activation_rules(mesh, sc, kind="train")):
            compiled = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                               out_shardings=cell.out_shardings,
                               donate_argnums=cell.donate_argnums
                               ).lower(*cell.args).compile()
        prof = CO.profile_from_compiled(
            "mini", compiled, num_devices=8, model_flops=1e9, tokens=128,
            devices_per_pod=4)
        assert prof.flops > 0 and prof.total_collective_bytes > 0
        rep = profile_congruence(prof, TPU_V5E)
        assert set(rep.scores) == {"ICS", "HRCS", "LBCS"}
        rl = analyze(prof, TPU_V5E)
        assert rl.dominant in ("compute", "memory", "interconnect")
        print("DRYRUN-OK", rep.dominant, rl.dominant)
    """)
    assert "DRYRUN-OK" in out
