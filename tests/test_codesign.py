"""Gradient co-design: jax.grad through the shared kernels must strictly
improve the scalarized (congruence, area, power) objective from the named
variant seeds on the synthetic profile suite (the ISSUE acceptance gate)."""

import numpy as np
import pytest

from repro.core import VARIANTS
from repro.core.codesign import (
    CodesignResult,
    OPT_FIELDS,
    grad_codesign,
    scalarized_objective,
)
from repro.core.costmodel import CostModel
from repro.core.sweep import MachineBatch
from test_sweep import random_profiles


def synthetic_suite():
    """The benchmark harness's synthetic trio (compute / memory / collective
    bound) -- the 'synthetic profile suite' the acceptance criterion names."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import common
    return common.synthetic_profiles()


@pytest.fixture(scope="module")
def result():
    return grad_codesign(synthetic_suite(),
                         MachineBatch.from_models(VARIANTS), steps=60)


def test_grad_strictly_improves_named_seeds(result):
    """Every named-variant seed must end with a strictly lower objective."""
    assert list(result.names) == [m.name for m in VARIANTS]
    assert np.all(result.objective_final < result.objective_seed)
    assert np.all(result.improvement > 0)


def test_trajectory_is_monotone_non_increasing(result):
    """Backtracking line search: accepted objective never goes up."""
    diffs = np.diff(result.trajectory, axis=0)
    assert np.all(diffs <= 1e-12)


def test_final_objective_matches_numpy_reference(result):
    """The jax-descended objective must re-evaluate identically (to 1e-6)
    on the NumPy reference kernels -- same math, one kernel layer."""
    models = result.models()
    # freeze beta to the seed convention: derived from the seed baseline
    from repro.core.sweep import default_beta_batched
    beta = default_beta_batched(
        synthetic_suite(), MachineBatch.from_models(VARIANTS))
    ref = scalarized_objective(synthetic_suite(),
                               MachineBatch.from_models(models), beta=beta)
    np.testing.assert_allclose(ref, result.objective_final, rtol=1e-6)


def test_optimized_models_are_well_formed(result):
    models = result.models()
    assert [m.name for m in models] == [f"{v.name}+grad" for v in VARIANTS]
    for m, seed in zip(models, VARIANTS):
        assert m.peak_flops > 0 and m.hbm_bw > 0
        assert m.ici_links == seed.ici_links  # held fixed
        for s, v in m.scale.items():
            assert v == seed.scale.get(s, 1.0)  # scales held fixed too
        # span clip: rates stay within the process envelope (relative
        # slack: exp(log(x)) round-trips to ~1e-13 of the boundary)
        for f in OPT_FIELDS:
            assert getattr(seed, f) / 16.0 * (1 - 1e-9) <= getattr(m, f) \
                <= getattr(seed, f) * 16.0 * (1 + 1e-9)


def test_to_json_serializable(result):
    import json
    blob = result.to_json()
    json.dumps(blob)
    assert blob["best_variant"].endswith("+grad")
    assert len(blob["variants"]) == len(VARIANTS)


def test_objective_gradient_matches_finite_differences():
    """The jax gradient every descent in this repo follows must match
    central finite differences of the NumPy reference objective (shared
    ``conftest.gradcheck`` harness -- the same one that pins the
    implicit budget sensitivities in tests/test_implicit.py)."""
    from conftest import gradcheck

    from repro.core.codesign import (
        _as_batches,
        _objective_terms,
        machine_arrays_from_theta,
        resolve_beta,
        theta_box,
    )
    from repro.core.costmodel import DEFAULT_COST_MODEL
    from repro.core.kernels_xp import IDEAL_EPS, get_backend

    profiles = synthetic_suite()
    pb, mb = _as_batches(profiles, MachineBatch.from_models(VARIANTS))
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, None, 0)
    theta0, _, _ = theta_box(mb, 16.0)
    backend = get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)

        def obj_jax(flat):
            th = jnp.reshape(backend.asarray(flat), theta0.shape)
            m = machine_arrays_from_theta(jnp, th, fixed)
            return jnp.sum(_objective_terms(
                jnp, p_arrays, m, beta_j, "serial", IDEAL_EPS,
                DEFAULT_COST_MODEL, 0.1, 0.05))

        grad = np.asarray(jax.grad(obj_jax)(
            backend.asarray(theta0.ravel())))

    def obj_np(flat):
        th = flat.reshape(theta0.shape)
        m = machine_arrays_from_theta(np, th, fixed_np)
        with np.errstate(divide="ignore", invalid="ignore"):
            return float(np.sum(_objective_terms(
                np, pb.arrays(), m, beta_np, "serial", IDEAL_EPS,
                DEFAULT_COST_MODEL, 0.1, 0.05)))

    worst = gradcheck(obj_np, theta0.ravel(), grad, rtol=1e-4, h=1e-5)
    assert worst <= 1e-4


def test_grad_respects_cost_model_weights():
    """Cranking the area weight must pull the optimized designs smaller."""
    profiles = random_profiles(3, seed=51)
    seeds = MachineBatch.from_models(VARIANTS)
    cheap = grad_codesign(profiles, seeds, steps=40, w_area=0.0,
                          w_power=0.0)
    lean = grad_codesign(profiles, seeds, steps=40, w_area=2.0,
                         w_power=1.0)
    cm = CostModel()
    area_cheap = np.mean([cm.area(m) for m in cheap.models()])
    area_lean = np.mean([cm.area(m) for m in lean.models()])
    assert area_lean < area_cheap


def test_scalarized_objective_shape_and_beta_forms():
    profiles = random_profiles(4, seed=53)
    machines = MachineBatch.from_models(VARIANTS)
    j = scalarized_objective(profiles, machines)
    assert j.shape == (len(VARIANTS),)
    j0 = scalarized_objective(profiles, machines, beta=0.0)
    assert j0.shape == (len(VARIANTS),)
    assert np.all(np.isfinite(j)) and np.all(np.isfinite(j0))


def test_codesign_result_best(result):
    assert isinstance(result, CodesignResult)
    assert result.best == int(np.argmin(result.objective_final))
    assert result.best_model().name == f"{result.names[result.best]}+grad"


def test_grad_codesign_reports_final_silicon(result):
    """The feasibility-report fields are populated even unconstrained:
    final area/power under the run's cost model, no budget, no trace."""
    from repro.core.costmodel import DEFAULT_COST_MODEL

    models = result.models()
    np.testing.assert_allclose(
        result.area_final, [DEFAULT_COST_MODEL.area(m) for m in models],
        rtol=1e-9)
    np.testing.assert_allclose(
        result.power_final, [DEFAULT_COST_MODEL.power(m) for m in models],
        rtol=1e-9)
    assert result.mode == "unconstrained"
    assert result.feasible is None and result.violation_trace is None
    assert result.feasibility_report() == {
        "constrained": False, "mode": "unconstrained"}


# --------------------------------------------------------------------------- #
# Joint (machine, sharding-variant) descent vs machine-only: the ISSUE
# acceptance gate on the 10 default profiles
# --------------------------------------------------------------------------- #


def default_profile_groups():
    """The 10 default profiles (benchmarks.common.scaling_profiles) each
    with three synthetic sharding layouts: member 0 is the default; the
    others trade collective traffic against memory traffic the way
    tp/zero1/fsdp layouts do."""
    import dataclasses as _dc
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import common
    groups = []
    for p in common.scaling_profiles(10):
        group = [p]
        for k, (coll_f, mem_f) in enumerate(((0.4, 1.25), (2.2, 0.8)), 1):
            q = _dc.replace(
                p, name=f"{p.name}/v{k}",
                hbm_bytes=p.hbm_bytes * mem_f,
                bytes_accessed=p.bytes_accessed * mem_f,
                collective_bytes={"all-reduce":
                                  p.total_collective_bytes * coll_f},
            )
            group.append(q)
        groups.append(group)
    return groups


def test_joint_beats_machine_only_on_default_profiles():
    """Joint (machine, sharding-variant) descent must match or beat
    machine-only descent on the per-profile scalarized objective for at
    least 8 of the 10 default profiles (ISSUE 4 acceptance criterion).

    Machine-only descends with every app pinned to its default sharding
    (member 0); joint may re-select per (app, machine variant).  Both are
    scored at their own best final machine: per-profile objective =
    aggregate congruence of the (chosen) member + the shared silicon
    terms.
    """
    from repro.core.constrained import joint_codesign
    from repro.core.costmodel import DEFAULT_COST_MODEL
    from repro.core.sweep import batched_congruence, default_beta_batched

    groups = default_profile_groups()
    seeds = MachineBatch.from_models(VARIANTS)
    defaults = [g[0] for g in groups]
    beta = default_beta_batched(defaults, seeds)

    machine_only = grad_codesign(defaults, seeds, steps=40, beta=beta)
    joint = joint_codesign(groups, seeds, rounds=3, steps=40, beta=beta)

    def per_profile_objective(model, chosen):
        res = batched_congruence(chosen, MachineBatch.from_models([model]),
                                 beta=beta, clamp=False)
        cm = DEFAULT_COST_MODEL
        silicon = 0.1 * cm.area(model) + 0.05 * cm.power(model)
        return res.aggregate[:, 0] + silicon

    mo_best = machine_only.best_model()
    j_best = joint.best_model()
    picks = joint.selection_names[joint.best]
    by_name = {p.name: p for g in groups for p in g}
    j_chosen = [by_name[n] for n in picks]

    mo_obj = per_profile_objective(mo_best, defaults)
    j_obj = per_profile_objective(j_best, j_chosen)
    wins = int(np.sum(j_obj <= mo_obj + 1e-9))
    assert wins >= 8, (
        f"joint beat machine-only on only {wins}/10 profiles "
        f"(joint={j_obj}, machine_only={mo_obj})")
    # The totals must agree with what each run reported for its best seed.
    np.testing.assert_allclose(np.mean(j_obj), joint.objective_final[
        joint.best], rtol=1e-6)
    np.testing.assert_allclose(np.mean(mo_obj), machine_only.objective_final[
        machine_only.best], rtol=1e-6)
