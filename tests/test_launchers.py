"""Launcher entry points run end-to-end on CPU (smoke scale)."""

import shutil

from repro.launch import serve as serve_launch
from repro.launch import train as train_launch


def test_train_launcher(tmp_path):
    shutil.rmtree("/tmp/repro_launch_train_test", ignore_errors=True)
    rc = train_launch.main([
        "--arch", "chatglm3-6b", "--smoke", "--steps", "6",
        "--seq-len", "32", "--batch", "2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
    ])
    assert rc == 0
    from repro.checkpoint import store
    assert store.latest_step(str(tmp_path)) == 6


def test_serve_launcher():
    rc = serve_launch.main([
        "--arch", "falcon-mamba-7b", "--smoke", "--requests", "2",
        "--slots", "2", "--new-tokens", "3", "--max-len", "32",
    ])
    assert rc == 0


def test_serve_codesign_launcher(capsys):
    from repro.launch import serve_codesign

    rc = serve_codesign.main(["--smoke", "--suites", "2", "--apps", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "mega-sweep shard" in out and "frontier+warm" in out

    # bad flags die at parse time through the one validation path
    import pytest
    with pytest.raises(SystemExit):
        serve_codesign.main(["--smoke", "--backend", "cuda"])
    with pytest.raises(SystemExit):
        serve_codesign.main(["--smoke", "--budgets", "-1"])
