"""Executable documentation: README.md and docs/*.md cannot rot.

Every fenced ```python block in each documentation file is executed, top
to bottom, in one namespace per file (so later blocks may build on
earlier ones).  Shell/text fences are ignored -- anything marked
```python is a promise that it runs.

Docstring examples on the public API (run_sweep, shard_sweep, evaluate,
ParamSpace, CostModel, grad_codesign) are covered separately by the
``pytest --doctest-modules`` leg in CI (.github/workflows/ci.yml).
"""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    [ROOT / "README.md", *(ROOT / "docs").glob("*.md")],
    key=lambda p: p.name)

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks(path: pathlib.Path):
    return _FENCE.findall(path.read_text())


def test_docs_tree_exists():
    names = {p.name for p in DOC_FILES}
    assert {"README.md", "architecture.md", "backends.md"} <= names


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_code_blocks_execute(path):
    blocks = _blocks(path)
    assert blocks, f"{path.name} has no executable ```python blocks"
    ns = {"__name__": f"docsmoke_{path.stem}"}
    for i, block in enumerate(blocks):
        code = compile(block, f"{path.name}[python block {i}]", "exec")
        exec(code, ns)  # assertions inside the docs are the test
