"""Multi-tenant packing (core/packing.py).

The acceptance pin: on a generated stress population (>= 32 apps) packed
across 4 machine instances under one fleet-total area budget,
``pack_codesign`` must beat the uniform baseline -- the best single
machine ``constrained_codesign`` finds at budget/M, replicated M times --
on the exact fleet objective (``fleet_objective``), while every returned
machine stays envelope-feasible and the fleet total stays inside the
budget to 1e-9 relative.

Plus the structural properties: alternation's trajectory is monotone
non-increasing, softmax never regresses past the seed (incumbent
guarantee), the fleet frontier J*(total budget) is monotone, the
reported objective IS the yardstick objective, and ``PackingResult``
speaks the uniform markdown/to_json serving protocol.
"""

import numpy as np
import pytest

from conftest import hypothesis_shim

# Few fallback trials -- each trial here is a full jax packing descent.
given, settings, st = hypothesis_shim(seed=0x9ACC, trials=4)

from repro.core import VARIANTS
from repro.core.constrained import (
    FEASIBLE_RTOL,
    budget_feasible,
    constrained_codesign,
)
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.model_zoo import resolve_suite
from repro.core.packing import (
    PACK_MODES,
    PackingResult,
    _pack_weights,
    _soft_weights,
    fleet_objective,
    pack_codesign,
)
from repro.core.spec import CodesignSpec
from repro.core.sweep import MachineBatch

BETA = 1.5  # one explicit target for both fleets: beta derivation must
            # not differ between the strategies being compared


def small_pack(**kw):
    kw.setdefault("num_machines", 2)
    kw.setdefault("rounds", 2)
    kw.setdefault("steps", 6)
    kw.setdefault("beta", BETA)
    return pack_codesign("gen:6", VARIANTS, **kw)


# --------------------------------------------------------------------------- #
# acceptance: packed fleet beats the uniform fleet under the same budget
# --------------------------------------------------------------------------- #


def test_pack_beats_uniform_fleet_acceptance():
    """ISSUE acceptance: >= 32 generated apps x 4 machines, one total
    area budget.  Packing must beat M replicas of the best single
    constrained machine on the exact fleet objective, and every returned
    machine must be feasible to 1e-9."""
    apps = resolve_suite("gen:32")
    seeds = MachineBatch.from_models(VARIANTS)
    m, budget = 4, 2.0

    uni = constrained_codesign(apps, seeds, steps=30, beta=BETA,
                               area_budget=budget / m)
    uniform_fleet = MachineBatch.from_models([uni.best_model()] * m)
    j_uniform = fleet_objective(apps, uniform_fleet, beta=BETA)

    res = pack_codesign(apps, seeds, num_machines=m, steps=30, beta=BETA,
                        area_budget=budget)
    j_pack = fleet_objective(apps, res.machines, beta=BETA)

    assert j_pack < j_uniform, (j_pack, j_uniform)
    # the fleet total respects the budget to 1e-9 relative
    assert res.area_total <= budget * (1.0 + FEASIBLE_RTOL)
    assert res.feasible is True
    # the reported objective IS the yardstick objective
    assert res.objective_final == pytest.approx(j_pack, rel=1e-9)
    assert len(res.assignment) == 32 and len(res.machines) == m


def test_objective_final_matches_fleet_objective_unconstrained():
    res = small_pack()
    j = fleet_objective(resolve_suite("gen:6"), res.machines, beta=BETA)
    assert res.objective_final == pytest.approx(j, rel=1e-9)
    assert res.feasible is None  # unconstrained: no feasibility claim
    assert res.improvement >= -1e-12


# --------------------------------------------------------------------------- #
# structural properties of the descent
# --------------------------------------------------------------------------- #


def test_alternate_trajectory_monotone_nonincreasing():
    res = small_pack(mode="alternate", steps=12, rounds=3)
    diffs = np.diff(res.trajectory)
    assert (diffs <= 1e-9).all(), res.trajectory
    assert res.trajectory[0] == pytest.approx(res.objective_seed)
    assert res.trajectory[-1] == pytest.approx(res.objective_final)


def test_softmax_never_regresses_past_seed():
    res = small_pack(mode="softmax", steps=12, rounds=3)
    assert res.objective_final <= res.objective_seed + 1e-9
    assert res.mode == "softmax"


def test_assignment_is_argmin_of_final_fleet():
    res = small_pack()
    from repro.core.codesign import _as_batches, resolve_beta
    from repro.core import kernels_xp as K

    pb, _ = _as_batches(resolve_suite("gen:6"), res.machines)
    beta = resolve_beta(pb, MachineBatch.from_models(VARIANTS), BETA, 0)
    out = K.congruence_kernel(np, pb.arrays(), res.machines.arrays(), beta,
                              "serial", K.IDEAL_EPS, clamp=False)
    agg = np.asarray(out.aggregate)
    np.testing.assert_array_equal(res.assignment, np.argmin(agg, axis=1))
    np.testing.assert_allclose(
        res.per_app_aggregate, agg[np.arange(6), res.assignment], rtol=1e-12)
    # apps_on partitions the app list
    names = sum((res.apps_on(i) for i in range(len(res.machines))), [])
    assert sorted(names) == sorted(res.app_names)


def test_pack_weights_shapes():
    agg = np.array([[0.3, 0.1], [0.2, 0.5], [0.4, 0.45]])
    w = _pack_weights(agg)
    assert w.sum() == pytest.approx(1.0)
    np.testing.assert_array_equal(np.nonzero(w)[1], [1, 0, 0])
    ws = _soft_weights(agg, temp=0.5)
    np.testing.assert_allclose(ws.sum(axis=1), 1.0 / 3.0, rtol=1e-12)
    # hardening limit: temp -> 0 recovers the one-hot weights (no ties)
    np.testing.assert_allclose(_soft_weights(agg, 1e-12), w, atol=1e-12)


# --------------------------------------------------------------------------- #
# envelopes: no returned machine may violate its per-subsystem caps
# --------------------------------------------------------------------------- #


def test_every_machine_envelope_feasible():
    env = {"peak_flops": 1.2, "hbm_bw": 0.9}
    res = small_pack(area_envelope=env, area_budget=1.8)
    feas = budget_feasible(np, res.machines.arrays(), DEFAULT_COST_MODEL,
                           None, None, rtol=FEASIBLE_RTOL, area_envelope=env)
    assert np.asarray(feas).all()  # every instance, not just assigned ones
    assert res.feasible is True
    assert res.area_total <= 1.8 * (1.0 + FEASIBLE_RTOL)
    # apps only ever land on machines that exist and are feasible
    assert set(int(i) for i in res.assignment) <= set(range(2))


@given(budget=st.floats(0.8, 4.0))
@settings(max_examples=4, deadline=None)
def test_random_total_budget_met(budget):
    res = small_pack(area_budget=float(budget))
    assert res.area_total <= float(budget) * (1.0 + FEASIBLE_RTOL)
    assert res.feasible is True
    assert res.objective_final <= res.objective_seed + 1e-9


# --------------------------------------------------------------------------- #
# the fleet frontier J*(total budget)
# --------------------------------------------------------------------------- #


def test_fleet_frontier_monotone():
    res = small_pack(budgets=[0.9, 1.4, 2.4])
    np.testing.assert_allclose(res.budgets, [0.9, 1.4, 2.4])
    # J* never increases as the total budget loosens
    assert (np.diff(res.frontier_objective) <= 1e-9).all()
    # feasible points respect their budgets
    for j, b in enumerate(res.budgets):
        if res.frontier_feasible[j]:
            assert res.frontier_area[j] <= b * (1.0 + FEASIBLE_RTOL)
    # main fields describe the tightest budget's fleet
    assert res.area_budget == pytest.approx(0.9)
    assert res.objective_final == pytest.approx(
        float(res.frontier_objective[0]))


def test_budgets_and_area_budget_are_exclusive():
    with pytest.raises(ValueError, match="not both"):
        small_pack(budgets=[1.0, 2.0], area_budget=1.5)


# --------------------------------------------------------------------------- #
# validation + spec plumbing
# --------------------------------------------------------------------------- #


def test_pack_validates_arguments():
    with pytest.raises(ValueError, match="unknown packing mode"):
        small_pack(mode="bogus")
    assert "bogus" not in PACK_MODES
    with pytest.raises(ValueError, match="num_machines"):
        small_pack(num_machines=0)
    with pytest.raises(ValueError, match="positive"):
        small_pack(area_budget=-1.0)
    with pytest.raises(ValueError, match="seed machine"):
        pack_codesign("gen:4", MachineBatch.from_models([]), num_machines=2)


def test_spec_drives_pack_and_explicit_wins():
    spec = CodesignSpec(num_machines=3, steps=4, mode="alternate",
                        beta=BETA).validate()
    res = pack_codesign("gen:6", VARIANTS, rounds=2, spec=spec)
    assert len(res.machines) == 3 and res.steps == 4
    # an explicitly-passed keyword beats the spec field
    res2 = pack_codesign("gen:6", VARIANTS, rounds=2, num_machines=2,
                         spec=spec)
    assert len(res2.machines) == 2
    # fleet instance names cycle the seeds and carry the instance index
    assert res2.machine_names[0].startswith("pack0-")
    assert res2.machine_names[1].startswith("pack1-")


# --------------------------------------------------------------------------- #
# result protocol: markdown / to_json / serving front door
# --------------------------------------------------------------------------- #


def test_packing_result_protocol():
    res = small_pack(area_budget=1.8, budgets=None)
    md = res.markdown(top_k=3)
    assert "packing: 6 apps across 2 machines" in md
    assert "| machine |" in md and "feasible=True" in md
    blob = res.to_json(top_k=3)
    assert blob["num_apps"] == 6 and blob["num_machines"] == 2
    assert set(blob["assignment"]) == set(res.app_names)
    assert set(blob["assignment"].values()) <= set(res.machine_names)
    assert blob["feasible"] is True
    assert len(blob["trajectory"]) == len(res.trajectory)
    import json
    json.dumps(blob)  # strictly JSON-serializable


def test_pack_serves_through_front_door():
    from repro.serving.codesign_service import (
        CodesignRequest,
        CodesignService,
        render_result,
    )

    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(
        kind="pack", profiles="gen:6",
        spec=CodesignSpec(steps=4, num_machines=2, beta=BETA)))
    svc.drain()
    got = svc.result(jid)
    assert isinstance(got, PackingResult)
    want = small_pack(steps=4, rounds=4)  # service uses pack defaults
    assert got.to_json(top_k=4) == want.to_json(top_k=4)
    assert "packing: 6 apps" in render_result(got, top_k=4)
