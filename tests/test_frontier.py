"""Feasibility-frontier subsystem: the ISSUE acceptance gates.

The load-bearing properties:
  * random budget schedules => J*(budget) is monotone non-increasing over
    the feasible points and every feasible frontier point satisfies its
    area budget to 1e-9 (hypothesis-driven end-to-end);
  * warm-started continuation and cold restarts trace the same monotone,
    feasible frontier shape;
  * a single-key area envelope budgets exactly what a scalar area budget
    under the single-key CostModel restriction budgets (projection-level
    AND end-to-end);
  * the sweep -> frontier bridge and the hillclimb --budget-sweep /
    --area-envelope parse-time validation.
"""

import numpy as np
import pytest

from conftest import hypothesis_shim

# Few fallback trials -- each trial here is a full jax descent.
given, settings, st = hypothesis_shim(seed=0xF407, trials=6)

from repro.core import VARIANTS, frontier_codesign
from repro.core.codesign import theta_box
from repro.core.constrained import (
    FEASIBLE_RTOL,
    constrained_codesign,
    project_to_budgets,
)
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.frontier import FrontierResult, _validate_budget_schedule
from repro.core.sweep import MachineBatch, run_sweep
from test_sweep import random_profiles

SEEDS = MachineBatch.from_models(VARIANTS)
FIXED = SEEDS.arrays()
THETA0, LO, HI = theta_box(SEEDS, span=16.0)

#: Tiny descent configs: the properties under test are structural
#: (monotonicity, feasibility), not convergence quality.
FAST = dict(steps=3, refine_steps=1)


@pytest.fixture(scope="module")
def suite():
    return random_profiles(2, seed=61)


def _assert_frontier_contract(fr):
    """The ISSUE acceptance gate, shared by every end-to-end test."""
    feas = fr.feasible
    # Feasible points satisfy their budgets to 1e-9 ...
    assert np.all(fr.area[feas] <= fr.budgets[feas] * (1.0 + FEASIBLE_RTOL))
    # ... and J* is monotone non-increasing in the budget across them.
    assert np.all(np.diff(fr.objective[feas]) <= 1e-12)
    # Budgets are reported ascending and deduplicated.
    assert np.all(np.diff(fr.budgets) > 0)


# --------------------------------------------------------------------------- #
# The frontier property (hypothesis: random schedules => monotone + feasible)
# --------------------------------------------------------------------------- #


@settings(max_examples=6, deadline=None)
@given(lo=st.floats(0.05, 0.5), span=st.floats(0.5, 3.0))
def test_frontier_monotone_and_feasible_for_random_schedules(lo, span, _s={}):
    """For ANY budget schedule (attainable or not), every feasible
    frontier point is area-feasible to 1e-9 and J* never increases with
    the budget -- the tentpole's acceptance gate."""
    if "suite" not in _s:
        _s["suite"] = random_profiles(2, seed=61)
    budgets = [lo, lo + 0.5 * span, lo + span]
    fr = frontier_codesign(_s["suite"], SEEDS, budgets, **FAST)
    _assert_frontier_contract(fr)
    assert fr.per_seed_objective.shape == (3, len(SEEDS))


def test_frontier_named_seeds_monotone_feasible_and_warm_matches_cold(suite):
    """On the named seeds: both continuation and cold restarts honour the
    contract, and an unattainable tightest budget is flagged rather than
    silently reported feasible."""
    budgets = [0.03, 0.2, 0.6, 1.5]          # 0.03 < the span-box floor
    warm = frontier_codesign(suite, SEEDS, budgets, steps=6, refine_steps=2)
    cold = frontier_codesign(suite, SEEDS, budgets, steps=6, refine_steps=2,
                             warm_start=False)
    for fr in (warm, cold):
        _assert_frontier_contract(fr)
        assert not fr.feasible[0]            # floor area > 0.03, flagged
        assert np.all(fr.feasible[1:])
    assert warm.warm_start and not cold.warm_start
    # Same seeds, same schedule: the two traces agree on which budgets are
    # attainable and on the frontier's weak ordering.
    np.testing.assert_array_equal(warm.feasible, cold.feasible)


def test_frontier_respects_fixed_power_budget_and_envelope(suite):
    """power_budget and area_envelope are held FIXED across the sweep;
    every feasible point satisfies them on top of its area budget."""
    env = {"hbm_bw": 0.5}
    fr = frontier_codesign(suite, SEEDS, [0.3, 0.8], power_budget=1.0,
                           area_envelope=env, **FAST)
    _assert_frontier_contract(fr)
    for i in np.nonzero(fr.feasible)[0]:
        m = fr.best_model(int(i))
        assert DEFAULT_COST_MODEL.power(m) <= 1.0 * (1.0 + FEASIBLE_RTOL)
        assert (DEFAULT_COST_MODEL.subsystem_area(m, "hbm_bw")
                <= 0.5 * (1.0 + FEASIBLE_RTOL))
    assert fr.area_envelope == env and fr.power_budget == 1.0
    assert "area_envelope" in fr.to_json()


def test_frontier_validates_inputs(suite):
    with pytest.raises(ValueError, match="at least one budget"):
        frontier_codesign(suite, SEEDS, [], **FAST)
    with pytest.raises(ValueError, match="must be positive"):
        frontier_codesign(suite, SEEDS, [1.0, -0.5], **FAST)
    with pytest.raises(ValueError, match="iterable of numbers"):
        _validate_budget_schedule(0.5)
    with pytest.raises(ValueError, match="power_budget must be positive"):
        frontier_codesign(suite, SEEDS, [1.0], power_budget=0.0, **FAST)
    with pytest.raises(ValueError, match="unknown area_envelope field"):
        frontier_codesign(suite, SEEDS, [1.0], area_envelope={"lutram": 1},
                          **FAST)


def test_budget_schedule_normalization():
    assert _validate_budget_schedule([2.0, 0.5, 2.0, 1.0]) == [0.5, 1.0, 2.0]


# --------------------------------------------------------------------------- #
# FrontierResult accessors (best_at / knee / reports)
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def traced(suite):
    return frontier_codesign(suite, SEEDS, [0.25, 0.5, 1.0, 2.0],
                             steps=6, refine_steps=2)


def test_best_at_returns_affordable_machine(traced):
    m = traced.best_at(0.7)
    # best_at picks the largest traced budget <= 0.7; nested feasible sets
    # make that machine affordable at 0.7 too.
    assert DEFAULT_COST_MODEL.area(m) <= 0.7 * (1.0 + FEASIBLE_RTOL)
    assert "+frontier@" in m.name
    with pytest.raises(ValueError, match="no feasible frontier point"):
        traced.best_at(1e-6)


def test_knee_is_a_traced_feasible_budget(traced):
    knee = traced.knee()
    feas_budgets = traced.budgets[traced.feasible]
    assert knee in feas_budgets.tolist()


def test_reports_render(traced):
    md = traced.markdown()
    assert "| area budget |" in md and "J*" in md
    blob = traced.to_json()
    assert len(blob["points"]) == len(traced)
    assert blob["budgets"] == sorted(blob["budgets"])
    # Every point's machine params round-trip into MachineModel.
    for i in range(len(traced)):
        assert traced.best_model(i).peak_flops > 0


def test_knee_flat_frontier_returns_tightest_feasible():
    """A flat frontier means extra budget buys nothing: the knee is the
    tightest feasible budget (the 'how much fabric do I need' answer)."""
    r = FrontierResult(
        budgets=np.array([0.5, 1.0, 2.0]),
        objective=np.array([1.0, 1.0, 1.0]),
        best_names=["a"] * 3, best_params=[{}] * 3,
        area=np.array([0.4, 0.4, 0.4]), power=np.array([0.5] * 3),
        feasible=np.array([True] * 3),
        per_seed_objective=np.ones((3, 1)), seed_names=["a"],
        steps=1, refine_steps=1, warm_start=True)
    assert r.knee() == 0.5


# --------------------------------------------------------------------------- #
# Envelope-vs-scalar-budget consistency (the single-key pin)
# --------------------------------------------------------------------------- #


def test_single_key_envelope_matches_scalar_budget_projection():
    """Projection level: a one-entry envelope on a field is the SAME
    constraint set as a scalar area budget under the single-key CostModel
    restriction, and the Euclidean operator maps both to the same point
    (the shift operator would rescale every rate for the scalar form --
    exactly the asymmetry the true projection removes)."""
    rng = np.random.default_rng(3)
    theta = THETA0 + rng.uniform(-4, 4, size=THETA0.shape)
    for field, b in (("peak_flops", 0.9), ("hbm_bw", 1.4),
                     ("ici_bw_total", 0.6)):
        single = CostModel(area_weights={field: 1.0})
        p_scalar, f_scalar = project_to_budgets(
            np, theta, LO, HI, FIXED, single, b, method="euclidean")
        p_env, f_env = project_to_budgets(
            np, theta, LO, HI, FIXED, DEFAULT_COST_MODEL, None,
            area_envelope={field: b}, method="euclidean")
        np.testing.assert_allclose(p_scalar, p_env, atol=1e-6)
        np.testing.assert_array_equal(f_scalar, f_env)


def test_single_key_envelope_matches_scalar_budget_end_to_end(suite):
    """End-to-end: with the SAME single-key cost model (so the scalarized
    objectives coincide), descending under the envelope form and under
    the scalar form lands on the same machines."""
    single = CostModel(area_weights={"hbm_bw": 1.0})
    kw = dict(steps=6, projection="euclidean", cost_model=single)
    scalar = constrained_codesign(suite, SEEDS, area_budget=0.8, **kw)
    env = constrained_codesign(suite, SEEDS,
                               area_envelope={"hbm_bw": 0.8}, **kw)
    np.testing.assert_allclose(scalar.objective_final, env.objective_final,
                               rtol=1e-5)
    for ps, pe in zip(scalar.final_params, env.final_params):
        for key in ps:
            np.testing.assert_allclose(ps[key], pe[key], rtol=1e-4)
    assert np.all(scalar.feasible) and np.all(env.feasible)


# --------------------------------------------------------------------------- #
# Sweep -> frontier bridge
# --------------------------------------------------------------------------- #


def test_sweep_frontier_bridge(suite):
    """run_sweep(...).frontier(...) warm-starts the continuation from the
    sweep's seed_codesign survivors over the same profile suite."""
    res = run_sweep(suite, n=64, seed=9, include_named=VARIANTS)
    fr = res.frontier([0.4, 1.0], k=3, **FAST)
    _assert_frontier_contract(fr)
    assert set(fr.seed_names) == set(res.seed_codesign(k=3).names)


# --------------------------------------------------------------------------- #
# CLI parse-time validation (hillclimb --budget-sweep / --area-envelope)
# --------------------------------------------------------------------------- #


def test_hillclimb_validates_frontier_args_at_parse_time():
    import argparse

    from repro.launch.hillclimb import (
        parse_area_envelope,
        parse_budget_sweep,
        validate_codesign_args,
    )

    class Boom(Exception):
        pass

    class P(argparse.ArgumentParser):
        def error(self, message):
            raise Boom(message)

    p = P()
    assert parse_budget_sweep(p, None) is None
    assert parse_budget_sweep(p, "0.5:1.5:3") == [0.5, 1.0, 1.5]
    for bad in ("nope", "1:2", "0:1:4", "2:1:4", "0.5:1.5:1", "a:b:3"):
        with pytest.raises(Boom):
            parse_budget_sweep(p, bad)
    assert parse_area_envelope(p, None) is None
    assert parse_area_envelope(p, "peak_flops=1.5, hbm_bw=0.8") == \
        {"peak_flops": 1.5, "hbm_bw": 0.8}
    for bad in ("peak_flops", "peak_flops=x", "sram=1.0", "hbm_bw=0"):
        with pytest.raises(Boom):
            parse_area_envelope(p, bad)

    def args_of(**kw):
        base = dict(grad=0, area_budget=None, power_budget=None,
                    constraint_mode=None, opt_links=False, joint=False,
                    budget_sweep=None, area_envelope=None)
        base.update(kw)
        return argparse.Namespace(**base)

    validate_codesign_args(p, args_of(grad=5, budget_sweep="0.5:1.5:3"))
    validate_codesign_args(p, args_of(grad=5, area_envelope="hbm_bw=0.8"))
    with pytest.raises(Boom, match="require --grad"):
        validate_codesign_args(p, args_of(budget_sweep="0.5:1.5:3"))
    with pytest.raises(Boom, match="require --grad"):
        validate_codesign_args(p, args_of(area_envelope="hbm_bw=0.8"))
    with pytest.raises(Boom, match="IS the area-budget axis"):
        validate_codesign_args(p, args_of(grad=5, budget_sweep="0.5:1.5:3",
                                          area_budget=1.0))
    with pytest.raises(Boom, match="projected continuation"):
        validate_codesign_args(p, args_of(grad=5, budget_sweep="0.5:1.5:3",
                                          opt_links=True))
    with pytest.raises(Boom, match="projected continuation"):
        validate_codesign_args(p, args_of(grad=5, budget_sweep="0.5:1.5:3",
                                          constraint_mode="lagrangian"))
    with pytest.raises(Boom, match="does not support --area-envelope"):
        validate_codesign_args(p, args_of(grad=5, joint=True,
                                          area_envelope="hbm_bw=0.8"))
