"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 architectures instantiates its REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, REGISTRY, SHAPES, applicable, get_config
from repro.models import transformer as T
from repro.models.config import Family
from repro.optim import adamw
from repro.training.step import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16):
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == Family.AUDIO:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.float32)
    if cfg.family == Family.VLM:
        batch["patches"] = jnp.ones((B, cfg.n_vision_tokens, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = T.init_model(KEY, cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    hidden, aux = T.forward(params, cfg, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    oc = adamw.OptimizerConfig(warmup_steps=1, total_steps=10)
    state, _ = init_state(KEY, cfg, oc)
    step = make_train_step(cfg, oc)
    batch = make_batch(cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = T.init_model(KEY, cfg)
    B, S = 2, 8
    cache, _ = T.init_cache(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    cache, logits = T.decode_step(params, cfg, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_full_configs_match_assignment():
    """Exact published numbers from the assignment block."""
    c = REGISTRY["chatglm3-6b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 4096, 32, 2, 13696, 65024)
    c = REGISTRY["qwen3-32b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (64, 5120, 64, 8, 25600, 151936)
    assert c.qk_norm
    c = REGISTRY["qwen1.5-4b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 2560, 20, 20, 6912, 151936)
    assert c.qkv_bias
    c = REGISTRY["deepseek-67b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = REGISTRY["whisper-medium"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        24, 1024, 16, 4096, 51865)
    c = REGISTRY["recurrentgemma-9b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (38, 4096, 16, 1, 12288, 256000)
    assert c.hybrid.pattern == ("rec", "rec", "att")
    c = REGISTRY["grok-1-314b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (64, 6144, 48, 8, 131072)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (8, 2, 32768)
    c = REGISTRY["qwen2-moe-a2.7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (
        24, 2048, 16, 151936)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared_experts) == (60, 4, 4)
    c = REGISTRY["paligemma-3b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (18, 2048, 8, 1, 16384, 257216)
    assert c.n_vision_tokens == 256
    c = REGISTRY["falcon-mamba-7b"]
    assert (c.n_layers, c.d_model, c.vocab_size) == (64, 4096, 65024)
    assert c.ssm.state_dim == 16


def test_cell_applicability():
    """long_500k runs exactly for the sub-quadratic archs."""
    long = SHAPES["long_500k"]
    runnable = {a for a in ARCH_IDS if applicable(REGISTRY[a], long)[0]}
    assert runnable == {"falcon-mamba-7b", "recurrentgemma-9b"}
    for a in ARCH_IDS - runnable if isinstance(ARCH_IDS, set) else \
            set(ARCH_IDS) - runnable:
        ok, reason = applicable(REGISTRY[a], long)
        assert not ok and "full-attention" in reason
