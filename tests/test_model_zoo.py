"""Model-zoo measurement path: golden profiles, calibration, invariants.

Three layers of protection for the zoo bridge (core/model_zoo.py):

  1. golden regression -- the smoke suite is re-extracted from scratch and
     compared against the checked-in JSON goldens in
     ``src/repro/core/zoo_cache/``; any change to the extraction math makes
     this fail byte-for-byte (the comparison is gated on the jax version
     recorded in the golden, with a structural fallback across versions);
  2. calibration -- every cached zoo cell agrees between the batched Eq.1
     kernel path and the scalar roofline path (ratio ~ 1, dominant term
     matches);
  3. property tests -- roofline invariants over randomized profiles and
     machines (dominant == argmax, step time monotone in every rate,
     useful_ratio <= 1 whenever HLO FLOPs cover the model FLOPs, JSON
     round-trips).  Uses hypothesis when installed, otherwise a seeded
     numpy sampling loop with the same predicates (the container image
     ships no hypothesis; CI installs it via the dev extras).
"""

import dataclasses
import json
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import model_zoo as MZ
from repro.core.costs import WorkloadProfile
from repro.core.machine import ALL_SUBSYSTEMS, TPU_V5E, VARIANTS
from repro.core.roofline import RooflineReport, analyze
from repro.core.spec import CodesignSpec
from repro.core.sweep import run_sweep
from repro.core.timing import step_time, subsystem_times
from repro.launch import xla_flags

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

from conftest import floats_property


# --------------------------------------------------------------------------- #
# Grid + suite-name grammar (pure, no compiles)
# --------------------------------------------------------------------------- #


def test_zoo_cell_counts():
    full = MZ.zoo_cells()
    smoke = MZ.zoo_cells(smoke=True)
    assert len(full) >= 100, len(full)          # acceptance: 100+ real cells
    assert len(smoke) >= 6, len(smoke)
    # every (arch, scenario) pair of the registry is covered
    assert {(c.arch, c.scenario) for c in full} == {
        (a, s) for a in MZ.ARCH_IDS for s in MZ.ZOO_SCENARIOS}
    # cache keys are unique (one artifact per cell)
    assert len({c.cache_key for c in full}) == len(full)


def test_zoo_full_shapes_fit_production_mesh():
    # The full suite compiles on the 16x16 pod mesh: every global batch
    # must split across the 16-way data axis.
    for cell in MZ.zoo_cells():
        assert cell.shape.global_batch % 16 == 0, cell.name
        assert cell.shape.seq_len % 16 == 0, cell.name


def test_suite_name_grammar():
    assert MZ.parse_suite("zoo") == (False, None)
    assert MZ.parse_suite("zoo-smoke") == (True, None)
    assert MZ.parse_suite("zoo:train") == (False, "train")
    assert MZ.parse_suite("zoo-smoke:serve-decode") == (True, "serve-decode")
    for bad in ("zoop", "zoo:", "zoo:bogus", "smoke", "zoo-smoke:train:x"):
        with pytest.raises(ValueError):
            MZ.parse_suite(bad)
    # the ONE validation path: CodesignSpec.validate delegates here
    CodesignSpec(suite="zoo:serve-prefill").validate()
    with pytest.raises(ValueError):
        CodesignSpec(suite="zoo:bogus").validate()


def test_cell_fingerprint_tracks_inputs():
    a, b = MZ.zoo_cells(smoke=True)[:2]
    assert MZ.cell_fingerprint(a) != MZ.cell_fingerprint(b)
    # same cell -> same fingerprint (deterministic)
    assert MZ.cell_fingerprint(a) == MZ.cell_fingerprint(a)


def test_full_suite_is_cache_only(tmp_path):
    with pytest.raises(RuntimeError, match="model_zoo"):
        MZ.resolve_suite("zoo", cache_dir=str(tmp_path))


# --------------------------------------------------------------------------- #
# Golden-profile regression (recompiles the smoke suite: ~30-60 s)
# --------------------------------------------------------------------------- #


def test_smoke_goldens_checked_in_and_fresh():
    """Cheap guard: every smoke cell has a golden whose fingerprint matches
    the *current* config/shape/extraction version -- catches config drift
    without recompiling anything."""
    for cell in MZ.zoo_cells(smoke=True):
        path = MZ.cache_path(cell, MZ.SMOKE_CACHE_DIR)
        assert os.path.exists(path), f"missing golden {path}"
        profile = WorkloadProfile.load(path)
        assert profile.meta["fingerprint"] == MZ.cell_fingerprint(cell), (
            f"stale golden {path}: re-run "
            f"PYTHONPATH=src python -m repro.core.model_zoo --smoke --refresh")
        assert profile.meta["scenario"] == cell.scenario
        # canonical form: volatile wall-clock fields zeroed
        assert profile.compile_seconds == 0.0
        assert "probe_seconds" not in profile.meta


def test_golden_profiles_pin_extraction_math(tmp_path):
    """Re-extract the smoke suite from scratch and compare to the goldens.

    Byte-for-byte when the golden was produced by this jax version; across
    jax versions, a structural comparison with tolerance on the measured
    cost fields (XLA codegen may legitimately shift them slightly)."""
    import jax

    fresh = MZ.profiles_from_configs(smoke=True, cache_dir=str(tmp_path),
                                     refresh=True)
    assert len(fresh) >= 6
    for cell in MZ.zoo_cells(smoke=True):
        golden_path = MZ.cache_path(cell, MZ.SMOKE_CACHE_DIR)
        new_path = MZ.cache_path(cell, str(tmp_path))
        with open(golden_path, "rb") as f:
            golden_bytes = f.read()
        golden = json.loads(golden_bytes)
        if golden["meta"].get("jax_version") == jax.__version__:
            with open(new_path, "rb") as f:
                new_bytes = f.read()
            assert new_bytes == golden_bytes, (
                f"extraction output changed for {cell.name}: if the change "
                f"is intentional, bump ZOO_EXTRACTION_VERSION and refresh "
                f"the goldens (python -m repro.core.model_zoo --smoke "
                f"--refresh)")
        else:  # pragma: no cover - exercised on CI's floating jax
            with open(new_path) as f:
                new = json.load(f)
            assert new["meta"]["fingerprint"] == golden["meta"]["fingerprint"]
            for field in ("flops", "hbm_bytes", "model_flops",
                          "num_devices", "tokens"):
                assert new[field] == pytest.approx(golden[field], rel=0.25), \
                    (cell.name, field)


# --------------------------------------------------------------------------- #
# Calibration: Eq.1 batched kernels vs scalar roofline on every zoo cell
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def smoke_profiles():
    return MZ.resolve_suite("zoo-smoke", extract_missing=False)


def test_calibration_report_smoke(smoke_profiles):
    rep = MZ.calibration_report(smoke_profiles)
    assert len(rep.cells) >= 6
    for c in rep.cells:
        # acceptance: calibration ratio finite and positive on every cell
        assert math.isfinite(c.ratio) and c.ratio > 0.0, c
        assert c.dominant_eq1 in ("compute", "memory", "interconnect")
    # acceptance: dominant-term match on >= 80% of smoke cells
    assert rep.dominant_agreement >= 0.8
    # the two code paths share the same kernel math: ratio is ~exactly 1
    for c in rep.cells:
        assert c.ratio == pytest.approx(1.0, rel=1e-9), c


def test_calibration_report_protocol(smoke_profiles):
    rep = MZ.calibration_report(smoke_profiles, timing_model="overlap")
    blob = rep.to_json(top_k=3)
    json.dumps(blob, allow_nan=False)  # strict-JSON clean
    assert blob["num_cells"] == len(rep.cells)
    assert len(blob["cells"]) == 3
    md = rep.markdown(top_k=2)
    assert "dominant-term agreement" in md
    assert len(rep.worst_offenders(4)) == 4


def test_zoo_cells_measured_invariants(smoke_profiles):
    # Measured (not assumed) sanity on every extracted cell.  NOTE:
    # useful_ratio <= 1 does NOT hold for tiny smoke configs (model FLOPs
    # 6*N*D can exceed the HLO count when vocab/width are toy-sized), so
    # here we pin finite-and-positive; the <= 1 direction is a *math*
    # property tested in test_useful_ratio_bounded below.
    for p in smoke_profiles:
        assert p.flops > 0 and p.hbm_bytes > 0
        assert math.isfinite(p.useful_flops_ratio)
        assert p.useful_flops_ratio > 0
        rep = analyze(p, TPU_V5E)
        assert rep.dominant in ("compute", "memory", "interconnect")
        assert rep.step_time_serial_s >= rep.step_time_overlap_s > 0


# --------------------------------------------------------------------------- #
# Roofline property tests (hypothesis when available, seeded loop otherwise)
# --------------------------------------------------------------------------- #


def _profile(fe, me, ce, model_frac=0.5, ndev=4):
    # exponent-parameterized so draws cover many orders of magnitude
    return WorkloadProfile(
        name="prop", flops=10.0 ** fe, hbm_bytes=10.0 ** me,
        collective_bytes={"all-reduce": 10.0 ** ce}, num_devices=ndev,
        model_flops=model_frac * (10.0 ** fe) * ndev)


@floats_property(fe=(8.0, 16.0), me=(6.0, 14.0), ce=(5.0, 13.0))
def test_dominant_is_argmax(fe, me, ce):
    p = _profile(fe, me, ce)
    t = subsystem_times(p, TPU_V5E)
    terms = [t.term(s) for s in ALL_SUBSYSTEMS]
    if len({terms[0], terms[1], terms[2]}) < 3:
        return  # exact tie: any winner is acceptable
    assert t.dominant == ALL_SUBSYSTEMS[int(np.argmax(terms))]


@floats_property(fe=(8.0, 16.0), me=(6.0, 14.0), ce=(5.0, 13.0),
                 scale=(1.0, 100.0))
def test_step_time_monotone_in_every_rate(fe, me, ce, scale):
    p = _profile(fe, me, ce)
    base = step_time(p, TPU_V5E)
    for field in ("peak_flops", "hbm_bw", "ici_bw", "inter_pod_bw"):
        faster = dataclasses.replace(
            TPU_V5E, **{field: getattr(TPU_V5E, field) * scale})
        assert step_time(p, faster) <= base * (1 + 1e-12), field


@floats_property(fe=(8.0, 16.0), frac=(1e-6, 1.0), ndev=(1.0, 512.0))
def test_useful_ratio_bounded(fe, frac, ndev):
    # Whenever the HLO actually performs at least the model FLOPs (the
    # dense-train regime), useful_ratio = model/global is <= 1 -- and it is
    # always positive and finite for positive inputs.
    p = _profile(fe, fe - 2, fe - 3, model_frac=frac, ndev=int(ndev))
    r = p.useful_flops_ratio
    assert 0.0 < r <= 1.0
    # conversely, model_flops above the HLO count pushes it above 1
    p2 = dataclasses.replace(p, model_flops=p.global_flops * 1.5)
    assert p2.useful_flops_ratio > 1.0


@floats_property(fe=(8.0, 16.0), me=(6.0, 14.0), ce=(5.0, 13.0))
def test_roofline_report_round_trip(fe, me, ce):
    rep = analyze(_profile(fe, me, ce), TPU_V5E)
    d = rep.as_dict()
    json.dumps(d, allow_nan=False)          # strict JSON always
    assert RooflineReport.from_dict(d) == rep


def test_roofline_round_trip_non_finite():
    # zero-rate machines / zero-FLOP cells produce inf and nan terms; the
    # satellite contract: as_dict stays strict-JSON-safe and from_dict is
    # an exact inverse (including sign of inf and nan-ness).
    dead = dataclasses.replace(TPU_V5E, hbm_bw=0.0)
    rep = analyze(_profile(12, 10, 9), dead)
    assert math.isinf(rep.memory_s)
    d = rep.as_dict()
    json.dumps(d, allow_nan=False)
    back = RooflineReport.from_dict(d)
    for f in dataclasses.fields(RooflineReport):
        a, b = getattr(rep, f.name), getattr(back, f.name)
        if isinstance(a, float) and math.isnan(a):
            assert math.isnan(b), f.name
        else:
            assert a == b, f.name
    # hand-built corners: -inf and nan survive exactly
    rep2 = dataclasses.replace(rep, mfu_bound=-math.inf,
                               roofline_fraction=math.nan)
    d2 = rep2.as_dict()
    assert d2["mfu_bound"] == "-inf" and d2["roofline_fraction"] == "nan"
    back2 = RooflineReport.from_dict(d2)
    assert back2.mfu_bound == -math.inf
    assert math.isnan(back2.roofline_fraction)
    with pytest.raises(ValueError, match="unknown RooflineReport"):
        RooflineReport.from_dict({**d, "bogus": 1})


# --------------------------------------------------------------------------- #
# Zoo suites end-to-end: sweep, frontier, service, CLI
# --------------------------------------------------------------------------- #


def test_run_sweep_accepts_suite_name(smoke_profiles):
    by_name = run_sweep("zoo-smoke", n=24, seed=3)
    by_list = run_sweep(smoke_profiles, n=24, seed=3)
    assert by_name.to_json(top_k=5) == by_list.to_json(top_k=5)
    assert len(by_name.profiles) >= 6


def test_frontier_accepts_suite_name():
    from repro.core.frontier import frontier_codesign

    res = frontier_codesign("zoo-smoke", VARIANTS, budgets=[0.9, 1.2],
                            steps=2, refine_steps=1)
    assert len(res) == 2
    assert np.all(np.isfinite(res.objective))


def test_service_resolves_spec_suite(smoke_profiles):
    from repro.serving.codesign_service import (
        CodesignRequest,
        CodesignService,
    )

    svc = CodesignService(auto_start=False)
    jid = svc.submit(CodesignRequest(
        kind="sweep", profiles=None,
        spec=CodesignSpec(suite="zoo-smoke", n=16, seed=1)))
    svc.drain()
    got = svc.result(jid)
    want = run_sweep(smoke_profiles, n=16, seed=1)
    assert got.to_json(top_k=4) == want.to_json(top_k=4)
    # profiles=None with no suite on the spec is rejected up front
    with pytest.raises(ValueError, match="spec.suite"):
        CodesignRequest(kind="sweep", profiles=None)


def test_sweep_cli_suite_smoke():
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "sweep.py"),
         "--suite", "zoo-smoke", "--num", "16", "--format", "md",
         "--top", "3"],
        capture_output=True, text=True, timeout=600, cwd=ROOT,
        env={**os.environ, "PYTHONPATH": SRC + os.pathsep + ROOT})
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "suite zoo-smoke:" in out.stderr and "profiles" in out.stderr
    assert "| variant |" in out.stdout
    # bad suite names die at argparse time
    bad = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "sweep.py"),
         "--suite", "zoo:bogus"],
        capture_output=True, text=True, timeout=120, cwd=ROOT,
        env={**os.environ, "PYTHONPATH": SRC + os.pathsep + ROOT})
    assert bad.returncode == 2
    assert "unknown zoo scenario" in bad.stderr


# --------------------------------------------------------------------------- #
# XLA_FLAGS satellite: append (not clobber) + loud device-count failure
# --------------------------------------------------------------------------- #


def test_request_host_devices_appends(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_enable_fast_math=false")
    xla_flags.request_host_devices(512)
    flags = os.environ["XLA_FLAGS"]
    assert "--xla_cpu_enable_fast_math=false" in flags   # preserved
    assert f"{xla_flags.HOST_PLATFORM_FLAG}=512" in flags
    assert xla_flags.requested_host_devices() == 512
    # a second request never duplicates or overrides the flag
    xla_flags.request_host_devices(8)
    assert os.environ["XLA_FLAGS"] == flags


def test_requested_host_devices_empty(monkeypatch):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    assert xla_flags.requested_host_devices() is None


def test_dryrun_import_preserves_existing_flags():
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import os
            import repro.launch.dryrun  # the import requests 512 devices
            flags = os.environ["XLA_FLAGS"]
            assert "--xla_cpu_enable_fast_math=false" in flags, flags
            assert "--xla_force_host_platform_device_count=512" in flags
            print("PRESERVED")
        """)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": SRC,
             "XLA_FLAGS": "--xla_cpu_enable_fast_math=false"})
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "PRESERVED" in out.stdout


def test_ensure_host_device_count_fails_loudly():
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import jax
            jax.devices()  # lock the backend at the default 1 device
            from repro.launch import xla_flags
            try:
                xla_flags.ensure_host_device_count(256)
            except RuntimeError as e:
                assert "jax locks the device count" in str(e), e
                print("LOUD-FAILURE")
        """)],
        capture_output=True, text=True, timeout=600,
        env={**env, "PYTHONPATH": SRC})
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "LOUD-FAILURE" in out.stdout
