"""Kernel backend layer: numpy/jax/pallas registry, selection, and
equivalence, plus the CostModel area/power proxies.

Pinned equivalence tolerances:
  * jax == numpy to 1e-6 (actually ~1e-12 -- the JAX backend runs x64).
  * pallas == numpy to 5e-4 -- the fused Pallas kernel computes in f32
    (TPUs have no f64), and the Eq. 1 cancellation (alpha - beta) /
    (gamma - beta) amplifies f32 epsilon; measured worst case is ~1e-5,
    5e-4 is the pin.  On CPU CI the kernel runs in interpreter mode --
    the same tiling and f32 math the TPU compile sees.
"""

import dataclasses
import os

import numpy as np
import pytest

from conftest import hypothesis_shim

given, settings, st = hypothesis_shim(seed=0xD1FF, trials=12)

from repro.core import (
    CostModel,
    DEFAULT_COST_MODEL,
    TPU_V5E,
    VARIANTS,
    available_backends,
    evaluate,
    get_backend,
)
from repro.core.kernels_xp import Backend, NumpyBackend
from repro.core.sweep import (
    MachineBatch,
    ParamSpace,
    batched_congruence,
    batched_step_time,
    default_beta_batched,
    run_sweep,
)
from test_sweep import candidate_machines, random_profiles

JAX_RTOL = 1e-6
PALLAS_RTOL = 5e-4


# --------------------------------------------------------------------------- #
# registry + selection
# --------------------------------------------------------------------------- #


def test_registry_has_numpy_and_jax():
    assert "numpy" in available_backends()
    assert "jax" in available_backends()
    assert get_backend("numpy").name == "numpy"
    assert get_backend("jax").name == "jax"
    assert get_backend("jax").differentiable
    assert not get_backend("numpy").differentiable


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("bogus")


def test_backend_instance_passthrough():
    be = get_backend("numpy")
    assert get_backend(be) is be


def test_env_var_selects_default_backend(monkeypatch):
    monkeypatch.delenv("REPRO_SWEEP_BACKEND", raising=False)
    assert get_backend().name == "numpy"
    monkeypatch.setenv("REPRO_SWEEP_BACKEND", "jax")
    assert get_backend().name == "jax"
    res = batched_congruence(random_profiles(2, seed=1),
                             MachineBatch.from_models(VARIANTS))
    assert res.backend == "jax"
    monkeypatch.setenv("REPRO_SWEEP_BACKEND", "numpy")
    assert get_backend().name == "numpy"


def test_register_backend_roundtrip():
    from repro.core import register_backend

    class Tagged(NumpyBackend):
        name = "tagged"

    register_backend("tagged", Tagged)
    try:
        assert "tagged" in available_backends()
        res = batched_congruence(random_profiles(2, seed=2),
                                 MachineBatch.from_models(VARIANTS),
                                 backend="tagged")
        assert res.backend == "tagged"
    finally:
        from repro.core.kernels_xp import _BACKEND_CACHE, _BACKEND_FACTORIES
        _BACKEND_FACTORIES.pop("tagged", None)
        _BACKEND_CACHE.pop("tagged", None)


# --------------------------------------------------------------------------- #
# numpy == jax (the 1e-6 acceptance property)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("timing_model", ["serial", "overlap"])
@pytest.mark.parametrize("clamp", [False, True])
def test_jax_matches_numpy_congruence(timing_model, clamp):
    profiles = random_profiles(6, seed=3)
    machines = candidate_machines(24, seed=1)
    res_n = batched_congruence(profiles, machines, timing_model=timing_model,
                               clamp=clamp, backend="numpy")
    res_j = batched_congruence(profiles, machines, timing_model=timing_model,
                               clamp=clamp, backend="jax")
    np.testing.assert_allclose(res_j.beta, res_n.beta, rtol=JAX_RTOL)
    np.testing.assert_allclose(res_j.gamma, res_n.gamma, rtol=JAX_RTOL)
    for k in res_n.scores:
        np.testing.assert_allclose(res_j.scores[k], res_n.scores[k],
                                   rtol=JAX_RTOL, atol=JAX_RTOL)
    for k in res_n.alphas:
        np.testing.assert_allclose(res_j.alphas[k], res_n.alphas[k],
                                   rtol=JAX_RTOL)
    np.testing.assert_allclose(res_j.aggregate, res_n.aggregate,
                               rtol=JAX_RTOL, atol=JAX_RTOL)
    # the jax tensors come home as NumPy; downstream extractions identical
    assert isinstance(res_j.aggregate, np.ndarray)
    assert res_j.pareto_front() == res_n.pareto_front()
    assert res_j.pareto_front_3d() == res_n.pareto_front_3d()


def test_jax_matches_numpy_step_time_and_beta():
    profiles = random_profiles(5, seed=7)
    machines = candidate_machines(16, seed=2)
    for tm in ("serial", "overlap"):
        t_n = batched_step_time(profiles, machines, timing_model=tm,
                                backend="numpy")
        t_j = batched_step_time(profiles, machines, timing_model=tm,
                                backend="jax")
        np.testing.assert_allclose(t_j, t_n, rtol=JAX_RTOL)
    b_n = default_beta_batched(profiles, machines, backend="numpy")
    b_j = default_beta_batched(profiles, machines, backend="jax")
    np.testing.assert_allclose(b_j, b_n, rtol=JAX_RTOL)


def test_evaluate_and_run_sweep_accept_backend():
    profiles = random_profiles(3, seed=9)
    t_n = evaluate(profiles, backend="numpy")
    t_j = evaluate(profiles, backend="jax")
    assert t_j.result.backend == "jax"
    for app in t_n.apps:
        assert t_j.best_fit(app) == t_n.best_fit(app)
        for v in t_n.variants:
            assert t_j._aggregate(app, v) == pytest.approx(
                t_n._aggregate(app, v), rel=JAX_RTOL, abs=JAX_RTOL)
    res = run_sweep(profiles, n=32, include_named=VARIANTS, backend="jax")
    assert res.backend == "jax"
    ref = run_sweep(profiles, n=32, include_named=VARIANTS, backend="numpy")
    np.testing.assert_allclose(res.aggregate, ref.aggregate,
                               rtol=JAX_RTOL, atol=JAX_RTOL)


def test_jax_backend_is_reused_and_cached():
    assert get_backend("jax") is get_backend("jax")


# --------------------------------------------------------------------------- #
# pallas == numpy (the fused-kernel acceptance property)
# --------------------------------------------------------------------------- #


def test_registry_has_pallas():
    """The fused backend registers lazily via the register_backend hook."""
    assert "pallas" in available_backends()
    be = get_backend("pallas")
    assert be.name == "pallas"
    assert not be.differentiable
    assert be is get_backend("pallas")  # cached like the others
    # no TPU in CI: the interpreter fallback must have been auto-selected
    import jax
    if jax.default_backend() != "tpu":
        assert be.interpret


@pytest.mark.parametrize("timing_model", ["serial", "overlap"])
@pytest.mark.parametrize("clamp", [False, True])
def test_pallas_matches_numpy_congruence(timing_model, clamp):
    profiles = random_profiles(6, seed=3)
    machines = candidate_machines(24, seed=1)
    res_n = batched_congruence(profiles, machines, timing_model=timing_model,
                               clamp=clamp, backend="numpy")
    res_p = batched_congruence(profiles, machines, timing_model=timing_model,
                               clamp=clamp, backend="pallas")
    np.testing.assert_allclose(res_p.beta, res_n.beta, rtol=PALLAS_RTOL)
    np.testing.assert_allclose(res_p.gamma, res_n.gamma, rtol=PALLAS_RTOL)
    for k in res_n.scores:
        np.testing.assert_allclose(res_p.scores[k], res_n.scores[k],
                                   rtol=PALLAS_RTOL, atol=PALLAS_RTOL)
    for k in res_n.alphas:
        np.testing.assert_allclose(res_p.alphas[k], res_n.alphas[k],
                                   rtol=PALLAS_RTOL)
    np.testing.assert_allclose(res_p.aggregate, res_n.aggregate,
                               rtol=PALLAS_RTOL, atol=PALLAS_RTOL)
    assert isinstance(res_p.aggregate, np.ndarray)
    assert res_p.backend == "pallas"


def test_pallas_matches_numpy_step_time_and_beta():
    profiles = random_profiles(5, seed=7)
    machines = candidate_machines(16, seed=2)
    for tm in ("serial", "overlap"):
        t_n = batched_step_time(profiles, machines, timing_model=tm,
                                backend="numpy")
        t_p = batched_step_time(profiles, machines, timing_model=tm,
                                backend="pallas")
        np.testing.assert_allclose(t_p, t_n, rtol=PALLAS_RTOL)
    b_n = default_beta_batched(profiles, machines, backend="numpy")
    b_p = default_beta_batched(profiles, machines, backend="pallas")
    np.testing.assert_allclose(b_p, b_n, rtol=PALLAS_RTOL)


def test_pallas_variant_padding_edges():
    """The variant axis is padded to a tile multiple and sliced back out;
    pin the boundary populations (V=1, sub-lane, exact-tile)."""
    profiles = random_profiles(2, seed=13)
    space = ParamSpace.default()
    for v in (1, 5, 127, 128, 129):
        machines = space.sample(v, seed=2)
        res_n = batched_congruence(profiles, machines, backend="numpy")
        res_p = batched_congruence(profiles, machines, backend="pallas")
        assert res_p.aggregate.shape == res_n.aggregate.shape == (2, v)
        np.testing.assert_allclose(res_p.aggregate, res_n.aggregate,
                                   rtol=PALLAS_RTOL, atol=PALLAS_RTOL)
        assert np.all(np.isfinite(res_p.aggregate))


def test_run_sweep_pallas_4096_matches_numpy():
    """ISSUE acceptance: run_sweep(n=4096, backend='pallas') == numpy
    within the pinned tolerance, under interpreter mode on CPU CI."""
    profiles = random_profiles(3, seed=11)
    res_p = run_sweep(profiles, n=4096, backend="pallas")
    res_n = run_sweep(profiles, n=4096, backend="numpy")
    assert res_p.backend == "pallas"
    np.testing.assert_allclose(res_p.aggregate, res_n.aggregate,
                               rtol=PALLAS_RTOL, atol=PALLAS_RTOL)
    np.testing.assert_allclose(res_p.beta, res_n.beta, rtol=PALLAS_RTOL)
    # extractions agree on the clear winners even under f32
    assert res_p.best_fit_indices().shape == res_n.best_fit_indices().shape


def test_pallas_interpret_env_override(monkeypatch):
    from repro.core.kernels_pallas import PallasBackend

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert PallasBackend().interpret
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert not PallasBackend().interpret
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    # explicit argument always wins
    assert PallasBackend(interpret=True).interpret


# --------------------------------------------------------------------------- #
# adversarial cross-backend differential fuzz
# --------------------------------------------------------------------------- #


def _fuzz_profile(name, flops, hbm, coll, nd=64, model_flops=None):
    from repro.core import WorkloadProfile

    return WorkloadProfile(
        name=name, flops=flops, hbm_bytes=hbm, bytes_accessed=hbm,
        collective_bytes={"all-reduce": coll}, num_devices=nd,
        model_flops=(0.5 * flops * nd if model_flops is None
                     else model_flops))


def _assert_backends_agree(profiles, machines, beta=None):
    res_n = batched_congruence(profiles, machines, beta=beta, clamp=True,
                               backend="numpy")
    res_j = batched_congruence(profiles, machines, beta=beta, clamp=True,
                               backend="jax")
    res_p = batched_congruence(profiles, machines, beta=beta, clamp=True,
                               backend="pallas")
    for res in (res_n, res_j, res_p):
        assert np.isfinite(res.aggregate).all(), res.backend
        assert np.isfinite(res.beta).all() and np.isfinite(res.gamma).all()
    np.testing.assert_allclose(res_j.aggregate, res_n.aggregate,
                               rtol=JAX_RTOL, atol=JAX_RTOL)
    np.testing.assert_allclose(res_p.aggregate, res_n.aggregate,
                               rtol=PALLAS_RTOL, atol=PALLAS_RTOL)


@given(
    flops=st.floats(1e6, 1e16),
    intensity=st.floats(1.0, 4096.0),
    coll_frac=st.floats(0.0, 1.0),
    rate_scale=st.floats(1e-3, 1e3),
    beta=st.floats(1e-4, 1e3),
)
@settings(max_examples=24, deadline=None)
def test_backends_agree_on_fuzzed_cells(flops, intensity, coll_frac,
                                        rate_scale, beta):
    """Differential fuzz: numpy == jax to 1e-6 and numpy == pallas to
    5e-4 must hold across the whole (workload x machine x beta) knob
    space, not just the curated suites -- ten decades of FLOPs, rates
    scaled 1e-3..1e3x off nominal, betas from microseconds to ks."""
    prof = _fuzz_profile("fuzz", flops, flops / intensity,
                         coll_frac * flops / intensity)
    machines = MachineBatch.from_models([
        TPU_V5E,
        dataclasses.replace(TPU_V5E,
                            peak_flops=TPU_V5E.peak_flops * rate_scale),
        dataclasses.replace(TPU_V5E, hbm_bw=TPU_V5E.hbm_bw * rate_scale),
        dataclasses.replace(TPU_V5E, ici_bw=TPU_V5E.ici_bw * rate_scale),
    ])
    _assert_backends_agree([prof], machines, beta=beta)


def test_backends_agree_on_degenerate_cells():
    """Deterministic adversarial pins: zero-FLOP and zero-collective
    apps, near-zero and huge machine rates, extreme betas.  Every
    backend must return finite clamped scores and agree."""
    profiles = [
        _fuzz_profile("zero-flop", 0.0, 1e9, 1e8, nd=8, model_flops=0.0),
        _fuzz_profile("zero-coll", 1e12, 1e9, 0.0, nd=8),
        _fuzz_profile("tiny", 1.0, 1.0, 0.0, nd=8, model_flops=0.5),
        _fuzz_profile("hbm-bound", 1e9, 1e12, 1e10, nd=8),
    ]
    machines = MachineBatch.from_models([
        TPU_V5E,
        dataclasses.replace(TPU_V5E,
                            peak_flops=TPU_V5E.peak_flops * 1e-6),
        dataclasses.replace(TPU_V5E, hbm_bw=TPU_V5E.hbm_bw * 1e6),
        dataclasses.replace(TPU_V5E, ici_bw=TPU_V5E.ici_bw * 1e-6,
                            inter_pod_bw=TPU_V5E.inter_pod_bw * 1e-6),
    ])
    for beta in (None, 1e-6, 1e3):
        _assert_backends_agree(profiles, machines, beta=beta)


def test_backends_agree_on_generated_population():
    """The gen:* stress suites run through the same pinned tolerances --
    the population that exists precisely to catch off-suite drift."""
    from repro.core.model_zoo import resolve_suite

    profiles = resolve_suite("gen:16:seed=9")
    machines = candidate_machines(24, seed=6)
    _assert_backends_agree(profiles, machines)


# --------------------------------------------------------------------------- #
# CLI --backend validation (fail at parse time, not deep in the registry)
# --------------------------------------------------------------------------- #


def _load_sweep_cli():
    import importlib.util

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "sweep_cli", os.path.join(root, "scripts", "sweep.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_sweep_cli_rejects_unknown_backend(capsys):
    cli = _load_sweep_cli()
    with pytest.raises(SystemExit) as exc:
        cli.main(["--num", "4", "--backend", "bogus"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "unknown backend" in err and "pallas" in err


def test_sweep_cli_accepts_registered_backends():
    cli = _load_sweep_cli()
    ap_stub = __import__("argparse").ArgumentParser()
    for name in available_backends():
        cli.validate_backend(ap_stub, name)  # must not raise


def test_hillclimb_rejects_unknown_backend(capsys):
    from repro.launch import hillclimb

    with pytest.raises(SystemExit) as exc:
        hillclimb.main(["--arch", "chatglm3-6b", "--shape", "train_4k",
                        "--backend", "bogus"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "unknown backend" in err and "pallas" in err


# --------------------------------------------------------------------------- #
# CostModel: area + power proxies
# --------------------------------------------------------------------------- #


def test_default_area_matches_legacy_proxy():
    """Equal weights must reproduce PR 1's four-rate mean exactly."""
    batch = candidate_machines(20, seed=4)
    legacy = (
        batch.peak_flops / TPU_V5E.peak_flops
        + batch.hbm_bw / TPU_V5E.hbm_bw
        + batch.ici_bw_total / (TPU_V5E.ici_bw * TPU_V5E.ici_links)
        + batch.inter_pod_bw / TPU_V5E.inter_pod_bw
    ) / 4.0
    np.testing.assert_allclose(DEFAULT_COST_MODEL.area(batch), legacy,
                               rtol=1e-12)
    np.testing.assert_allclose(batch.area(), legacy, rtol=1e-12)


def test_cost_model_reference_point():
    ref_batch = MachineBatch.from_models([TPU_V5E])
    assert DEFAULT_COST_MODEL.area(ref_batch)[0] == pytest.approx(1.0)
    assert DEFAULT_COST_MODEL.power(ref_batch)[0] == pytest.approx(
        1.0 + DEFAULT_COST_MODEL.static_power)
    # scalar MachineModel works too (duck-typed rate fields)
    assert DEFAULT_COST_MODEL.area(TPU_V5E) == pytest.approx(1.0)


def test_power_superlinear_in_compute():
    """Doubling peak_flops must cost more than 2x its dynamic share
    (DVFS-flavored exponent), while hbm scales linearly."""
    m1 = MachineBatch.from_models([TPU_V5E])
    import dataclasses
    m2 = MachineBatch.from_models(
        [dataclasses.replace(TPU_V5E, peak_flops=TPU_V5E.peak_flops * 2)])
    cm = CostModel()
    d1 = cm.power(m1)[0] - cm.static_power
    d2 = cm.power(m2)[0] - cm.static_power
    # compute contributes 1/4 at reference; superlinear term: 2**1.5 > 2
    assert d2 - d1 > (2.0 - 1.0) / 4.0
    assert d2 - d1 == pytest.approx((2.0 ** 1.5 - 1.0) / 4.0)


def test_cost_model_weights_change_ranking():
    space = ParamSpace.default()
    batch = space.sample(32, seed=5)
    heavy_compute = CostModel(area_weights={"peak_flops": 10.0, "hbm_bw": 1.0,
                                            "ici_bw_total": 1.0,
                                            "inter_pod_bw": 1.0})
    a_eq = DEFAULT_COST_MODEL.area(batch)
    a_hc = heavy_compute.area(batch)
    assert not np.allclose(np.argsort(a_eq), np.argsort(a_hc))


def test_cost_model_rejects_unknown_field():
    with pytest.raises(KeyError):
        CostModel(area_weights={"nonsense": 1.0})


def test_cost_model_rejects_degenerate_weights():
    """Empty or all-zero weight maps fail at construction, not mid-sweep."""
    with pytest.raises(ValueError, match="positive total"):
        CostModel(area_weights={})
    with pytest.raises(ValueError, match="positive total"):
        CostModel(power_weights={"peak_flops": 0.0})


def test_backend_base_class_is_abstract():
    be = Backend()
    with pytest.raises(NotImplementedError):
        be.asarray([1.0])
