"""Shared test plumbing: the hypothesis-with-fallback property shims.

Tier-1 must pass without the ``dev`` extra (pyproject declares hypothesis
there, not in the core deps), so every property test runs through one of
the two shims defined here instead of importing hypothesis directly:

  ``hypothesis_shim(seed, trials)`` -> the ``(given, settings, st)``
      triple a test module would import from hypothesis.  With hypothesis
      installed these ARE the real decorators (``seed``/``trials`` are
      ignored -- hypothesis manages its own examples); without it the
      same property bodies run over both range endpoints plus seeded
      uniform draws, ``trials`` calls total.

  ``floats_property(n_examples, seed, **ranges)`` -> a decorator mapping
      argument names to ``(lo, hi)`` float bounds; a real ``@given``
      property under hypothesis, a seeded numpy loop otherwise.

Keeping the fallback in ONE place (it used to be copied into four test
modules) means the trial-0/trial-1 endpoint convention and the
no-functools.wraps pytest workaround cannot drift between files.
"""

import numpy as np

try:
    from hypothesis import given as _h_given
    from hypothesis import settings as _h_settings
    from hypothesis import strategies as _h_st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal images
    HAVE_HYPOTHESIS = False


def hypothesis_shim(seed, trials):
    """The ``(given, settings, st)`` triple for one test module.

    ``seed`` keeps each module's fallback draws distinct (and stable
    across runs); ``trials`` sizes the fallback loop -- modules whose
    property bodies run full jax descents use far fewer trials than the
    pure-numpy ones.  Trial 0 pins every argument to its lower bound and
    trial 1 to its upper bound, so range endpoints are always exercised.
    """
    if HAVE_HYPOTHESIS:
        return _h_given, _h_settings, _h_st

    import random as _random

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

    class st:  # noqa: N801 - mirrors the hypothesis module name
        floats = _Floats

    def settings(**_kw):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: copying __wrapped__ would make pytest see
            # the inner signature and demand fixtures for every argument.
            def runner():
                rng = _random.Random(seed)
                for trial in range(trials):
                    kwargs = {}
                    for name in sorted(strategies):
                        s = strategies[name]
                        if trial == 0:
                            kwargs[name] = s.lo
                        elif trial == 1:
                            kwargs[name] = s.hi
                        else:
                            kwargs[name] = s.lo + (s.hi - s.lo) * rng.random()
                    fn(**kwargs)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    return given, settings, st


def gradcheck(fn, x, grad, *, rtol=1e-3, atol=1e-8, h=1e-3,
              log_space=False, n_dirs=None, seed=0):
    """Central-finite-difference check of ``grad`` against ``fn`` at ``x``.

    The one FD harness every gradient test shares (it used to be
    hand-rolled per test): ``fn`` maps a 1-D numpy array to a scalar,
    ``grad`` is the analytic gradient at ``x``.  Each coordinate is
    perturbed by a scaled central step ``h * max(|x_j|, 1)`` -- or
    multiplicatively (``x_j * (1 +/- h)``) with ``log_space=True``, the
    right convention for the strictly-positive rate/budget parameters
    this repo differentiates through.  With ``n_dirs`` set, only that
    many seeded random coordinates are checked (for expensive ``fn``).

    Asserts ``|fd - grad| <= atol + rtol * max(|fd|, |grad|)`` per
    checked coordinate and returns the worst relative error.
    """
    x = np.asarray(x, dtype=np.float64).ravel()
    grad = np.asarray(grad, dtype=np.float64).ravel()
    assert grad.shape == x.shape, (grad.shape, x.shape)
    coords = np.arange(x.size)
    if n_dirs is not None and n_dirs < x.size:
        coords = np.random.default_rng(seed).choice(
            x.size, size=n_dirs, replace=False)
    worst = 0.0
    for j in coords:
        if log_space:
            assert x[j] > 0.0, f"log_space gradcheck needs x > 0, got {x[j]}"
            hj = h * x[j]
        else:
            hj = h * max(abs(x[j]), 1.0)
        xp, xm = x.copy(), x.copy()
        xp[j] += hj
        xm[j] -= hj
        fd = (float(fn(xp)) - float(fn(xm))) / (2.0 * hj)
        scale = max(abs(fd), abs(grad[j]))
        err = abs(fd - grad[j])
        assert err <= atol + rtol * scale, (
            f"gradcheck failed at coordinate {j}: fd={fd:.8g} "
            f"grad={grad[j]:.8g} err={err:.3g} > "
            f"atol+rtol*scale={atol + rtol * scale:.3g}")
        worst = max(worst, err / max(scale, 1e-30))
    return worst


def floats_property(n_examples=150, seed=20260808, **ranges):
    """``@given`` with float ranges, or a seeded-loop fallback.

    ``ranges`` maps argument names to ``(lo, hi)`` bounds.  With
    hypothesis installed the test becomes a ``@given`` property; without
    it the same predicate runs over ``n_examples`` deterministic uniform
    draws.
    """

    def deco(fn):
        if HAVE_HYPOTHESIS:
            strats = {k: _h_st.floats(min_value=lo, max_value=hi,
                                      allow_nan=False, allow_infinity=False)
                      for k, (lo, hi) in ranges.items()}
            return _h_settings(max_examples=n_examples,
                               deadline=None)(_h_given(**strats)(fn))

        def runner():
            rng = np.random.default_rng(seed)
            for _ in range(n_examples):
                fn(**{k: float(rng.uniform(lo, hi))
                      for k, (lo, hi) in ranges.items()})

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
