"""Shared test plumbing: the hypothesis-with-fallback property shims.

Tier-1 must pass without the ``dev`` extra (pyproject declares hypothesis
there, not in the core deps), so every property test runs through one of
the two shims defined here instead of importing hypothesis directly:

  ``hypothesis_shim(seed, trials)`` -> the ``(given, settings, st)``
      triple a test module would import from hypothesis.  With hypothesis
      installed these ARE the real decorators (``seed``/``trials`` are
      ignored -- hypothesis manages its own examples); without it the
      same property bodies run over both range endpoints plus seeded
      uniform draws, ``trials`` calls total.

  ``floats_property(n_examples, seed, **ranges)`` -> a decorator mapping
      argument names to ``(lo, hi)`` float bounds; a real ``@given``
      property under hypothesis, a seeded numpy loop otherwise.

Keeping the fallback in ONE place (it used to be copied into four test
modules) means the trial-0/trial-1 endpoint convention and the
no-functools.wraps pytest workaround cannot drift between files.
"""

import numpy as np

try:
    from hypothesis import given as _h_given
    from hypothesis import settings as _h_settings
    from hypothesis import strategies as _h_st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal images
    HAVE_HYPOTHESIS = False


def hypothesis_shim(seed, trials):
    """The ``(given, settings, st)`` triple for one test module.

    ``seed`` keeps each module's fallback draws distinct (and stable
    across runs); ``trials`` sizes the fallback loop -- modules whose
    property bodies run full jax descents use far fewer trials than the
    pure-numpy ones.  Trial 0 pins every argument to its lower bound and
    trial 1 to its upper bound, so range endpoints are always exercised.
    """
    if HAVE_HYPOTHESIS:
        return _h_given, _h_settings, _h_st

    import random as _random

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

    class st:  # noqa: N801 - mirrors the hypothesis module name
        floats = _Floats

    def settings(**_kw):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: copying __wrapped__ would make pytest see
            # the inner signature and demand fixtures for every argument.
            def runner():
                rng = _random.Random(seed)
                for trial in range(trials):
                    kwargs = {}
                    for name in sorted(strategies):
                        s = strategies[name]
                        if trial == 0:
                            kwargs[name] = s.lo
                        elif trial == 1:
                            kwargs[name] = s.hi
                        else:
                            kwargs[name] = s.lo + (s.hi - s.lo) * rng.random()
                    fn(**kwargs)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    return given, settings, st


def floats_property(n_examples=150, seed=20260808, **ranges):
    """``@given`` with float ranges, or a seeded-loop fallback.

    ``ranges`` maps argument names to ``(lo, hi)`` bounds.  With
    hypothesis installed the test becomes a ``@given`` property; without
    it the same predicate runs over ``n_examples`` deterministic uniform
    draws.
    """

    def deco(fn):
        if HAVE_HYPOTHESIS:
            strats = {k: _h_st.floats(min_value=lo, max_value=hi,
                                      allow_nan=False, allow_infinity=False)
                      for k, (lo, hi) in ranges.items()}
            return _h_settings(max_examples=n_examples,
                               deadline=None)(_h_given(**strats)(fn))

        def runner():
            rng = np.random.default_rng(seed)
            for _ in range(n_examples):
                fn(**{k: float(rng.uniform(lo, hi))
                      for k, (lo, hi) in ranges.items()})

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
