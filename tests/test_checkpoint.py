"""Checkpoint store: crash-mid-save atomicity, retention, elastic restore.

``repro.checkpoint.store`` is the durability layer under the resumable
mega-sweep (``shard_sweep(checkpoint_dir=...)``), so these tests pin the
properties that resume correctness rests on:

  * a crash at ANY point mid-save leaves the previous checkpoint as the
    visible latest -- partial ``step_*.tmp`` dirs are never listed, and a
    retried save of the same step clobbers the stale tmp;
  * ``restore`` fails loudly on a structure mismatch instead of silently
    mis-assigning leaves;
  * ``retain`` garbage-collects oldest-first and never touches tmp dirs;
  * ``AsyncCheckpointer`` surfaces worker-thread errors on the next call
    rather than swallowing them;
  * leaves stored unsharded restore onto a *different* mesh shape
    (8 -> 4 devices, subprocess with forced host devices) -- the elastic
    path a resumed sweep uses after losing half its slice.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree():
    return {"app_idx": np.arange(4, dtype=np.int64),
            "app_min": np.linspace(0.1, 0.4, 4),
            "survivors": np.array([0, 7, 63], dtype=np.int64)}


# --------------------------------------------------------------------------- #
# crash-mid-save atomicity
# --------------------------------------------------------------------------- #


def test_partial_tmp_without_manifest_is_invisible(tmp_path):
    """Crash after some leaf .npy writes but before the manifest: the tmp
    dir must not count as a checkpoint and the previous step stays latest."""
    store.save(str(tmp_path), 1, _tree(), extra={"completed_shards": 1})
    crashed = tmp_path / "step_00000002.tmp"
    crashed.mkdir()
    np.save(crashed / "leaf_00000.npy", np.zeros(3))  # partial write
    assert store.latest_step(str(tmp_path)) == 1
    restored, extra = store.restore(str(tmp_path), _tree())
    assert extra["step"] == 1 and extra["completed_shards"] == 1
    np.testing.assert_array_equal(restored["app_idx"], _tree()["app_idx"])


def test_tmp_with_full_manifest_is_still_invisible(tmp_path):
    """Crash between manifest write and the atomic rename: even a COMPLETE
    tmp dir is ignored until the rename commits it."""
    store.save(str(tmp_path), 3, _tree())
    final = store.save(str(tmp_path), 4, _tree())
    os.rename(final, final + ".tmp")  # un-commit step 4
    assert store.latest_step(str(tmp_path)) == 3


def test_retried_save_clobbers_stale_tmp(tmp_path):
    """A restarted process re-saving the step a crash interrupted must
    succeed (the stale tmp is removed, not collided with)."""
    stale = tmp_path / "step_00000002.tmp"
    stale.mkdir()
    (stale / "leaf_00000.npy").write_bytes(b"garbage")
    store.save(str(tmp_path), 2, _tree(), extra={"retry": True})
    assert store.latest_step(str(tmp_path)) == 2
    _, extra = store.restore(str(tmp_path), _tree())
    assert extra["retry"] is True
    assert not stale.exists()


def test_resave_same_step_overwrites(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 5, t, extra={"gen": 1})
    t2 = dict(t, app_min=t["app_min"] + 1.0)
    store.save(str(tmp_path), 5, t2, extra={"gen": 2})
    restored, extra = store.restore(str(tmp_path), t)
    assert extra["gen"] == 2
    np.testing.assert_array_equal(restored["app_min"], t2["app_min"])


# --------------------------------------------------------------------------- #
# restore semantics
# --------------------------------------------------------------------------- #


def test_restore_structure_mismatch_fails_loudly(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    with pytest.raises(AssertionError, match="leaves"):
        store.restore(str(tmp_path), {"only_one": np.zeros(2)})


def test_restore_specific_step_and_missing_dir(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 1, t, extra={"tag": "a"})
    store.save(str(tmp_path), 2, dict(t, app_min=t["app_min"] * 2),
               extra={"tag": "b"})
    _, extra = store.restore(str(tmp_path), t, step=1)
    assert extra["tag"] == "a" and extra["step"] == 1
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        store.restore(str(tmp_path / "nope"), t)


def test_bfloat16_roundtrip(tmp_path):
    """bf16 leaves ride the uint16-view path and restore bit-exact."""
    t = {"w": jnp.linspace(-2, 2, 16).astype(jnp.bfloat16)}
    store.save(str(tmp_path), 1, t)
    restored, _ = store.restore(str(tmp_path), t)
    assert np.asarray(restored["w"]).dtype == np.asarray(t["w"]).dtype
    np.testing.assert_array_equal(
        np.asarray(restored["w"], np.float32), np.asarray(t["w"], np.float32))


def test_retain_keeps_newest_and_ignores_tmp(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        store.save(str(tmp_path), s, t)
    (tmp_path / "step_00000099.tmp").mkdir()
    store.retain(str(tmp_path), keep=2)
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004", "step_00000099.tmp"]
    assert store.latest_step(str(tmp_path)) == 4


def test_async_checkpointer_propagates_worker_errors(tmp_path):
    """The worker thread's failure must surface on the next wait()/save(),
    not vanish -- a silently-lost checkpoint breaks resume guarantees."""
    blocker = tmp_path / "occupied"
    blocker.write_text("not a directory")
    ck = store.AsyncCheckpointer(str(blocker), keep=2)
    ck.save(1, {"w": np.ones(2)})
    with pytest.raises(OSError):
        ck.wait()
    # the error is consumed; the checkpointer is reusable afterwards
    ck.directory = str(tmp_path)
    ck.save(2, {"w": np.ones(2)})
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 2


# --------------------------------------------------------------------------- #
# the mega-sweep customer
# --------------------------------------------------------------------------- #


def test_shard_sweep_checkpoints_are_store_readable(tmp_path):
    """shard_sweep's per-shard saves go through this store: the latest
    step equals the shard count, the state tree restores with the
    documented structure, and retention bounds the directory size."""
    from repro.core.sweep import shard_sweep
    from test_sweep import random_profiles

    profiles = random_profiles(3, seed=17)
    sharded = shard_sweep(profiles, n=64, num_shards=4,
                          checkpoint_dir=str(tmp_path), checkpoint_keep=2)
    assert store.latest_step(str(tmp_path)) == 4
    tree_like = {"app_idx": np.zeros(3, np.int64),
                 "app_min": np.zeros(3),
                 "survivors": np.zeros(0, np.int64)}
    state, extra = store.restore(str(tmp_path), tree_like)
    assert extra["completed_shards"] == 4
    assert extra["num_shards"] == 4 and extra["num_variants"] == 64
    # the final checkpoint's per-app argmins ARE the sweep's best fits
    for i, app in enumerate(p.name for p in profiles):
        idx = int(state["app_idx"][i])
        assert sharded.best_fit_map[app] == sharded.result.machines.names[
            list(sharded.candidate_indices).index(idx)]
    steps = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
    assert len(steps) == 2  # checkpoint_keep pruned shards 1-2


def test_elastic_restore_8_to_4_devices():
    """Sweep state saved under an 8-device variants mesh restores onto a
    4-device mesh (leaves are stored gathered).  Forced host devices must
    precede jax import, so this runs in a subprocess."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import store
        from repro.launch import mesh as MESH

        mesh8 = MESH.make_variant_mesh()
        assert mesh8.devices.size == 8
        tree = {"app_min": jnp.linspace(0.1, 0.8, 8),
                "agg": jnp.arange(64, dtype=jnp.float32)}
        ref_min = np.asarray(tree["app_min"])
        sh8 = {"app_min": NamedSharding(mesh8, P("variants")),
               "agg": NamedSharding(mesh8, P("variants"))}
        tree = jax.tree.map(jax.device_put, tree, sh8)
        d = tempfile.mkdtemp()
        store.save(d, 7, tree, extra={"completed_shards": 7})

        mesh4 = MESH.make_variant_mesh(num_devices=4)
        sh4 = {"app_min": NamedSharding(mesh4, P("variants")),
               "agg": NamedSharding(mesh4, P("variants"))}
        restored, extra = store.restore(d, tree, shardings=sh4)
        assert extra["step"] == 7 and extra["completed_shards"] == 7
        np.testing.assert_array_equal(np.asarray(restored["app_min"]),
                                      ref_min)
        np.testing.assert_array_equal(np.asarray(restored["agg"]),
                                      np.arange(64))
        assert restored["agg"].sharding.mesh.devices.size == 4
        print("ELASTIC-SWEEP-OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ELASTIC-SWEEP-OK" in out.stdout
