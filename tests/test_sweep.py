"""Sweep engine: batched == scalar equivalence, population generators,
Pareto invariants, and the lazy DSE table.

The batched kernels in ``repro.core.sweep`` re-implement the scalar timing +
Eq. 1 pipeline as (A, V) array ops; these tests pin them to the scalar
reference (``profile_congruence`` / ``evaluate(method="scalar")``) to within
1e-9, which is what licenses the fast path as the ``evaluate()`` default.
"""

import random

import numpy as np
import pytest

from repro.core import (
    ALL_SUBSYSTEMS,
    MachineModel,
    TPU_V5E,
    VARIANTS,
    WorkloadProfile,
    profile_congruence,
)
from repro.core.congruence import default_beta
from repro.core.dse import DseTable, LazyDseTable, evaluate
from repro.core.sweep import (
    Dim,
    MachineBatch,
    ParamSpace,
    ProfileBatch,
    batched_congruence,
    batched_step_time,
    halton,
    run_sweep,
)
from repro.core.timing import step_time, subsystem_times

RTOL = 1e-9


def random_profiles(n, seed=0):
    rng = random.Random(seed)
    out = []
    for i in range(n):
        p = WorkloadProfile(
            name=f"app{i}",
            flops=10 ** rng.uniform(9, 15),
            hbm_bytes=10 ** rng.uniform(6, 12),
            bytes_accessed=10 ** rng.uniform(6, 12),
            collective_bytes={
                "all-reduce": 10 ** rng.uniform(6, 12),
                "all-gather": 10 ** rng.uniform(5, 11),
            },
            num_devices=rng.choice([1, 8, 256]),
            model_flops=(10 ** rng.uniform(12, 18)
                         if rng.random() < 0.8 else 0.0),
        )
        if i % 3 == 0:
            p.pod_collective_bytes = 0.3 * p.total_collective_bytes
        if i % 5 == 0:
            p.hbm_bytes = 0.0  # exercise the bytes_accessed fallback
        out.append(p)
    return out


def candidate_machines(n=24, seed=1):
    return MachineBatch.concat(
        MachineBatch.from_models(VARIANTS),
        ParamSpace.default().sample(n, seed=seed))


# --------------------------------------------------------------------------- #
# batched vs scalar equivalence (the ISSUE's 1e-9 property)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("timing_model", ["serial", "overlap"])
@pytest.mark.parametrize("clamp", [False, True])
def test_batched_matches_scalar(timing_model, clamp):
    profiles = random_profiles(6, seed=3)
    machines = candidate_machines(24, seed=1)
    res = batched_congruence(
        profiles, machines, timing_model=timing_model, clamp=clamp)
    for a, p in enumerate(profiles):
        beta = default_beta(p, machines.model(0))
        assert res.beta[a] == pytest.approx(beta, rel=RTOL)
        for v in range(len(machines)):
            rep = profile_congruence(
                p, machines.model(v), beta=beta,
                timing_model=timing_model, clamp=clamp)
            assert res.gamma[a, v] == pytest.approx(rep.gamma, rel=RTOL)
            for sub, alpha in rep.alphas.items():
                assert res.alphas[sub][a, v] == pytest.approx(alpha, rel=RTOL)
            for k, s in rep.scores.items():
                assert res.scores[k][a, v] == pytest.approx(
                    s, rel=RTOL, abs=RTOL)
            assert res.aggregate[a, v] == pytest.approx(
                rep.aggregate, rel=RTOL, abs=RTOL)


def test_batched_step_time_matches_scalar():
    profiles = random_profiles(5, seed=7)
    machines = candidate_machines(16, seed=2)
    for tm in ("serial", "overlap"):
        t = batched_step_time(profiles, machines, timing_model=tm)
        for a, p in enumerate(profiles):
            for v in range(len(machines)):
                assert t[a, v] == pytest.approx(
                    step_time(p, machines.model(v), tm), rel=RTOL)


def test_explicit_beta_forms():
    profiles = random_profiles(4, seed=11)
    machines = candidate_machines(8, seed=4)
    scalar = batched_congruence(profiles, machines, beta=0.0)
    assert np.all(scalar.beta == 0.0)
    per_app = np.array([1e-4, 2e-4, 3e-4, 4e-4])
    res = batched_congruence(profiles, machines, beta=per_app)
    for a, p in enumerate(profiles):
        rep = profile_congruence(p, machines.model(2), beta=per_app[a])
        assert res.aggregate[a, 2] == pytest.approx(rep.aggregate, rel=RTOL)


@pytest.mark.parametrize("timing_model", ["serial", "overlap"])
@pytest.mark.parametrize("beta_frac", [0.0, 0.5, 0.9, 2.0])
@pytest.mark.parametrize("clamp", [False, True])
def test_clamp_semantics_scalar_equals_batched(timing_model, beta_frac, clamp):
    """Clamp pin (one kernel, one semantic): scalar and batched must agree
    cell-for-cell for every clamp setting, including betas that push raw
    Eq. 1 scores above 1 (beta between alpha and gamma) and below 0
    (beta > gamma, negative denominator)."""
    profiles = random_profiles(4, seed=31)
    machines = candidate_machines(10, seed=6)
    gamma0 = np.array([step_time(p, machines.model(0), timing_model)
                       for p in profiles])
    beta = beta_frac * gamma0
    res = batched_congruence(profiles, machines, beta=beta,
                             timing_model=timing_model, clamp=clamp)
    saw_out_of_unit = False
    for a, p in enumerate(profiles):
        for v in range(len(machines)):
            rep = profile_congruence(p, machines.model(v), beta=beta[a],
                                     timing_model=timing_model, clamp=clamp)
            for k, s in rep.scores.items():
                if clamp:
                    assert 0.0 <= s <= 1.0
                elif s < 0.0 or s > 1.0:
                    saw_out_of_unit = True
                assert res.scores[k][a, v] == pytest.approx(
                    s, rel=RTOL, abs=RTOL)
            assert res.aggregate[a, v] == pytest.approx(
                rep.aggregate, rel=RTOL, abs=RTOL)
    if not clamp and beta_frac in (0.9, 2.0):
        assert saw_out_of_unit, "fixture must exercise scores outside [0, 1]"


def test_clamp_applies_to_extended_decomposition():
    """A clamped report is clamped throughout, including §II-B sub-scores."""
    p = random_profiles(1, seed=33)[0]
    gamma = step_time(p, TPU_V5E)
    rep = profile_congruence(p, TPU_V5E, beta=2.0 * gamma, clamp=True)
    assert all(0.0 <= v <= 1.0 for v in rep.scores.values())
    assert all(0.0 <= v <= 1.0 for v in rep.extended.values())
    raw = profile_congruence(p, TPU_V5E, beta=2.0 * gamma, clamp=False)
    assert any(v < 0.0 or v > 1.0 for v in raw.extended.values())


def test_default_beta_accepts_threaded_baseline():
    """Satellite fix: the baseline TimingBreakdown is shared, not recomputed
    -- passing it explicitly must be an exact no-op."""
    for p in random_profiles(4, seed=35):
        baseline = subsystem_times(p, TPU_V5E)
        assert default_beta(p, TPU_V5E, baseline=baseline) \
            == default_beta(p, TPU_V5E)


def test_degenerate_gamma_equals_beta_scores_zero():
    p = random_profiles(1)[0]
    machines = MachineBatch.from_models(VARIANTS)
    gamma = step_time(p, VARIANTS[0])
    res = batched_congruence([p], machines, beta=gamma)
    for k in ("ICS", "HRCS", "LBCS"):
        assert np.isfinite(res.scores[k][0, 0])
    assert res.scores["ICS"][0, 0] == 0.0 or res.gamma[0, 0] != gamma


# --------------------------------------------------------------------------- #
# evaluate(): lazy table == eager table
# --------------------------------------------------------------------------- #


def test_evaluate_batched_equals_scalar_table():
    profiles = random_profiles(5, seed=5)
    suites = {"even": [p.name for p in profiles[::2]],
              "odd": [p.name for p in profiles[1::2]]}
    lazy = evaluate(profiles, suites=suites, method="batched")
    eager = evaluate(profiles, suites=suites, method="scalar")
    assert isinstance(lazy, LazyDseTable) and isinstance(eager, DseTable)
    assert lazy.apps == eager.apps
    assert lazy.variants == eager.variants
    for app in eager.apps:
        assert lazy.best_fit(app) == eager.best_fit(app)
        for v in eager.variants:
            assert lazy.cell(app, v).aggregate == pytest.approx(
                eager.cell(app, v).aggregate, rel=RTOL, abs=RTOL)
    for suite in suites:
        for v in eager.variants:
            assert lazy.suite_mean(suite, v) == pytest.approx(
                eager.suite_mean(suite, v), rel=RTOL)
        assert lazy.suite_best_fit(suite) == eager.suite_best_fit(suite)
    assert lazy.overall_best_fit() == eager.overall_best_fit()
    # identical rendering, including per-cell extended reports on demand
    assert lazy.markdown() == eager.markdown()
    assert lazy.radar_markdown() == eager.radar_markdown()
    a, v = eager.apps[0], eager.variants[0]
    assert (lazy.cell(a, v).report.extended.keys()
            == eager.cell(a, v).report.extended.keys())


def test_evaluate_default_is_batched_and_auto():
    profiles = random_profiles(3, seed=9)
    assert isinstance(evaluate(profiles), LazyDseTable)
    assert isinstance(evaluate(profiles, method="auto"), LazyDseTable)
    with pytest.raises(ValueError):
        evaluate(profiles, method="bogus")


def test_evaluate_accepts_machine_batch():
    profiles = random_profiles(3, seed=13)
    machines = ParamSpace.default().sample(10, seed=3)
    lazy = evaluate(profiles, variants=machines)
    eager = evaluate(profiles, variants=machines, method="scalar")
    for app in eager.apps:
        assert lazy.best_fit(app) == eager.best_fit(app)


def test_lazy_cells_materialize_on_demand():
    profiles = random_profiles(2, seed=15)
    lazy = evaluate(profiles)
    assert not lazy._cell_cache
    c = lazy.cell(profiles[0].name, "baseline")
    assert c.report.name == profiles[0].name
    assert len(lazy._cell_cache) == 1
    assert c is lazy.cell(profiles[0].name, "baseline")  # cached
    assert len(lazy.cells) == len(profiles) * len(VARIANTS)


# --------------------------------------------------------------------------- #
# population generators
# --------------------------------------------------------------------------- #


def test_halton_is_low_discrepancy_and_deterministic():
    pts = halton(256, 5, seed=0)
    assert pts.shape == (256, 5)
    assert np.all((pts >= 0.0) & (pts < 1.0))
    # every dimension covers the unit interval reasonably evenly
    for j in range(5):
        hist, _ = np.histogram(pts[:, j], bins=8, range=(0, 1))
        assert hist.min() >= 16  # perfectly uniform would be 32
    assert np.array_equal(pts, halton(256, 5, seed=0))
    assert not np.array_equal(pts, halton(256, 5, seed=1))


def test_param_space_sample_bounds():
    space = ParamSpace.default(span=4.0, max_links=8)
    batch = space.sample(128, seed=2)
    assert len(batch) == 128
    for name, dim in space.dims.items():
        vals = getattr(batch, name)
        assert np.all(vals >= dim.lo) and np.all(vals <= dim.hi), name
    assert np.array_equal(batch.ici_links, np.rint(batch.ici_links))
    # unswept params pinned at nominal
    assert np.all(batch.scale_compute == 1.0)


def test_param_space_grid_cross_product():
    space = ParamSpace.default()
    batch = space.grid({"peak_flops": 3, "hbm_bw": 2, "ici_links": 4})
    links = space.dims["ici_links"].points(4)
    assert len(batch) == 3 * 2 * len(links)
    assert len({(f, h, l) for f, h, l in
                zip(batch.peak_flops, batch.hbm_bw, batch.ici_links)}) \
        == len(batch)


def test_dim_points_and_unit_mapping():
    d = Dim(1.0, 100.0, log=True)
    pts = d.points(3)
    assert pts == pytest.approx([1.0, 10.0, 100.0])
    di = Dim(1, 4, log=False, integer=True)
    vals = di.from_unit(np.linspace(0.0, 0.999, 64))
    assert set(vals) == {1.0, 2.0, 3.0, 4.0}


def test_machine_batch_roundtrip():
    batch = MachineBatch.from_models(VARIANTS)
    for i, m in enumerate(VARIANTS):
        back = batch.model(i)
        assert back.name == m.name
        assert back.peak_flops == m.peak_flops
        assert back.hbm_bw == m.hbm_bw
        assert back.ici_bw_total == m.ici_bw_total
    assert batch.area()[0] == pytest.approx(1.0)  # baseline vs itself


def test_profile_batch_mem_fallback():
    p = random_profiles(1)[0]
    p.hbm_bytes = 0.0
    p.bytes_accessed = 123.0
    pb = ProfileBatch.from_profiles([p])
    assert pb.mem_bytes[0] == 123.0


# --------------------------------------------------------------------------- #
# extractions: best fit + Pareto front
# --------------------------------------------------------------------------- #


def test_pareto_front_has_no_dominated_point():
    profiles = random_profiles(6, seed=21)
    res = run_sweep(profiles, n=200, seed=4, include_named=VARIANTS)
    area, agg = res.area(), res.aggregate_mean()
    front = res.pareto_front()
    assert front, "front must be non-empty"
    assert area[front] == pytest.approx(sorted(area[front]))  # sorted by area
    for i in front:
        dominated = ((area <= area[i]) & (agg <= agg[i])
                     & ((area < area[i]) | (agg < agg[i])))
        assert not dominated.any(), f"front point {i} is dominated"
    # the global congruence optimum is always on the front
    assert int(np.argmin(agg)) in front


def test_best_fit_matches_argmin():
    profiles = random_profiles(4, seed=23)
    res = batched_congruence(profiles, candidate_machines(12), clamp=True)
    for a, p in enumerate(profiles):
        v = int(np.argmin(res.aggregate[a]))
        assert res.best_fit(p.name) == res.machines.names[v]


def test_pareto_front_3d_has_no_dominated_point():
    profiles = random_profiles(5, seed=27)
    res = run_sweep(profiles, n=150, seed=6, include_named=VARIANTS)
    agg = res.aggregate_mean()
    area = np.asarray(res.area())
    power = np.asarray(res.power())
    front = res.pareto_front_3d()
    assert front, "3-D front must be non-empty"
    assert area[front] == pytest.approx(sorted(area[front]))
    for i in front:
        dominated = ((area <= area[i]) & (agg <= agg[i]) & (power <= power[i])
                     & ((area < area[i]) | (agg < agg[i]) | (power < power[i])))
        assert not dominated.any(), f"3-D front point {i} is dominated"
    # every non-front point is dominated by someone (front completeness)
    for i in set(range(len(res.machines))) - set(front):
        dominated = ((area <= area[i]) & (agg <= agg[i]) & (power <= power[i])
                     & ((area < area[i]) | (agg < agg[i]) | (power < power[i])))
        assert dominated.any(), f"non-front point {i} is non-dominated"


def test_sweep_result_reports():
    profiles = random_profiles(3, seed=25)
    res = run_sweep(profiles, n=20, include_named=VARIANTS)
    md = res.markdown(top_k=5)
    assert "pareto front" in md and "mean aggregate" in md
    assert "power" in md and "3-D pareto front" in md
    blob = res.to_json(top_k=5)
    assert blob["num_variants"] == 23
    assert set(blob["best_fit"]) == {p.name for p in profiles}
    assert len(blob["top_variants"]) == 5
    assert blob["backend"] in ("numpy", "jax")
    assert blob["pareto_front_3d"], "3-D front serialized"
    import json
    json.dumps(blob)  # fully serializable


# --------------------------------------------------------------------------- #
# per-subsystem scale_* sweeps (degradation analysis)
# --------------------------------------------------------------------------- #


def scale_space(span=4.0):
    """Degradation sweep: rate dims plus the per-subsystem delay scale_*
    dims UNpinned -- now the ``ParamSpace.scale_space`` preset (pinned
    further in tests/test_genload.py)."""
    return ParamSpace.scale_space(span=span, scale_span=4.0)


def test_scale_dims_sample_and_vary():
    batch = scale_space().sample(64, seed=8)
    for name in ("scale_compute", "scale_memory", "scale_interconnect"):
        vals = getattr(batch, name)
        assert np.all((vals >= 0.25) & (vals <= 4.0))
        assert len(np.unique(vals)) > 8, f"{name} must actually vary"


@pytest.mark.parametrize("timing_model", ["serial", "overlap"])
def test_scale_sweep_batched_matches_scalar(timing_model):
    """Degradation sweep equivalence: with all scale_* dims unpinned, the
    batched path must still match the scalar with_scales path to 1e-9."""
    profiles = random_profiles(4, seed=41)
    machines = scale_space().sample(16, seed=9)
    res = batched_congruence(profiles, machines, timing_model=timing_model)
    for a, p in enumerate(profiles):
        beta = default_beta(p, machines.model(0))
        for v in range(len(machines)):
            m = machines.model(v)
            # the materialized model carries the sampled non-default scales
            scales = [m.scale_for(s) for s in ALL_SUBSYSTEMS]
            assert any(abs(x - 1.0) > 1e-6 for x in scales)
            rep = profile_congruence(p, m, beta=beta,
                                     timing_model=timing_model)
            assert res.gamma[a, v] == pytest.approx(rep.gamma, rel=RTOL)
            for k, s in rep.scores.items():
                assert res.scores[k][a, v] == pytest.approx(
                    s, rel=RTOL, abs=RTOL)


def test_machine_model_json_roundtrip_with_scales():
    m = TPU_V5E.with_scales(compute=1.3, memory=0.7, interconnect=2.5)
    back = MachineModel.from_json(m.to_json())
    assert back == m
    # and through a sampled batch: model(i) -> json -> model survives
    batch = scale_space().sample(4, seed=10)
    for i in range(len(batch)):
        v = batch.model(i)
        assert MachineModel.from_json(v.to_json()) == v


def test_machine_model_with_rates():
    m = TPU_V5E.with_scales(memory=0.7).with_rates(
        name="tweaked", peak_flops=2 * TPU_V5E.peak_flops, ici_links=3.6)
    assert m.name == "tweaked"
    assert m.peak_flops == 2 * TPU_V5E.peak_flops
    assert m.ici_links == 4  # rounded to int
    assert m.hbm_bw == TPU_V5E.hbm_bw  # untouched rates preserved
    assert m.scale["memory"] == 0.7    # scales preserved
    with pytest.raises(KeyError):
        TPU_V5E.with_rates(bogus=1.0)


# --------------------------------------------------------------------------- #
# shard_sweep: sharded mega-sweeps must reproduce the single-device answer
# --------------------------------------------------------------------------- #


def _front_names(res):
    return ([res.machines.names[i] for i in res.pareto_front()],
            [res.machines.names[i] for i in res.pareto_front_3d()])


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_shard_sweep_matches_run_sweep(backend):
    """ISSUE acceptance: shard_sweep produces the same Pareto fronts and
    best fits as a single-device run_sweep over the identical population.
    backend="jax" exercises the NamedSharding mesh path (1-device mesh on
    CI); backend="numpy" the chunked shard loop."""
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(4, seed=5)
    single = run_sweep(profiles, n=150, include_named=VARIANTS,
                       backend=backend)
    sharded = shard_sweep(profiles, n=150, include_named=VARIANTS,
                          backend=backend, num_shards=4)
    f2, f3 = _front_names(single)
    sf2, sf3 = _front_names(sharded.result)
    assert sharded.pareto_names() == sf2 == f2
    assert sf3 == f3
    for app in single.apps:
        assert sharded.best_fit(app) == single.best_fit(app)
    # pre-filtering actually filtered, and survivors are scored identically
    assert sharded.num_variants == len(single.machines)
    assert 0 < len(sharded.result.machines) < sharded.num_variants
    np.testing.assert_allclose(
        sharded.result.aggregate,
        single.aggregate[:, sharded.candidate_indices], rtol=1e-12)


def test_shard_sweep_single_shard_and_reports():
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(3, seed=21)
    single = run_sweep(profiles, n=64)
    sharded = shard_sweep(profiles, n=64, num_shards=1)
    assert sharded.num_shards == 1
    assert sharded.pareto_names() == [
        single.machines.names[i] for i in single.pareto_front()]
    md = sharded.markdown(top_k=4)
    assert md.startswith("sharded sweep: 64 variants across 1 shards")
    blob = sharded.to_json(top_k=4)
    assert blob["num_variants"] == 64
    assert blob["num_shards"] == 1
    assert blob["num_candidates"] == len(sharded.result.machines)
    assert set(blob["best_fit"]) == set(sharded.apps)


def test_shard_sweep_pallas_backend():
    """The fused f32 backend shards too; fronts are checked for set-level
    agreement with its own single-device pass (bitwise within backend)."""
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(3, seed=8)
    single = run_sweep(profiles, n=96, backend="pallas")
    sharded = shard_sweep(profiles, n=96, backend="pallas", num_shards=3)
    assert sharded.pareto_names() == [
        single.machines.names[i] for i in single.pareto_front()]
    for app in single.apps:
        assert sharded.best_fit(app) == single.best_fit(app)


def test_shard_bounds_cover_and_balance():
    from repro.core.sweep import _shard_bounds

    for v, s in [(10, 3), (7, 7), (5, 2), (1, 1), (128, 4)]:
        bounds = _shard_bounds(v, s)
        assert bounds[0][0] == 0 and bounds[-1][1] == v
        sizes = [hi - lo for lo, hi in bounds]
        assert sum(sizes) == v
        assert max(sizes) - min(sizes) <= 1
        for (_, hi), (lo, _) in zip(bounds, bounds[1:]):
            assert hi == lo


def test_shard_sweep_custom_cost_model_front_complete():
    """Fronts are extracted under the SAME cost model the shards were
    pre-filtered with (stored on the result), so reweighted sweeps stay
    front-complete vs the single-device reference."""
    from repro.core.costmodel import CostModel
    from repro.core.sweep import (pareto_front_indices, shard_sweep)

    cm = CostModel(area_weights={"peak_flops": 4.0, "hbm_bw": 1.0,
                                 "ici_bw_total": 0.5, "inter_pod_bw": 0.5})
    profiles = random_profiles(3, seed=31)
    single = run_sweep(profiles, n=120)
    sharded = shard_sweep(profiles, n=120, num_shards=5, cost_model=cm)
    # single-device reference fronts under the same custom model
    ref2 = [single.machines.names[i] for i in pareto_front_indices(
        cm.area(single.machines), single.aggregate_mean())]
    ref3 = [single.machines.names[i] for i in single.pareto_front_3d(cm)]
    assert sharded.pareto_names() == ref2
    assert [sharded.result.machines.names[i]
            for i in sharded.pareto_front_3d()] == ref3
    assert sharded.cost_model is cm


def test_shard_sweep_multidevice_pad_masking():
    """Regression: on a multi-device mesh with V not divisible by the
    device count, the benign all-1.0 pad machines must never win an app's
    argmin in the sharded jax statistics pass.  Needs a forced 8-device
    host, so it runs in a subprocess (XLA_FLAGS must precede jax import).
    """
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        from repro.core import WorkloadProfile, run_sweep, shard_sweep
        # interconnect-dominated profile: makes cheap pad machines look good
        apps = [WorkloadProfile(name="app0", flops=1e10, hbm_bytes=1e9,
                                collective_bytes={"all-reduce": 5e13},
                                num_devices=256, model_flops=1e12)]
        sharded = shard_sweep(apps, n=1001, backend="jax")   # 1001 % 8 != 0
        single = run_sweep(apps, n=1001, backend="jax")
        assert sharded.best_fit("app0") == single.best_fit("app0"), (
            sharded.best_fit("app0"), single.best_fit("app0"))
        assert sharded.pareto_names() == [
            single.machines.names[i] for i in single.pareto_front()]
        print("OK", sharded.num_shards)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    env.pop("REPRO_SWEEP_BACKEND", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", code], cwd=root, env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert proc.stdout.startswith("OK 8")


# --------------------------------------------------------------------------- #
# streamed populations + resumable mega-sweeps
# --------------------------------------------------------------------------- #


def _assert_batch_equal(a, b):
    from repro.core.sweep import SWEEP_PARAMS

    assert a.names == b.names
    for field in SWEEP_PARAMS:
        np.testing.assert_array_equal(getattr(a, field), getattr(b, field))


@pytest.mark.parametrize("mode", ["random", "grid"])
def test_population_stream_matches_materialized(mode):
    """Index-addressed regeneration: any batch()/take() of the stream is
    byte-identical to slicing the materialized population -- the property
    that makes streamed sweep results exact, not approximate."""
    from repro.core.sweep import PopulationStream, _population

    space = ParamSpace.default()
    stream = PopulationStream(space, 200, mode=mode, seed=5,
                              include_named=VARIANTS)
    full = _population(space, 200, mode, 5, VARIANTS)
    assert len(stream) == len(full)
    _assert_batch_equal(stream.materialize(), full)
    # shard spanning the named/generated boundary, plus interior shards
    for lo, hi in [(0, 7), (1, 40), (50, 120), (len(full) - 9, len(full))]:
        _assert_batch_equal(stream.batch(lo, hi), full.slice(lo, hi))
    # arbitrary gather mixing named + generated rows (the survivor path)
    idx = np.array([0, 2, 17, 5, 100, 1, len(full) - 1])
    _assert_batch_equal(stream.take(idx), full.take(idx))


def test_save_load_population_roundtrip(tmp_path):
    from repro.core.sweep import (PopulationStream, _population,
                                  load_population, save_population)

    space = ParamSpace.default()
    full = _population(space, 150, "random", 9, VARIANTS)
    save_population(str(tmp_path / "pop"), full, shard_size=64)
    loaded = load_population(str(tmp_path / "pop"))
    assert len(loaded) == len(full)
    _assert_batch_equal(loaded.materialize(), full)
    _assert_batch_equal(loaded.batch(10, 90), full.slice(10, 90))
    _assert_batch_equal(loaded.take([3, 77, 0, 149]),
                        full.take([3, 77, 0, 149]))
    assert loaded.signature().startswith("mmap:")
    # saving a STREAM (not a batch) never materializes but writes the same
    stream = PopulationStream(space, 150, seed=9, include_named=VARIANTS)
    save_population(str(tmp_path / "pop2"), stream, shard_size=32)
    _assert_batch_equal(load_population(str(tmp_path / "pop2")).materialize(),
                        full)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_streamed_shard_sweep_byte_identical(backend):
    """ISSUE acceptance: stream=True changes memory behavior, not results.
    Candidates, fronts, best fits and aggregates match the materialized
    shard_sweep AND run_sweep bit for bit."""
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(4, seed=5)
    kw = dict(n=150, include_named=VARIANTS, backend=backend, num_shards=5)
    materialized = shard_sweep(profiles, **kw)
    streamed = shard_sweep(profiles, stream=True, **kw)
    assert streamed.streamed and not materialized.streamed
    np.testing.assert_array_equal(streamed.candidate_indices,
                                  materialized.candidate_indices)
    assert streamed.result.machines.names == materialized.result.machines.names
    np.testing.assert_array_equal(streamed.result.aggregate,
                                  materialized.result.aggregate)
    assert streamed.pareto_names() == materialized.pareto_names()
    assert streamed.best_fit_map == materialized.best_fit_map
    single = run_sweep(profiles, n=150, include_named=VARIANTS,
                       backend=backend)
    assert streamed.pareto_names() == [
        single.machines.names[i] for i in single.pareto_front()]
    for app in single.apps:
        assert streamed.best_fit(app) == single.best_fit(app)


def test_mmap_population_sweep_matches_generated(tmp_path):
    from repro.core.sweep import load_population, save_population, shard_sweep

    profiles = random_profiles(3, seed=19)
    direct = shard_sweep(profiles, n=96, num_shards=3)
    save_population(str(tmp_path / "pop"),
                    run_sweep(profiles, n=96).machines)
    via_mmap = shard_sweep(profiles, population=load_population(
        str(tmp_path / "pop")), num_shards=3)
    assert via_mmap.streamed
    assert via_mmap.pareto_names() == direct.pareto_names()
    assert via_mmap.best_fit_map == direct.best_fit_map
    np.testing.assert_array_equal(via_mmap.result.aggregate,
                                  direct.result.aggregate)


def _sharded_equal(a, b):
    np.testing.assert_array_equal(a.candidate_indices, b.candidate_indices)
    assert a.result.machines.names == b.result.machines.names
    np.testing.assert_array_equal(a.result.aggregate, b.result.aggregate)
    assert a.pareto_names() == b.pareto_names()
    assert a.best_fit_map == b.best_fit_map


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_resumed_sweep_identical_to_uninterrupted(tmp_path, backend):
    """ISSUE acceptance: kill after shard k, resume -> byte-identical
    result, with resumed_shards reporting the skipped prefix."""
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(3, seed=29)
    kw = dict(n=120, stream=True, num_shards=6, backend=backend,
              checkpoint_dir=str(tmp_path / "ck"))

    class Kill(Exception):
        pass

    def die_after_2(s, num_shards, lo, hi):
        if s >= 2:
            raise Kill

    with pytest.raises(Kill):
        shard_sweep(profiles, progress=die_after_2, **kw)
    events = []
    resumed = shard_sweep(profiles, resume=True,
                          progress=lambda s, n_, lo, hi:
                          events.append(s), **kw)
    assert resumed.resumed_shards == 3   # shards 0-2 checkpointed pre-raise
    assert events == [3, 4, 5]           # only the remaining shards ran
    straight = shard_sweep(profiles, n=120, stream=True, num_shards=6,
                           backend=backend)
    assert straight.resumed_shards == 0
    _sharded_equal(resumed, straight)
    # markdown/json agree modulo the resume being invisible in the result
    assert resumed.markdown(top_k=4) == straight.markdown(top_k=4)


def test_resume_refuses_config_mismatch(tmp_path):
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(2, seed=3)
    shard_sweep(profiles, n=64, num_shards=4,
                checkpoint_dir=str(tmp_path / "ck"))
    with pytest.raises(ValueError, match="different sweep configuration"):
        shard_sweep(profiles, n=64, num_shards=4, seed=1, resume=True,
                    checkpoint_dir=str(tmp_path / "ck"))
    with pytest.raises(ValueError, match="requires checkpoint_dir"):
        shard_sweep(profiles, n=64, resume=True)


def test_shard_progress_events_all_backends():
    """Satellite regression: every backend (including the mesh-distributed
    jax path, which once collapsed to a single progress(0, 1, ...) call)
    emits one event per shard with covering [lo, hi) bounds."""
    from repro.core.sweep import shard_sweep

    profiles = random_profiles(2, seed=7)
    for backend in ("numpy", "jax", "pallas"):
        events = []
        shard_sweep(profiles, n=64, num_shards=4, backend=backend,
                    progress=lambda s, n_, lo, hi:
                    events.append((s, n_, lo, hi)))
        assert [e[0] for e in events] == [0, 1, 2, 3], backend
        assert all(n_ == 4 for _, n_, _lo, _hi in events)
        assert events[0][2] == 0 and events[-1][3] == 64
        for (_, _, _, hi), (_, _, lo, _) in zip(events, events[1:]):
            assert hi == lo


def test_pallas_shard_map_multidevice_streamed_resume():
    """The tentpole end to end on a forced 8-device host: ONE fused
    pallas_call under shard_map scores each chunk with the variant axis
    split over the mesh, streamed + resumed, and the result matches the
    numpy host-chunked reference exactly.  Subprocess because XLA_FLAGS
    must precede the jax import."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import numpy as np, tempfile
        from repro.core import VARIANTS, WorkloadProfile, shard_sweep

        apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
                                collective_bytes={"all-reduce": 2e10},
                                num_devices=256, model_flops=5e16),
                WorkloadProfile(name="app1", flops=8e13, hbm_bytes=4e11,
                                collective_bytes={"all-gather": 6e10},
                                num_devices=64, model_flops=1e16)]
        kw = dict(n=517, stream=True, include_named=VARIANTS, num_shards=4)
        ref = shard_sweep(apps, backend="numpy", **kw)
        pal = shard_sweep(apps, backend="pallas", **kw)
        assert pal.mesh_axis == "variants=8 mesh", pal.mesh_axis
        assert pal.pareto_names() == ref.pareto_names()
        assert pal.best_fit_map == ref.best_fit_map
        np.testing.assert_array_equal(pal.candidate_indices,
                                      ref.candidate_indices)

        d = tempfile.mkdtemp()
        class Kill(Exception):
            pass
        def die(s, n_, lo, hi):
            if s >= 1:
                raise Kill
        try:
            shard_sweep(apps, backend="pallas", checkpoint_dir=d,
                        progress=die, **kw)
        except Kill:
            pass
        resumed = shard_sweep(apps, backend="pallas", checkpoint_dir=d,
                              resume=True, **kw)
        assert resumed.resumed_shards == 2
        assert resumed.pareto_names() == pal.pareto_names()
        assert resumed.best_fit_map == pal.best_fit_map
        np.testing.assert_array_equal(resumed.result.aggregate,
                                      pal.result.aggregate)
        print("PALLAS-MEGA-OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    env.pop("REPRO_SWEEP_BACKEND", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", code], cwd=root, env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PALLAS-MEGA-OK" in proc.stdout


@pytest.mark.slow
def test_streamed_million_variant_sweep():
    """ISSUE acceptance: V = 1M streams through a single host without the
    population ever materializing (each shard holds <= 64k variants)."""
    from repro.core.sweep import STREAM_SHARD_VARIANTS, shard_sweep

    profiles = random_profiles(2, seed=1)
    events = []
    sharded = shard_sweep(profiles, n=1_000_000, stream=True,
                          progress=lambda s, n_, lo, hi:
                          events.append(hi - lo))
    assert sharded.streamed
    assert sharded.num_variants == 1_000_000
    assert max(events) <= STREAM_SHARD_VARIANTS
    assert sharded.num_shards == len(events) >= 16
    assert 0 < len(sharded.result.machines) < 5000
    assert set(sharded.best_fit_map) == {p.name for p in profiles}
    front = sharded.pareto_names()
    assert front and all(isinstance(n, str) for n in front)
