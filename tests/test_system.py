"""End-to-end behaviour tests for the paper's system.

Full pipeline on CPU: compile a real (smoke-size) train step, extract the
workload profile from the compiled artifact, compute congruence scores,
run the DSE sweep, and check the decisions are self-consistent -- the
complete paper flow (compile-once -> profile -> Eq.1 scores -> Table I).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (
    TPU_V5E,
    analyze,
    evaluate,
    profile_congruence,
    profile_from_compiled,
)
from repro.optim import adamw
from repro.training.step import init_state, make_train_step


@pytest.fixture(scope="module")
def compiled_profile():
    cfg = get_config("chatglm3-6b", smoke=True)
    oc = adamw.OptimizerConfig(warmup_steps=1, total_steps=10)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, oc)
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "labels": jnp.zeros((4, 64), jnp.int32)}
    compiled = jax.jit(make_train_step(cfg, oc)).lower(state, batch).compile()
    total, active = cfg.param_counts()
    return profile_from_compiled(
        "e2e", compiled, num_devices=1,
        model_flops=6 * active * batch["tokens"].size,
        tokens=batch["tokens"].size, params=total, params_active=active)


def test_profile_extraction_sane(compiled_profile):
    p = compiled_profile
    assert p.flops > 0
    assert p.hbm_bytes > 0
    assert p.dot_count > 0
    # single device: no collectives
    assert p.total_collective_bytes == 0


def test_congruence_full_pipeline(compiled_profile):
    rep = profile_congruence(compiled_profile, TPU_V5E)
    assert set(rep.scores) == {"ICS", "HRCS", "LBCS"}
    # single-device artifact: interconnect can't be the bottleneck
    assert rep.dominant in ("HRCS", "LBCS")
    assert rep.scores["ICS"] == pytest.approx(0.0, abs=1e-6)
    assert rep.gamma > rep.beta >= 0


def test_roofline_full_pipeline(compiled_profile):
    rl = analyze(compiled_profile, TPU_V5E)
    assert rl.compute_s > 0 and rl.memory_s > 0
    assert rl.collective_s == 0
    assert rl.dominant in ("compute", "memory")
    assert 0 < rl.useful_ratio < 10


def test_dse_full_pipeline(compiled_profile):
    table = evaluate([compiled_profile])
    assert table.best_fit("e2e") in ("baseline", "denser", "densest")
    md = table.markdown()
    assert "e2e" in md


def test_idealization_consistency(compiled_profile):
    """Idealizing every subsystem jointly reaches ~the ideal step time."""
    from repro.core import ALL_SUBSYSTEMS, step_time
    m = TPU_V5E
    for s in ALL_SUBSYSTEMS:
        m = m.idealized(s)
    t_all_ideal = step_time(compiled_profile, m)
    t_base = step_time(compiled_profile, TPU_V5E)
    assert t_all_ideal < 0.01 * t_base
