"""Substrate tests: data pipeline, optimizer, checkpointing, fault-tolerant
trainer, serving engine, sharding rules."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLM
from repro.models import transformer as T
from repro.optim import adamw
from repro.serving.engine import BatchedEngine, Request
from repro.training.trainer import FailureInjector, Trainer, TrainerConfig

CFG = get_config("chatglm3-6b", smoke=True)


# --------------------------------------------------------------------------- #
# data
# --------------------------------------------------------------------------- #


def test_data_deterministic():
    dc = DataConfig(seq_len=16, global_batch=4, seed=3)
    a = SyntheticLM(CFG, dc).batch(7)
    b = SyntheticLM(CFG, dc).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(CFG, dc).batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_disjoint():
    dc0 = DataConfig(seq_len=16, global_batch=8, host_index=0, host_count=2)
    dc1 = DataConfig(seq_len=16, global_batch=8, host_index=1, host_count=2)
    b0 = SyntheticLM(CFG, dc0).batch(0)
    b1 = SyntheticLM(CFG, dc1).batch(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_data_labels_shift():
    dc = DataConfig(seq_len=16, global_batch=2)
    src = SyntheticLM(CFG, dc)
    b = src.batch(0)
    assert b["tokens"].shape == b["labels"].shape


def test_prefetch_iterator():
    dc = DataConfig(seq_len=8, global_batch=2)
    src = SyntheticLM(CFG, dc)
    it = PrefetchIterator(src, start_step=5)
    step, batch = next(it)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], src.batch(5)["tokens"])
    step, _ = next(it)
    assert step == 6
    it.close()


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #


def test_adamw_converges_quadratic():
    oc = adamw.OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                               weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params, oc)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, _ = adamw.update(grads, state, params, oc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_clip():
    oc = adamw.OptimizerConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, oc)
    _, _, stats = adamw.update({"w": jnp.full(3, 1e6)}, state, params, oc)
    assert float(stats["grad_norm"]) > 1e5  # raw norm reported


def test_schedule_shape():
    oc = adamw.OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                               min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(jnp.int32(s), oc)) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert lrs[10] == pytest.approx(1.0, abs=1e-3)
    assert lrs[100] == pytest.approx(0.1, abs=1e-3)


def test_grad_compression_error_feedback():
    oc = adamw.OptimizerConfig(compress_grads=True, warmup_steps=0)
    params = {"w": jnp.zeros(8)}
    state = adamw.init(params, oc)
    assert "ef" in state
    g = {"w": jnp.array([1.0, 1e-4, 0.5, -0.3, 0.0, 2.0, -1.7, 0.2])}
    _, state2, _ = adamw.update(g, state, params, oc)
    # residual captures quantization error; bounded by one quantum
    quantum = 2.0 / 127.0
    assert float(jnp.max(jnp.abs(state2["ef"]["w"]))) <= quantum + 1e-6


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16), "d": jnp.int32(7)}}
    store.save(str(tmp_path), 3, tree, extra={"loss": 1.5})
    restored, extra = store.restore(str(tmp_path), tree)
    assert extra["step"] == 3 and extra["loss"] == 1.5
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x, np.float32), np.asarray(y, np.float32)),
        tree, restored)


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.ones(3)}
    store.save(str(tmp_path), 1, tree)
    # a stale tmp dir (simulated crash) must be invisible
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert store.latest_step(str(tmp_path)) == 1


def test_checkpoint_retain(tmp_path):
    tree = {"a": jnp.ones(2)}
    for s in (1, 2, 3, 4, 5):
        store.save(str(tmp_path), s, tree)
    store.retain(str(tmp_path), keep=2)
    assert store.latest_step(str(tmp_path)) == 5
    assert len([d for d in os.listdir(tmp_path) if d.startswith("step_")]) == 2


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(10, {"w": jnp.ones(5)})
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 10


# --------------------------------------------------------------------------- #
# fault-tolerant trainer
# --------------------------------------------------------------------------- #


def test_trainer_restart_from_failure(tmp_path):
    tc = TrainerConfig(total_steps=12, checkpoint_every=4,
                       checkpoint_dir=str(tmp_path), max_restarts=2,
                       log_every=100)
    dc = DataConfig(seq_len=16, global_batch=2)
    tr = Trainer(CFG, tc, dc, failure_injector=FailureInjector(fail_at=[6]))
    out = tr.run()
    assert out["steps"] == 12
    assert out["restarts"] == 1
    assert store.latest_step(str(tmp_path)) == 12


def test_trainer_gives_up_after_max_restarts(tmp_path):
    tc = TrainerConfig(total_steps=10, checkpoint_every=100,
                       checkpoint_dir=str(tmp_path), max_restarts=1,
                       log_every=100)
    dc = DataConfig(seq_len=16, global_batch=2)
    # no checkpoint before the failure -> restart hits it again
    tr = Trainer(CFG, tc, dc, failure_injector=FailureInjector(fail_at=[2, 2]))
    tr.failure_injector.fired = set()

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 2:
                raise RuntimeError("boom")

    tr.failure_injector = AlwaysFail()
    with pytest.raises(RuntimeError, match="max_restarts"):
        tr.run()


def test_straggler_detection(tmp_path):
    tc = TrainerConfig(total_steps=1, checkpoint_dir=str(tmp_path),
                       straggler_factor=2.0, ewma_alpha=0.5)
    dc = DataConfig(seq_len=8, global_batch=2)
    events = []
    tr = Trainer(CFG, tc, dc,
                 on_straggler=lambda s, dt, ewma: events.append((s, dt)))
    tr._track_step_time(0, 1.0)   # seeds ewma
    tr._track_step_time(1, 1.1)
    tr._track_step_time(2, 5.0)   # 5x ewma -> straggler
    assert tr.stragglers.count == 1
    assert events and events[0][0] == 2


# --------------------------------------------------------------------------- #
# serving engine
# --------------------------------------------------------------------------- #


def test_batched_engine_slots_recycle():
    cfg = CFG.replace(vocab_size=32)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, slots=2, max_len=16)
    for rid in range(4):  # more requests than slots
        eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=3))
    eng.run_to_completion(max_steps=200)
    assert not eng.active and not eng.queue
    assert len(eng.free) == 2


def test_engine_greedy_matches_decode():
    cfg = CFG.replace(vocab_size=32)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, slots=1, max_len=16)
    req = Request(rid=0, prompt=[5, 7], max_new_tokens=2)
    eng.submit(req)
    eng.run_to_completion(max_steps=50)
    assert len(req.generated) >= 2
    assert all(0 <= t < cfg.vocab_size for t in req.generated)
