"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def tol_for(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

FA_SHAPES = [
    # (B, H, K, S, T, D)
    (1, 4, 4, 128, 128, 64),     # MHA square
    (2, 8, 2, 128, 128, 32),     # GQA
    (1, 4, 1, 256, 256, 64),     # MQA
    (1, 2, 2, 64, 256, 32),      # cross-length (S != T)
]


@pytest.mark.slow
@pytest.mark.parametrize("shape", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_attention_sweep(shape, dtype, causal, window):
    B, H, K, S, T, D = shape
    if causal and S != T:
        pytest.skip("causal with S != T not a supported layout")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, T, D), dtype)
    v = jax.random.normal(ks[2], (B, K, T, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol_for(dtype), rtol=tol_for(dtype))


def test_flash_attention_blocks_invariance():
    B, H, K, S, D = 1, 2, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    outs = [
        ops.flash_attention(q, k, v, block_q=bq, block_kv=bkv, interpret=True)
        for bq, bkv in [(64, 64), (128, 64), (64, 128), (256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #


@pytest.mark.slow
@pytest.mark.parametrize("rows,d", [(8, 128), (37, 256), (256, 512), (1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), dtype)
    sc = jax.random.normal(jax.random.PRNGKey(1), (d,)) + 1.0
    got = ops.rmsnorm(x, sc, interpret=True)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol_for(dtype), rtol=tol_for(dtype))


def test_rmsnorm_residual():
    x = jax.random.normal(KEY, (16, 9, 128))
    r = jax.random.normal(jax.random.PRNGKey(1), x.shape)
    sc = jnp.ones((128,))
    g1, g2 = ops.rmsnorm_residual(x, r, sc, interpret=True)
    w1, w2 = ref.rmsnorm_residual_ref(x, r, sc)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(w1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(w2), atol=1e-5)


# --------------------------------------------------------------------------- #
# selective scan
# --------------------------------------------------------------------------- #


@pytest.mark.slow
@pytest.mark.parametrize("B,S,Din,N,chunk,dblk", [
    (1, 32, 64, 4, 8, 32),
    (2, 64, 128, 8, 16, 64),
    (2, 64, 128, 8, 64, 128),    # single chunk / single block
    (1, 48, 96, 16, 16, 96),     # odd-ish sizes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_sweep(B, S, Din, N, chunk, dblk, dtype):
    ks = jax.random.split(KEY, 4)
    xi = (jax.random.normal(ks[0], (B, S, Din)) * 0.5).astype(dtype)
    dt_raw = (jax.random.normal(ks[1], (B, S, Din)) * 0.5 - 1.0).astype(dtype)
    Bm = (jax.random.normal(ks[2], (B, S, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[3], (B, S, N)) * 0.3).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (Din, N)) * 0.3)
    y_got, h_got = ops.selective_scan(xi, dt_raw, Bm, Cm, A, chunk=chunk,
                                      d_block=dblk, interpret=True)
    y_want, h_want = ref.selective_scan_ref(xi, dt_raw, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y_got, np.float32),
                               np.asarray(y_want, np.float32),
                               atol=tol_for(dtype), rtol=tol_for(dtype))
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               atol=tol_for(dtype), rtol=tol_for(dtype))


def test_selective_scan_carries_state():
    """Scanning two halves with carried state == scanning the whole."""
    B, S, Din, N = 1, 32, 64, 4
    ks = jax.random.split(KEY, 4)
    xi = jax.random.normal(ks[0], (B, S, Din)) * 0.5
    dt_raw = jax.random.normal(ks[1], (B, S, Din)) * 0.5 - 1.0
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (Din, N)) * 0.3)
    y_full, h_full = ops.selective_scan(xi, dt_raw, Bm, Cm, A, chunk=8,
                                        d_block=32, interpret=True)
    half = S // 2
    y1, h1 = ops.selective_scan(xi[:, :half], dt_raw[:, :half], Bm[:, :half],
                                Cm[:, :half], A, chunk=8, d_block=32,
                                interpret=True)
    y2, h2 = ops.selective_scan(xi[:, half:], dt_raw[:, half:], Bm[:, half:],
                                Cm[:, half:], A, h1, chunk=8, d_block=32,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, half:]), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               atol=1e-5, rtol=1e-5)
