"""HLO parsing: collective bytes, replica groups, pod crossing, dot FLOPs,
TPU HBM-traffic model."""

import pytest

from repro.core.costs import (
    WorkloadProfile,
    _crosses_pod,
    _parse_replica_groups,
    parse_hlo_stats,
)

HLO = """
HloModule jit_step

%fused_computation.1 (param_0.1: f32[1024,1024]) -> f32[1024,1024] {
  %param_0.1 = f32[1024,1024]{1,0} parameter(0)
  ROOT %mul.1 = f32[1024,1024]{1,0} multiply(%param_0.1, %param_0.1)
}

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.9 = f32[] add(%x, %y)
}

ENTRY %main (p0: f32[128,512], p1: f32[512,256]) -> f32[128,256] {
  %p0 = f32[128,512]{1,0} parameter(0)
  %p1 = f32[512,256]{1,0} parameter(1)
  %fusion = f32[1024,1024]{1,0} fusion(f32[1024,1024]{1,0} %p0), kind=kLoop, calls=%fused_computation.1
  %dot.1 = f32[128,256]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-gather.1 = f32[128,1024]{1,0} all-gather(f32[128,512]{1,0} %p0), replica_groups=[2,2]<=[4], dimensions={1}
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%dot.1), replica_groups={{0,1},{2,3}}, to_apply=%add.clone
  %reduce-scatter.1 = f32[64,256]{1,0} reduce-scatter(f32[128,256]{1,0} %all-reduce.1), replica_groups=[1,4]<=[4], dimensions={0}, to_apply=%add.clone
  %cp = f32[128,256]{1,0} collective-permute(%all-reduce.1), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %out = f32[128,256]{1,0} add(%all-reduce.1, %cp)
}
"""


def test_collective_bytes_by_kind():
    stats = parse_hlo_stats(HLO)
    f32 = 4
    assert stats.collective_bytes["all-gather"] == 128 * 512 * f32  # operand
    assert stats.collective_bytes["all-reduce"] == 128 * 256 * f32  # via symtab
    assert stats.collective_bytes["reduce-scatter"] == 128 * 256 * f32
    assert stats.collective_bytes["collective-permute"] == 128 * 256 * f32
    assert stats.collective_counts["all-gather"] == 1


def test_dot_flops_via_symbol_table():
    stats = parse_hlo_stats(HLO)
    assert stats.dot_flops == 2 * 128 * 256 * 512
    assert stats.dot_count == 1


def test_hbm_model_scoping():
    """Fusion-body + nested-computation params must not be double counted."""
    stats = parse_hlo_stats(HLO)
    f32 = 4
    # parameter: only ENTRY p0 + p1
    params = (128 * 512 + 512 * 256) * f32
    dot = (128 * 512 + 512 * 256 + 128 * 256) * f32
    fusion = (1024 * 1024 + 1024 * 1024) * f32  # operand (inline) + result
    colls = (128 * 512 + 128 * 256 * 3) * f32
    assert stats.hbm_bytes == pytest.approx(params + dot + fusion + colls)


def test_replica_group_parsing_iota():
    groups = _parse_replica_groups("replica_groups=[2,4]<=[8]")
    assert groups == [[0, 1, 2, 3], [4, 5, 6, 7]]
    groups = _parse_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
    # arange(8).reshape(2,4).T.flatten() = [0,4,1,5,2,6,3,7] -> groups of 2
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_replica_group_parsing_explicit():
    groups = _parse_replica_groups("replica_groups={{0,1},{2,3}}")
    assert groups == [[0, 1], [2, 3]]


def test_pod_crossing():
    assert not _crosses_pod([[0, 1], [2, 3]], devices_per_pod=2)
    assert _crosses_pod([[0, 2]], devices_per_pod=2)
    assert _crosses_pod([[1, 2], [0, 3]], devices_per_pod=2)
    # iota T-form groups [0,4],[1,5]... cross a 4-device pod
    stats = parse_hlo_stats(
        "ENTRY %m (p: f32[8]) -> f32[8] {\n"
        "  %p = f32[8]{0} parameter(0)\n"
        "  ROOT %ar = f32[8]{0} all-reduce(%p), replica_groups=[4,2]<=[2,4]T(1,0)\n"
        "}\n",
        devices_per_pod=4,
    )
    assert stats.pod_collective_bytes == 32.0


def test_profile_json_roundtrip(tmp_path):
    p = WorkloadProfile(name="x", flops=1.0, bytes_accessed=2.0,
                        collective_bytes={"all-reduce": 3.0},
                        hbm_bytes=5.0, model_flops=4.0, num_devices=8)
    path = str(tmp_path / "p.json")
    p.save(path)
    q = WorkloadProfile.load(path)
    assert q.flops == p.flops
    assert q.hbm_bytes == p.hbm_bytes
    assert q.collective_bytes == p.collective_bytes
    assert q.useful_flops_ratio == p.useful_flops_ratio
