"""Deterministic synthetic token pipeline with host sharding + prefetch.

Production shape: each host materializes only its slice of the global batch
(``host_count``/``host_index``), batches are derivable from the step number
alone (resumable without data-state checkpoints), and a background thread
prefetches ahead of the training loop.

The synthetic stream is a mixture of Zipf-distributed unigrams and repeated
n-gram motifs, so models show a real learning curve (loss drops below the
uniform-entropy floor) while remaining fully offline and reproducible.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import Family, ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64
    motif_prob: float = 0.5
    prefetch: int = 2


class SyntheticLM:
    """Step-indexed deterministic batches: batch(i) is a pure function."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        assert dc.global_batch % dc.host_count == 0
        self.cfg = cfg
        self.dc = dc
        self.local_batch = dc.global_batch // dc.host_count
        root = np.random.default_rng(dc.seed)
        v = cfg.vocab_size
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-dc.zipf_a)
        self.probs = probs / probs.sum()
        # fixed motif table (n-grams the model can learn to complete)
        self.motifs = root.integers(0, v, size=(dc.n_motifs, dc.motif_len))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rng = np.random.default_rng(
            (dc.seed, step, dc.host_index))  # host-disjoint, step-derivable
        B, S = self.local_batch, dc.seq_len
        toks = rng.choice(self.cfg.vocab_size, size=(B, S + 1), p=self.probs)
        # splice motifs at random offsets
        n_splice = int(S * dc.motif_prob / dc.motif_len)
        for b in range(B):
            for _ in range(n_splice):
                m = self.motifs[rng.integers(0, dc.n_motifs)]
                off = rng.integers(0, S + 1 - dc.motif_len)
                toks[b, off: off + dc.motif_len] = m
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.family == Family.AUDIO:
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq_len, self.cfg.d_model),
            ).astype(np.float32)
        if self.cfg.family == Family.VLM:
            batch["patches"] = rng.standard_normal(
                (B, self.cfg.n_vision_tokens, self.cfg.d_model),
            ).astype(np.float32)
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch of a step-indexed source."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: Optional[int] = None):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(
            maxsize=depth or source.dc.prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
