"""Checkpointing: atomic, async-capable, elastic-reshard on restore.

Layout: <dir>/step_<N>/  with one .npy per flattened leaf + manifest.json
(treedef, shapes, dtypes, step metadata).  Writes go to a temp dir that is
atomically renamed, so a crash mid-save can never corrupt the latest
checkpoint; ``latest_step`` only sees manifests that finished.

Elastic restore: leaves are stored unsharded (gathered), so a checkpoint
written on one mesh restores onto any other mesh/device-count -- restore
takes target shardings and device_puts accordingly (tested 8 -> 4 devices).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"

# numpy cannot round-trip ml_dtypes through .npy; store as uint views
_NONNATIVE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
              "float8_e5m2": np.uint8, "float16": None}
_NONNATIVE = {k: v for k, v in _NONNATIVE.items() if v is not None}


def _decode_dtype(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _NONNATIVE:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, logical)))
    return arr


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _NONNATIVE:  # bf16/f8: store as uint view
            arr = arr.view(_NONNATIVE[logical])
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": logical})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree of NamedSharding (same structure) for
    elastic placement onto the current mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    arrays = [
        _decode_dtype(np.load(os.path.join(path, leaf["file"])), leaf["dtype"])
        for leaf in manifest["leaves"]
    ]
    treedef = jax.tree_util.tree_structure(tree_like)
    assert treedef.num_leaves == len(arrays), (
        f"checkpoint has {len(arrays)} leaves, tree expects "
        f"{treedef.num_leaves}")
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest["extra"] | {"step": manifest["step"]}


def retain(directory: str, keep: int = 3) -> None:
    """Garbage-collect all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(directory, n, _MANIFEST)))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Non-blocking saves on a worker thread (one in flight at a time;
    the training loop never stalls on I/O)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # materialize on host before handing to the thread (device buffers
        # may be donated by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
                retain(self.directory, self.keep)
            except BaseException as exc:  # noqa: BLE001
                self._error = exc

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
