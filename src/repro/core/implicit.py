"""Implicit differentiation through the co-design optimum.

The frontier answers "what is the best design at this budget"; this module
answers the other half of early design exploration -- "which constraint is
worth relaxing, and by how much".  Each budget's optimum ``theta*(b)`` is a
fixed point of the project-then-descend map from ``constrained.py``; instead
of differentiating through hundreds of unrolled descent steps (whose
projections are bisection solves with zero budget-derivative almost
everywhere), we apply the implicit function theorem at the KKT point:

* ``implicit_sensitivities`` / ``sensitivities_of`` -- first-order shadow
  prices ``lambda`` per constraint (scalar area/power budgets and
  per-subsystem envelopes) recovered from the stationarity system
  ``grad J + G^T lambda = 0`` on the free (non-box-active) coordinates,
  plus the envelope-theorem sensitivities ``dJ*/d(budget) = -lambda`` and
  ``dJ*/d(cost-model weights)``.
* ``implicit_jstar_fn`` -- a differentiable ``J*(budgets)`` whose forward
  pass is a rolled ``lax.fori_loop`` descent (trace size independent of
  ``steps``) and whose backward pass is a custom VJP solving the linearized
  KKT system directly on the small per-variant theta dimension.
* ``unrolled_jstar_fn`` -- the penalty-smoothed unrolled-descent baseline
  the benchmarks compare against (trace grows with ``steps``).
* ``bilevel_codesign`` -- outer gradient descent on the split of one total
  budget across area and power, through the inner optimum.

Constraint columns follow ``constrained.budget_violations_vector`` order:
scalar area, scalar power, then envelope fields sorted by name (see
``constraint_labels``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import kernels_xp as K
from .codesign import (
    OPT_FIELDS,
    CodesignResult,
    _as_batches,
    _objective_terms,
    backtracking_descent,
    machine_arrays_from_theta,
    resolve_beta,
    theta_box,
)
from .constrained import (
    _area_posynomial,
    _power_posynomial,
    constrained_codesign,
    constraint_labels,
    project_to_budgets,
    validate_area_envelope,
)
from .costmodel import DEFAULT_COST_MODEL, RATE_FIELDS, CostModel
from .spec import resolve_spec

__all__ = [
    "SensitivityReport",
    "implicit_sensitivities",
    "sensitivities_of",
    "implicit_jstar_fn",
    "unrolled_jstar_fn",
    "BilevelResult",
    "bilevel_codesign",
]

#: Relative slack for "constraint is active": value >= budget * (1 - tol).
ACTIVE_RTOL = 1e-5
#: Absolute log-space slack for "coordinate is pinned at the span box".
BOX_ATOL = 1e-7

#: theta column per envelope field (``ici_bw_total`` rides on the
#: per-link ``ici_bw`` column; the link count is a fixed constant here).
_ENV_COL = {f: j for j, f in
            enumerate(("peak_flops", "hbm_bw", "ici_bw", "inter_pod_bw"))}
_ENV_COL["ici_bw_total"] = _ENV_COL.pop("ici_bw")


# --------------------------------------------------------------------------- #
# Constraint values + analytic gradients (shared by the NumPy report path
# and the traced custom-VJP backward pass)
# --------------------------------------------------------------------------- #


def _constraint_system(xp, theta, fixed, cost_model, area_budget,
                       power_budget, area_envelope):
    """Values ``(V, C)``, gradients ``(V, C, D)`` and budgets ``(V, C)``
    for every configured constraint, in ``constraint_labels`` order.

    Gradients are analytic posynomial derivatives in log-rate space
    (``d/d theta_j  c_j e^(e_j theta_j) = c_j e_j e^(e_j theta_j)``), so
    this works identically for NumPy and for traced ``jax.numpy`` inputs.
    ``area_budget`` may be per-variant ``(V,)`` (the frontier's rows).
    """
    v, d = theta.shape[0], len(OPT_FIELDS)
    th = theta[:, :d]
    values, grads, budgets = [], [], []
    ones = xp.ones((v,))
    if area_budget is not None:
        coeff, expo, offset = _area_posynomial(xp, cost_model, fixed)
        terms = coeff * xp.exp(expo[None, :] * th)
        values.append(xp.sum(terms, axis=1) + offset)
        grads.append(terms * expo[None, :])
        budgets.append(ones * area_budget)
    if power_budget is not None:
        coeff, expo, offset = _power_posynomial(xp, cost_model, fixed)
        terms = coeff * xp.exp(expo[None, :] * th)
        values.append(xp.sum(terms, axis=1) + offset)
        grads.append(terms * expo[None, :])
        budgets.append(ones * power_budget)
    if area_envelope:
        ref = cost_model.reference
        for field in sorted(area_envelope):
            col = _ENV_COL[field]
            scale = (fixed.ici_links / ref.ici_bw_total
                     if field == "ici_bw_total"
                     else 1.0 / getattr(ref, field))
            val = scale * xp.exp(th[:, col])
            g = xp.zeros((v, d))
            g = _one_hot_col(xp, g, col, val)
            values.append(val)
            grads.append(g)
            budgets.append(ones * area_envelope[field])
    return (xp.stack(values, axis=1),
            xp.stack(grads, axis=1),
            xp.stack(budgets, axis=1))


def _one_hot_col(xp, g, col, val):
    if xp is np:
        g = g.copy()
        g[:, col] = val
        return g
    return g.at[:, col].set(val)


def _free_mask(xp, theta, lo, hi, atol):
    """Coordinates strictly inside the span box (KKT stationarity is only
    required on these; box-pinned coordinates carry their own multiplier
    which we fold away by dropping the coordinate)."""
    d = theta.shape[1]
    return (theta > lo[:, :d] + atol) & (theta < hi[:, :d] - atol)


def _nnls_multipliers(gj, grads, active, free, tol=1e-12):
    """Per-variant nonnegative least-squares multipliers (NumPy).

    Solves ``min || G_A^T lam + grad J ||`` on the free coordinates over
    the active set ``A``, pruning the most-negative multiplier until all
    remaining are nonnegative (classic active-set NNLS on a tiny system).
    Returns ``(lam (V, C), residual (V,))`` where ``residual`` is the
    relative stationarity defect -- a diagnostic for "was this actually a
    KKT point".

    >>> gj = np.array([[-2.0, 0.0]])          # one variant, two coords
    >>> grads = np.array([[[1.0, 0.0], [0.0, 1.0]]])  # two constraints
    >>> active = np.array([[True, False]])
    >>> free = np.array([[True, True]])
    >>> lam, res = _nnls_multipliers(gj, grads, active, free)
    >>> lam.round(6).tolist(), res.round(6).tolist()
    ([[2.0, 0.0]], [0.0])
    """
    v, c = active.shape
    lam = np.zeros((v, c))
    residual = np.zeros(v)
    for i in range(v):
        f = free[i]
        g_free = gj[i][f]
        norm = max(float(np.linalg.norm(gj[i])), 1e-30)
        act = [int(j) for j in np.nonzero(active[i])[0]]
        while act:
            a = grads[i][np.asarray(act)][:, f]          # (|A|, F)
            sol, *_ = np.linalg.lstsq(a.T, -g_free, rcond=None)
            if sol.size == 0 or float(np.min(sol)) >= -tol:
                lam[i, np.asarray(act)] = np.maximum(sol, 0.0)
                break
            act.pop(int(np.argmin(sol)))
        r = g_free + grads[i][:, f].T @ lam[i]
        residual[i] = float(np.linalg.norm(r)) / norm
    return lam, residual


def _ridge_multipliers(jnp, gj, grads, active, free, ridge=1e-10):
    """Traced multiplier solve for the custom-VJP backward pass.

    Masks inactive constraints and box-pinned coordinates to zero, solves
    the (C, C) normal equations with a small ridge (a direct solve on the
    small theta dimension -- C <= 6), and clamps to nonnegative.  Agrees
    with ``_nnls_multipliers`` away from degenerate active sets; the NumPy
    path remains the careful reference.
    """
    a_eff = grads * active[:, :, None] * free[:, None, :]
    g_eff = gj * free
    c = a_eff.shape[1]
    m = jnp.einsum("vcd,ved->vce", a_eff, a_eff) + ridge * jnp.eye(c)
    rhs = -jnp.einsum("vcd,vd->vc", a_eff, g_eff)
    lam = jnp.linalg.solve(m, rhs[..., None])[..., 0]
    return jnp.where(active, jnp.maximum(lam, 0.0), 0.0)


# --------------------------------------------------------------------------- #
# The sensitivity report
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class SensitivityReport:
    """Shadow prices and envelope-theorem sensitivities at an optimum.

    ``multipliers[v, c]`` is the KKT multiplier of constraint ``c`` (order:
    ``constraint_names``) for variant ``v`` against its ABSOLUTE budget, so
    ``dJ_dbudget = -multipliers``: relaxing budget ``c`` by ``db`` buys a
    first-order objective improvement of ``multipliers[v, c] * db``.
    """

    names: List[str]
    constraint_names: Tuple[str, ...]
    multipliers: np.ndarray          # (V, C) shadow prices, >= 0
    dJ_dbudget: np.ndarray           # (V, C) = -multipliers
    active: np.ndarray               # (V, C) bool constraint-active mask
    free: np.ndarray                 # (V, D) bool inside-the-box mask
    residual: np.ndarray             # (V,) relative stationarity defect
    objective: np.ndarray            # (V,) J at the point
    area: np.ndarray                 # (V,)
    power: np.ndarray                # (V,)
    dJ_dw_area: np.ndarray           # (V,) envelope theorem: area(theta*)
    dJ_dw_power: np.ndarray          # (V,) envelope theorem: power(theta*)
    dJ_darea_weights: Dict[str, np.ndarray]   # field -> (V,)
    dJ_dpower_weights: Dict[str, np.ndarray]  # field -> (V,)
    area_budget: Optional[object] = None
    power_budget: Optional[float] = None
    area_envelope: Optional[Dict[str, float]] = None

    def best_relaxation(self, i: int) -> Optional[str]:
        """The constraint whose relaxation buys variant ``i`` the most."""
        lam = self.multipliers[i]
        if not np.any(lam > 0.0):
            return None
        return self.constraint_names[int(np.argmax(lam))]

    def to_json(self, top_k: Optional[int] = None) -> dict:
        order = list(range(len(self.names)))
        if top_k is not None:
            order = sorted(sorted(order,
                                  key=lambda i: float(self.objective[i]))
                           [:top_k])
        return {
            "constraints": list(self.constraint_names),
            "variants": [
                {"name": self.names[i],
                 "objective": float(self.objective[i]),
                 "area": float(self.area[i]),
                 "power": float(self.power[i]),
                 "shadow_prices": {c: float(self.multipliers[i, j])
                                   for j, c in
                                   enumerate(self.constraint_names)},
                 "dJ_dbudget": {c: float(self.dJ_dbudget[i, j])
                                for j, c in
                                enumerate(self.constraint_names)},
                 "active": {c: bool(self.active[i, j])
                            for j, c in enumerate(self.constraint_names)},
                 "stationarity_residual": float(self.residual[i]),
                 "best_relaxation": self.best_relaxation(i),
                 "dJ_dw_area": float(self.dJ_dw_area[i]),
                 "dJ_dw_power": float(self.dJ_dw_power[i])}
                for i in order],
        }

    def markdown(self, top_k: Optional[int] = None) -> str:
        blob = self.to_json(top_k)
        cols = "".join(f" {c} |" for c in self.constraint_names)
        lines = [f"| variant | J |{cols} relax first |",
                 "|---|---|" + "---|" * (len(self.constraint_names) + 1)]
        for row in blob["variants"]:
            prices = "".join(
                f" {row['shadow_prices'][c]:.4f}"
                f"{'' if row['active'][c] else ' (slack)'} |"
                for c in self.constraint_names)
            lines.append(f"| {row['name']} | {row['objective']:.4f} |"
                         f"{prices} {row['best_relaxation'] or '-'} |")
        lines.append("")
        lines.append("shadow price = dJ*/d(budget) with sign flipped; "
                     "slack constraints price at ~0 (complementary "
                     "slackness).")
        return "\n".join(lines)


# --------------------------------------------------------------------------- #
# First-order sensitivities at a given point
# --------------------------------------------------------------------------- #


def _first_order_report(pb, names, fixed_np, theta_np, lo, hi, *,
                        area_budget, power_budget, area_envelope,
                        cost_model, beta_np, timing_model, eps,
                        w_area, w_power, active_rtol=ACTIVE_RTOL,
                        box_atol=BOX_ATOL) -> SensitivityReport:
    """Assemble a ``SensitivityReport`` from raw arrays (internal: the
    public entry points and ``frontier_codesign`` both funnel here)."""
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp
    d = len(OPT_FIELDS)
    theta_np = np.asarray(theta_np, dtype=np.float64)[:, :d]

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)

        def sum_obj(theta):
            m = machine_arrays_from_theta(jnp, theta, fixed)
            return jnp.sum(_objective_terms(jnp, p_arrays, m, beta_j,
                                            timing_model, eps, cost_model,
                                            w_area, w_power))

        gj = backend.to_numpy(jax.grad(sum_obj)(backend.asarray(theta_np)))

    values, grads, budgets = _constraint_system(
        np, theta_np, fixed_np, cost_model, area_budget, power_budget,
        area_envelope)
    active = values >= budgets * (1.0 - active_rtol)
    free = _free_mask(np, theta_np, lo, hi, box_atol)
    lam, residual = _nnls_multipliers(gj, grads, active, free)

    m_np = machine_arrays_from_theta(np, theta_np, fixed_np)
    area = np.asarray(cost_model.area(m_np))
    power = np.asarray(cost_model.power(m_np))
    with np.errstate(divide="ignore", invalid="ignore"):
        obj = _objective_terms(np, pb.arrays(), m_np, beta_np, timing_model,
                               eps, cost_model, w_area, w_power)

    labels = constraint_labels(area_budget, power_budget, area_envelope)
    lam_area = (lam[:, labels.index("area")]
                if "area" in labels else np.zeros(len(names)))
    lam_power = (lam[:, labels.index("power")]
                 if "power" in labels else np.zeros(len(names)))

    # Envelope theorem for the cost-model weights: the weights enter J both
    # through the scalarization terms (weights w_area/w_power) and through
    # any active area/power constraint (multipliers lam_area/lam_power).
    ref = cost_model.reference
    w_sum_a = sum(cost_model.area_weights[f] for f in RATE_FIELDS)
    w_sum_p = sum(cost_model.power_weights[f] for f in RATE_FIELDS)
    norm = {f: _norm_rate(m_np, ref, f) for f in RATE_FIELDS}
    dyn = power - cost_model.static_power
    d_aw = {f: (w_area + lam_area) * (norm[f] - area) / w_sum_a
            for f in RATE_FIELDS}
    d_pw = {f: (w_power + lam_power)
            * (norm[f] ** cost_model.power_exponents[f] - dyn) / w_sum_p
            for f in RATE_FIELDS}

    return SensitivityReport(
        names=list(names),
        constraint_names=tuple(labels),
        multipliers=lam,
        dJ_dbudget=-lam,
        active=active,
        free=free,
        residual=residual,
        objective=np.asarray(obj),
        area=area,
        power=power,
        dJ_dw_area=area,
        dJ_dw_power=power,
        dJ_darea_weights=d_aw,
        dJ_dpower_weights=d_pw,
        area_budget=area_budget,
        power_budget=power_budget,
        area_envelope=dict(area_envelope) if area_envelope else None,
    )


def _norm_rate(m, ref, field):
    if field == "ici_bw_total":
        return np.asarray(m.ici_bw_total) / ref.ici_bw_total
    return np.asarray(getattr(m, field)) / getattr(ref, field)


def polish_theta(profiles, machines, theta, *, area_budget=None,
                 power_budget=None, area_envelope=None, steps=40, lr=0.05,
                 span=16.0, projection="euclidean", beta=None, beta_ref=0,
                 timing_model="serial", eps=K.IDEAL_EPS,
                 cost_model=DEFAULT_COST_MODEL, w_area=0.1, w_power=0.05):
    """Refine ``theta`` toward the KKT point with a short projected
    descent (same objective/retraction as ``constrained_codesign``) and
    return the polished ``(theta, objective)`` as NumPy arrays.

    This is the warm-started re-solve the finite-difference harness uses
    to evaluate ``J*(b +- h)``, and the optional pre-step of
    ``implicit_sensitivities``: the sensitivity formulas assume the point
    actually is stationary.  ``area_budget`` may be per-variant ``(V,)``.
    """
    area_envelope = validate_area_envelope(area_envelope)
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp
    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    _, lo, hi = theta_box(mb, span)
    d = len(OPT_FIELDS)
    theta = np.asarray(theta, dtype=np.float64)[:, :d]

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)
        b_area = (None if area_budget is None
                  else backend.asarray(np.asarray(area_budget)))

        def objective(th):
            m = machine_arrays_from_theta(jnp, th, fixed)
            return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                    eps, cost_model, w_area, w_power)

        def retract(th):
            out, _ = project_to_budgets(jnp, th, lo_j, hi_j, fixed,
                                        cost_model, b_area, power_budget,
                                        area_envelope=area_envelope,
                                        method=projection)
            return out

        seed = retract(backend.asarray(theta))
        th, f_cur, _, _, _ = backtracking_descent(
            jax, jnp, seed, objective, steps, lr, retract=retract)
        return backend.to_numpy(th), np.asarray(f_cur)


def implicit_sensitivities(profiles, machines, theta=None, *,
                           area_budget=None, power_budget=None,
                           area_envelope=None, span=16.0, polish_steps=0,
                           projection="euclidean", lr=0.05, beta=None,
                           beta_ref=0, timing_model="serial",
                           eps=K.IDEAL_EPS, cost_model=DEFAULT_COST_MODEL,
                           w_area=0.1, w_power=0.05,
                           active_rtol=ACTIVE_RTOL,
                           box_atol=BOX_ATOL) -> SensitivityReport:
    """Shadow prices and budget sensitivities at an optimized design.

    ``machines`` are the SEED variants (they define the span box the
    descent ran in); ``theta`` is the optimized ``(V, len(OPT_FIELDS))``
    log-rate matrix (defaults to the seed rates).  Set ``polish_steps`` to
    refine a roughly-converged point before reading multipliers -- the
    implicit function theorem only holds AT the optimum.
    """
    area_envelope = validate_area_envelope(area_envelope)
    if area_budget is None and power_budget is None and not area_envelope:
        raise ValueError("implicit_sensitivities needs at least one of "
                         "area_budget, power_budget, area_envelope")
    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    theta0, lo, hi = theta_box(mb, span)
    theta = theta0 if theta is None else np.asarray(theta, np.float64)
    if polish_steps:
        theta, _ = polish_theta(
            profiles, mb, theta, area_budget=area_budget,
            power_budget=power_budget, area_envelope=area_envelope,
            steps=polish_steps, lr=lr, span=span, projection=projection,
            beta=beta, beta_ref=beta_ref, timing_model=timing_model,
            eps=eps, cost_model=cost_model, w_area=w_area, w_power=w_power)
    return _first_order_report(
        pb, mb.names, fixed_np, theta, lo, hi, area_budget=area_budget,
        power_budget=power_budget, area_envelope=area_envelope,
        cost_model=cost_model, beta_np=beta_np, timing_model=timing_model,
        eps=eps, w_area=w_area, w_power=w_power, active_rtol=active_rtol,
        box_atol=box_atol)


def sensitivities_of(result: CodesignResult, profiles, *, span=16.0,
                     polish_steps=0, beta=None, beta_ref=0,
                     timing_model="serial", eps=K.IDEAL_EPS,
                     cost_model=DEFAULT_COST_MODEL,
                     **overrides) -> SensitivityReport:
    """``implicit_sensitivities`` at a ``CodesignResult``'s final designs.

    Reconstructs the seed box from ``result.seed_params`` and evaluates at
    ``result.final_params`` under the result's budgets and scalarization
    weights.  Joint-mode results (per-variant app selection) are not
    supported -- their objective is not the plain scalarization.
    """
    if result.mode.startswith("joint"):
        raise ValueError("sensitivities_of does not support joint-mode "
                         "results (selection changes the objective)")
    from .sweep import MachineBatch

    def batch(params_list):
        fields = ("peak_flops", "hbm_bw", "ici_bw", "ici_links",
                  "inter_pod_bw", "scale_compute", "scale_memory",
                  "scale_interconnect")
        cols = {f: np.array([p[f] for p in params_list], dtype=np.float64)
                for f in fields}
        return MachineBatch(names=list(result.names), **cols)

    seeds = batch(result.seed_params)
    finals = batch(result.final_params)
    theta = np.log(np.stack(
        [[p[f] for f in OPT_FIELDS] for p in result.final_params]))
    pb, _ = _as_batches(profiles, seeds)
    beta_np = resolve_beta(pb, seeds, beta, beta_ref)
    _, lo, hi = theta_box(seeds, span)
    if polish_steps:
        if not np.allclose(seeds.ici_links, finals.ici_links):
            raise ValueError("polish is not supported for link-optimized "
                             "results (the integral link count is frozen)")
        theta, _ = polish_theta(
            profiles, seeds, theta, area_budget=result.area_budget,
            power_budget=result.power_budget,
            area_envelope=result.area_envelope, steps=polish_steps,
            span=span, beta=beta, beta_ref=beta_ref,
            timing_model=timing_model, eps=eps, cost_model=cost_model,
            w_area=result.w_area, w_power=result.w_power, **overrides)
    return _first_order_report(
        pb, result.names, finals.arrays(), theta, lo, hi,
        area_budget=result.area_budget, power_budget=result.power_budget,
        area_envelope=result.area_envelope, cost_model=cost_model,
        beta_np=beta_np, timing_model=timing_model, eps=eps,
        w_area=result.w_area, w_power=result.w_power)


# --------------------------------------------------------------------------- #
# Differentiable J*(budgets): rolled forward solve + KKT custom VJP
# --------------------------------------------------------------------------- #


def implicit_jstar_fn(profiles, machines, *, steps=80, lr=0.1, span=16.0,
                      projection="euclidean", area_envelope=None, beta=None,
                      beta_ref=0, timing_model="serial", eps=K.IDEAL_EPS,
                      cost_model=DEFAULT_COST_MODEL, w_area=0.1,
                      w_power=0.05, active_rtol=ACTIVE_RTOL,
                      box_atol=BOX_ATOL):
    """Build a differentiable ``jstar(budgets) -> (V,)`` map.

    ``budgets`` is a length-2 array ``[area_budget, power_budget]``.  The
    forward pass runs ``steps`` backtracking projected-descent iterations
    inside one ``lax.fori_loop`` (the traced graph does NOT grow with
    ``steps`` -- pinned by the structure regression test); the backward
    pass ignores the solver entirely and returns the envelope-theorem
    cotangent ``b_bar = -sum_v y_bar_v * lambda_v`` with multipliers from
    a direct ridge solve of the linearized KKT system (``C <= 6``
    constraints, ``D = 4`` theta coordinates per variant).
    """
    area_envelope = validate_area_envelope(area_envelope)
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp
    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    theta0, lo, hi = theta_box(mb, span)

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        theta0_j = backend.asarray(theta0)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

    def per_variant_obj(theta):
        m = machine_arrays_from_theta(jnp, theta, fixed)
        return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                eps, cost_model, w_area, w_power)

    grad_obj = jax.grad(lambda th: jnp.sum(per_variant_obj(th)))

    def solve(b):
        def retract(th):
            out, _ = project_to_budgets(jnp, th, lo_j, hi_j, fixed,
                                        cost_model, b[0], b[1],
                                        area_envelope=area_envelope,
                                        method=projection)
            return out

        def body(_, state):
            th, f, lrs = state
            cand = retract(th - lrs[:, None] * grad_obj(th))
            f_new = per_variant_obj(cand)
            ok = f_new < f
            return (jnp.where(ok[:, None], cand, th),
                    jnp.where(ok, f_new, f),
                    jnp.where(ok, lrs * 1.2, lrs * 0.5))

        seed = retract(theta0_j)
        init = (seed, per_variant_obj(seed),
                jnp.full((theta0_j.shape[0],), lr))
        th, _, _ = jax.lax.fori_loop(0, steps, body, init)
        return th

    @jax.custom_vjp
    def jstar(b):
        return per_variant_obj(solve(b))

    def fwd(b):
        th = solve(b)
        return per_variant_obj(th), (th, b)

    def bwd(res, ybar):
        th, b = res
        gj = grad_obj(th)
        values, grads, budgets = _constraint_system(
            jnp, th, fixed, cost_model, b[0], b[1], area_envelope)
        active = values >= budgets * (1.0 - active_rtol)
        free = _free_mask(jnp, th, lo_j, hi_j, box_atol)
        lam = _ridge_multipliers(jnp, gj, grads, active, free)
        # dJ*_v/db_i = -lambda_{v,i}; the scalar budgets are columns 0, 1.
        bbar = -jnp.sum(ybar[:, None] * lam[:, :2], axis=0)
        return (bbar,)

    jstar.defvjp(fwd, bwd)

    def fn(budgets):
        with backend._x64():
            return jstar(jnp.asarray(budgets, dtype=jnp.float64))

    return fn


def unrolled_jstar_fn(profiles, machines, *, steps=40, lr=0.05, span=16.0,
                      penalty=200.0, beta=None, beta_ref=0,
                      timing_model="serial", eps=K.IDEAL_EPS,
                      cost_model=DEFAULT_COST_MODEL, w_area=0.1,
                      w_power=0.05):
    """Differentiate-through-the-solver baseline: a Python-unrolled
    quadratic-penalty descent whose traced graph (and gradient cost)
    grows linearly with ``steps``.

    The hard projections in ``constrained.py`` are bisection solves --
    piecewise constant in the budget under autodiff -- so the unrolled
    baseline smooths them into a penalty ``rho * relu(value/b - 1)^2``;
    its budget-gradient is a penalty approximation of the true shadow
    price.  Used by ``benchmarks/run.py sensitivity`` and the structure
    regression test as the thing the implicit VJP avoids.
    """
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp
    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    theta0, lo, hi = theta_box(mb, span)

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        theta0_j = backend.asarray(theta0)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

    def per_variant_obj(theta):
        m = machine_arrays_from_theta(jnp, theta, fixed)
        return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                eps, cost_model, w_area, w_power)

    def penalized(theta, b):
        m = machine_arrays_from_theta(jnp, theta, fixed)
        viol_a = jnp.maximum(cost_model.area(m) / b[0] - 1.0, 0.0)
        viol_p = jnp.maximum(cost_model.power(m) / b[1] - 1.0, 0.0)
        return (per_variant_obj(theta)
                + penalty * (viol_a ** 2 + viol_p ** 2))

    grad_pen = jax.grad(lambda th, b: jnp.sum(penalized(th, b)))

    def fn(budgets):
        with backend._x64():
            b = jnp.asarray(budgets, dtype=jnp.float64)
            th = theta0_j
            for _ in range(steps):        # deliberately unrolled
                th = jnp.clip(th - lr * grad_pen(th, b), lo_j, hi_j)
            return penalized(th, b)

    return fn


# --------------------------------------------------------------------------- #
# Bilevel budget descent
# --------------------------------------------------------------------------- #


_BILEVEL_DEFAULTS = dict(
    total_budget=None, split0=0.5, outer_steps=10, outer_lr=0.2,
    steps=60, lr=0.1, span=16.0, projection="euclidean", beta=None,
    timing_model="serial", cost_model=DEFAULT_COST_MODEL, w_area=0.1,
    w_power=0.05, area_envelope=None,
)
_SPLIT_MIN = 0.02


@dataclasses.dataclass
class BilevelResult:
    """Outcome of the outer budget-split descent (uniform result protocol:
    renders via ``markdown``/``to_json`` like every other result type)."""

    total_budget: float
    split_trajectory: np.ndarray       # (T+1,) accepted splits, s in (0, 1)
    objective_trajectory: np.ndarray   # (T+1,) min-variant J* per accepted s
    objective_uniform: float           # J* at the fixed 50/50 split
    inner: CodesignResult              # full inner solve at the final split
    sensitivity: SensitivityReport
    outer_steps: int

    @property
    def split_final(self) -> float:
        return float(self.split_trajectory[-1])

    @property
    def objective_final(self) -> float:
        return float(self.objective_trajectory[-1])

    @property
    def area_budget(self) -> float:
        return self.split_final * self.total_budget

    @property
    def power_budget(self) -> float:
        return (1.0 - self.split_final) * self.total_budget

    @property
    def improvement_over_uniform(self) -> float:
        """Objective gain of the learned split vs the 50/50 baseline
        (nonnegative by construction: the outer loop only accepts
        improving steps starting FROM the uniform split)."""
        return self.objective_uniform - self.objective_final

    def to_json(self, top_k: Optional[int] = None) -> dict:
        return {
            "total_budget": self.total_budget,
            "split_final": self.split_final,
            "area_budget": self.area_budget,
            "power_budget": self.power_budget,
            "objective_uniform": self.objective_uniform,
            "objective_final": self.objective_final,
            "improvement_over_uniform": self.improvement_over_uniform,
            "outer_steps": self.outer_steps,
            "split_trajectory": [float(s) for s in self.split_trajectory],
            "objective_trajectory": [float(f) for f in
                                     self.objective_trajectory],
            "inner": self.inner.to_json(top_k),
            "sensitivity": self.sensitivity.to_json(top_k),
        }

    def markdown(self, top_k: Optional[int] = None) -> str:
        lines = [
            "| total budget | split (area) | area budget | power budget "
            "| J* uniform | J* bilevel | gain |",
            "|---|---|---|---|---|---|---|",
            (f"| {self.total_budget:.3f} | {self.split_final:.3f} "
             f"| {self.area_budget:.3f} | {self.power_budget:.3f} "
             f"| {self.objective_uniform:.4f} "
             f"| {self.objective_final:.4f} "
             f"| {self.improvement_over_uniform:+.4f} |"),
            "",
            self.inner.markdown(top_k),
        ]
        return "\n".join(lines)


def bilevel_codesign(profiles, machines, *, spec=None, **explicit
                     ) -> BilevelResult:
    """Outer gradient descent on the split of ``total_budget`` across the
    area and power budgets, THROUGH the inner constrained optimum.

    The inner problem at split ``s`` is ``constrained_codesign`` with
    ``area_budget = s * T`` and ``power_budget = (1 - s) * T``; the outer
    gradient ``dJ*/ds = T * (lambda_power - lambda_area)`` comes for free
    from the implicit custom VJP (one KKT solve, no unrolling).  Starting
    from the uniform split and accepting only improving steps makes the
    result at least as good as the fixed 50/50 baseline by construction.

    Accepts a ``CodesignSpec`` (``total_budget``, ``outer_steps``,
    ``outer_lr``, ``split0``, inner ``steps``/``lr``/``span``/... -- the
    serving funnel's ``kind="bilevel"``) with explicit kwargs winning.
    """
    cfg = resolve_spec(spec, _BILEVEL_DEFAULTS, explicit)
    total = cfg["total_budget"]
    if total is None or not total > 0.0:
        raise ValueError("bilevel_codesign needs a positive total_budget "
                         f"(got {total!r})")
    split0 = float(cfg["split0"])
    if not _SPLIT_MIN <= split0 <= 1.0 - _SPLIT_MIN:
        raise ValueError(f"split0 must lie in [{_SPLIT_MIN}, "
                         f"{1 - _SPLIT_MIN}], got {split0!r}")
    outer_steps, outer_lr = int(cfg["outer_steps"]), float(cfg["outer_lr"])

    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp
    inner_kw = dict(steps=cfg["steps"], lr=cfg["lr"], span=cfg["span"],
                    projection=cfg["projection"], beta=cfg["beta"],
                    timing_model=cfg["timing_model"],
                    cost_model=cfg["cost_model"], w_area=cfg["w_area"],
                    w_power=cfg["w_power"],
                    area_envelope=cfg["area_envelope"])
    jstar = implicit_jstar_fn(profiles, machines, **inner_kw)

    def outer(s):
        b = jnp.stack([s * total, (1.0 - s) * total])
        return jnp.min(jstar(b))

    with backend._x64():
        val_grad = jax.jit(jax.value_and_grad(outer))
        s = split0
        f, g = (float(x) for x in val_grad(s))
        splits, objs = [s], [f]
        eta = outer_lr
        for _ in range(outer_steps):
            cand = float(np.clip(s - eta * g, _SPLIT_MIN, 1.0 - _SPLIT_MIN))
            fc, gc = (float(x) for x in val_grad(cand))
            if fc < f and cand != s:
                s, f, g = cand, fc, gc
                eta *= 1.2
            else:
                eta *= 0.5
            splits.append(s)
            objs.append(f)

    inner = constrained_codesign(
        profiles, machines, area_budget=s * total,
        power_budget=(1.0 - s) * total, mode="projected", **inner_kw)
    sens = sensitivities_of(
        inner, profiles, span=cfg["span"], beta=cfg["beta"],
        timing_model=cfg["timing_model"], cost_model=cfg["cost_model"])
    return BilevelResult(
        total_budget=float(total),
        split_trajectory=np.asarray(splits),
        objective_trajectory=np.asarray(objs),
        objective_uniform=float(objs[0]) if split0 == 0.5 else float(
            np.min(np.asarray(jstar(np.array([0.5 * total, 0.5 * total]))))),
        inner=inner,
        sensitivity=sens,
        outer_steps=outer_steps,
    )
