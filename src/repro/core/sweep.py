"""Vectorized design-space sweep engine (paper §III at population scale).

The paper's central economics: packing/placement/routing (for us: the XLA
compile) is paid once per application, after which re-timing an architecture
variant is pure arithmetic.  The scalar DSE loop in ``repro.core.dse`` walks
(app, variant, subsystem) cells one at a time in Python, which wastes that
cheapness.  This module re-states the whole pipeline --
``subsystem_times`` -> ``step_time`` -> Eq. 1 ``congruence_score`` ->
aggregate (paper §II-B, §III-C) -- as struct-of-arrays NumPy kernels with
shape ``(A, V)`` (apps x variants), so sweeping thousands of machine designs
costs a handful of array ops.

Three layers:

  ParamSpace     -- bounded design space over the machine-model constants
                    (``peak_flops``, ``hbm_bw``, ``ici_bw``, ``ici_links``,
                    ``inter_pod_bw``, per-subsystem ``scale``); generates
                    populations by full grid or low-discrepancy (Halton)
                    random sampling, the paper's "denser / densest" axis
                    extended to a continuous sweep.
  MachineBatch / ProfileBatch
                 -- struct-of-arrays packings of ``MachineModel`` /
                    ``WorkloadProfile`` (one float64 array per field).
  batched_*      -- thin wrappers over the backend-agnostic kernels in
                    ``repro.core.kernels_xp`` (the SAME math the scalar
                    path runs at batch size 1), evaluated on a selectable
                    backend: ``"numpy"`` (default) or ``"jax"`` (jitted,
                    device-placed, ~1e-12 from NumPy under x64).

``SweepResult`` holds the full score tensor plus the DSE extractions the
paper's Table I points at: per-app best-fit variants (lowest aggregate =
smallest radar area, §III-C), the 2-D Pareto front of aggregate congruence
vs. silicon area, and the 3-D front over (congruence, area, power) via the
configurable ``repro.core.costmodel.CostModel`` (the PPA trade-off of §I).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import kernels_xp as K
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.costs import WorkloadProfile
from repro.core.machine import (
    IDEAL_EPS,
    MachineModel,
    Subsystem,
    TPU_V5E,
)

# The machine-model constants a sweep may vary, in canonical order.
SWEEP_PARAMS = (
    "peak_flops",
    "hbm_bw",
    "ici_bw",
    "ici_links",
    "inter_pod_bw",
    "scale_compute",
    "scale_memory",
    "scale_interconnect",
)


# --------------------------------------------------------------------------- #
# ParamSpace: grid + low-discrepancy population generators
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Dim:
    """One bounded sweep dimension.

    ``log=True`` spaces points geometrically -- hardware rates span decades,
    so a log grid is the natural "denser / densest" ladder.  ``integer``
    rounds to whole values (link counts).
    """

    lo: float
    hi: float
    log: bool = True
    integer: bool = False

    def points(self, k: int) -> np.ndarray:
        """``k`` grid points across the range (deduplicated if integer)."""
        if k <= 1:
            pts = np.array([self.hi if self.integer else
                            float(np.sqrt(self.lo * self.hi)) if self.log
                            else 0.5 * (self.lo + self.hi)])
        elif self.log:
            pts = np.geomspace(self.lo, self.hi, k)
        else:
            pts = np.linspace(self.lo, self.hi, k)
        if self.integer:
            pts = np.unique(np.rint(pts))
        return pts.astype(np.float64)

    def from_unit(self, u: np.ndarray) -> np.ndarray:
        """Map uniform [0, 1) samples onto the dimension's range."""
        u = np.asarray(u, dtype=np.float64)
        if self.integer:
            lo, hi = int(round(self.lo)), int(round(self.hi))
            return np.clip(np.floor(lo + (hi - lo + 1) * u), lo, hi)
        if self.log:
            return self.lo * (self.hi / self.lo) ** u
        return self.lo + (self.hi - self.lo) * u


_HALTON_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def _radical_inverse(index: np.ndarray, base: int) -> np.ndarray:
    """Van der Corput radical inverse of ``index`` in ``base`` (vectorized)."""
    idx = np.asarray(index, dtype=np.int64).copy()
    inv = np.zeros(idx.shape, dtype=np.float64)
    frac = 1.0 / base
    while np.any(idx > 0):
        inv += frac * (idx % base)
        idx //= base
        frac /= base
    return inv


def halton(n: int, d: int, seed: int = 0) -> np.ndarray:
    """``(n, d)`` low-discrepancy points in [0, 1).

    Halton sequence with a seeded Cranley-Patterson rotation so different
    seeds give different (still low-discrepancy) populations.
    """
    if d > len(_HALTON_PRIMES):
        raise ValueError(f"halton supports at most {len(_HALTON_PRIMES)} dims")
    shifts = np.random.default_rng(seed).random(d)
    out = np.empty((n, d), dtype=np.float64)
    for j in range(d):
        out[:, j] = (_radical_inverse(np.arange(1, n + 1), _HALTON_PRIMES[j])
                     + shifts[j]) % 1.0
    return out


@dataclasses.dataclass
class ParamSpace:
    """Bounded machine design space around a ``nominal`` machine.

    ``dims`` maps a subset of ``SWEEP_PARAMS`` to ``Dim`` ranges; parameters
    not present stay pinned at the nominal machine's value.
    """

    dims: Dict[str, Dim]
    nominal: MachineModel = TPU_V5E

    def __post_init__(self) -> None:
        for name in self.dims:
            if name not in SWEEP_PARAMS:
                raise KeyError(
                    f"unknown sweep parameter {name!r}; have {SWEEP_PARAMS}")

    @staticmethod
    def default(nominal: MachineModel = TPU_V5E, span: float = 4.0,
                max_links: int = 8) -> "ParamSpace":
        """The paper's density ladder as a continuous space: every rate swept
        geometrically ``span``x below/above the nominal chip, link count up
        to ``max_links``."""
        dims = {
            "peak_flops": Dim(nominal.peak_flops / span, nominal.peak_flops * span),
            "hbm_bw": Dim(nominal.hbm_bw / span, nominal.hbm_bw * span),
            "ici_bw": Dim(nominal.ici_bw / span, nominal.ici_bw * span),
            "ici_links": Dim(1, max_links, log=False, integer=True),
            "inter_pod_bw": Dim(nominal.inter_pod_bw / span,
                                nominal.inter_pod_bw * span),
        }
        return ParamSpace(dims=dims, nominal=nominal)

    # ------------------------------------------------------------------ #

    def _nominal_value(self, name: str) -> float:
        if name.startswith("scale_"):
            return self.nominal.scale_for(Subsystem(name[len("scale_"):]))
        return float(getattr(self.nominal, name))

    def _columns_to_batch(self, cols: Dict[str, np.ndarray], n: int,
                          prefix: str) -> "MachineBatch":
        full = {}
        for name in SWEEP_PARAMS:
            if name in cols:
                full[name] = np.asarray(cols[name], dtype=np.float64)
            else:
                full[name] = np.full(n, self._nominal_value(name))
        return MachineBatch(
            names=[f"{prefix}{i:05d}" for i in range(n)], **full)

    def grid(self, points: Union[int, Mapping[str, int]] = 3) -> "MachineBatch":
        """Full cross-product grid.

        ``points`` is either a per-dimension count mapping or one count
        applied to every dimension in the space.
        """
        if isinstance(points, int):
            points = {name: points for name in self.dims}
        axes = {name: self.dims[name].points(k) for name, k in points.items()
                if name in self.dims}
        names = list(axes)
        combos = list(itertools.product(*(axes[n] for n in names)))
        cols = {n: np.array([c[i] for c in combos], dtype=np.float64)
                for i, n in enumerate(names)}
        return self._columns_to_batch(cols, len(combos), "grid-")

    def sample(self, n: int, seed: int = 0) -> "MachineBatch":
        """``n`` low-discrepancy (Halton) samples across every dimension."""
        names = list(self.dims)
        unit = halton(n, len(names), seed=seed)
        cols = {name: self.dims[name].from_unit(unit[:, j])
                for j, name in enumerate(names)}
        return self._columns_to_batch(cols, n, "sweep-")


# --------------------------------------------------------------------------- #
# Struct-of-arrays packings
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class MachineBatch:
    """``V`` machine variants as one float64 array per model constant."""

    names: List[str]
    peak_flops: np.ndarray
    hbm_bw: np.ndarray
    ici_bw: np.ndarray
    ici_links: np.ndarray
    inter_pod_bw: np.ndarray
    scale_compute: np.ndarray
    scale_memory: np.ndarray
    scale_interconnect: np.ndarray

    def __len__(self) -> int:
        return len(self.names)

    @property
    def ici_bw_total(self) -> np.ndarray:
        return self.ici_bw * self.ici_links

    def scale_for(self, subsystem: Subsystem) -> np.ndarray:
        return {
            Subsystem.COMPUTE: self.scale_compute,
            Subsystem.MEMORY: self.scale_memory,
            Subsystem.INTERCONNECT: self.scale_interconnect,
        }[subsystem]

    @staticmethod
    def from_models(models: Sequence[MachineModel]) -> "MachineBatch":
        arr = lambda get: np.array([get(m) for m in models], dtype=np.float64)
        return MachineBatch(
            names=[m.name for m in models],
            peak_flops=arr(lambda m: m.peak_flops),
            hbm_bw=arr(lambda m: m.hbm_bw),
            ici_bw=arr(lambda m: m.ici_bw),
            ici_links=arr(lambda m: m.ici_links),
            inter_pod_bw=arr(lambda m: m.inter_pod_bw),
            scale_compute=arr(lambda m: m.scale_for(Subsystem.COMPUTE)),
            scale_memory=arr(lambda m: m.scale_for(Subsystem.MEMORY)),
            scale_interconnect=arr(lambda m: m.scale_for(Subsystem.INTERCONNECT)),
        )

    @staticmethod
    def concat(*batches: "MachineBatch") -> "MachineBatch":
        cat = lambda get: np.concatenate([get(b) for b in batches])
        return MachineBatch(
            names=[n for b in batches for n in b.names],
            peak_flops=cat(lambda b: b.peak_flops),
            hbm_bw=cat(lambda b: b.hbm_bw),
            ici_bw=cat(lambda b: b.ici_bw),
            ici_links=cat(lambda b: b.ici_links),
            inter_pod_bw=cat(lambda b: b.inter_pod_bw),
            scale_compute=cat(lambda b: b.scale_compute),
            scale_memory=cat(lambda b: b.scale_memory),
            scale_interconnect=cat(lambda b: b.scale_interconnect),
        )

    def model(self, i: int) -> MachineModel:
        """Materialize variant ``i`` as a scalar ``MachineModel``."""
        return MachineModel(
            name=self.names[i],
            peak_flops=float(self.peak_flops[i]),
            hbm_bw=float(self.hbm_bw[i]),
            ici_bw=float(self.ici_bw[i]),
            ici_links=int(self.ici_links[i]),
            inter_pod_bw=float(self.inter_pod_bw[i]),
            scale={
                Subsystem.COMPUTE.value: float(self.scale_compute[i]),
                Subsystem.MEMORY.value: float(self.scale_memory[i]),
                Subsystem.INTERCONNECT.value: float(self.scale_interconnect[i]),
            },
        )

    def models(self) -> List[MachineModel]:
        return [self.model(i) for i in range(len(self))]

    def area(self, reference: MachineModel = TPU_V5E) -> np.ndarray:
        """Relative silicon/cost proxy per variant (see ``CostModel.area``;
        the default equal-weight model is used, matching the historical
        four-rate-mean proxy exactly)."""
        return CostModel(reference=reference).area(self)

    def arrays(self) -> K.MachineArrays:
        """The kernel-layer view: one ``MachineArrays`` namedtuple."""
        return K.MachineArrays(
            peak_flops=self.peak_flops,
            hbm_bw=self.hbm_bw,
            ici_bw=self.ici_bw,
            ici_links=self.ici_links,
            inter_pod_bw=self.inter_pod_bw,
            scale_compute=self.scale_compute,
            scale_memory=self.scale_memory,
            scale_interconnect=self.scale_interconnect,
        )

    def select(self, i: int) -> "MachineBatch":
        """Single-variant sub-batch (used as the default-beta reference)."""
        sel = {name: getattr(self, name)[i:i + 1] for name in SWEEP_PARAMS}
        return MachineBatch(names=[self.names[i]], **sel)

    def params_row(self, i: int) -> Dict[str, float]:
        return {name: float(getattr(self, name)[i]) for name in SWEEP_PARAMS}


@dataclasses.dataclass
class ProfileBatch:
    """``A`` workload profiles packed into the arrays the timing model reads.

    ``mem_bytes`` applies the scalar path's fallback (``hbm_bytes`` when
    positive, else raw ``bytes_accessed``) at pack time.
    """

    names: List[str]
    flops: np.ndarray
    mem_bytes: np.ndarray
    collective_bytes: np.ndarray
    pod_collective_bytes: np.ndarray
    model_flops: np.ndarray
    num_devices: np.ndarray
    profiles: List[WorkloadProfile]

    def __len__(self) -> int:
        return len(self.names)

    @staticmethod
    def from_profiles(profiles: Sequence[WorkloadProfile]) -> "ProfileBatch":
        profiles = list(profiles)
        return ProfileBatch(
            names=[p.name for p in profiles],
            flops=np.array([p.flops for p in profiles], dtype=np.float64),
            mem_bytes=np.array(
                [p.hbm_bytes if p.hbm_bytes > 0 else p.bytes_accessed
                 for p in profiles], dtype=np.float64),
            collective_bytes=np.array(
                [p.total_collective_bytes for p in profiles], dtype=np.float64),
            pod_collective_bytes=np.array(
                [p.pod_collective_bytes for p in profiles], dtype=np.float64),
            model_flops=np.array(
                [p.model_flops for p in profiles], dtype=np.float64),
            num_devices=np.array(
                [p.num_devices for p in profiles], dtype=np.float64),
            profiles=profiles,
        )

    def arrays(self) -> K.ProfileArrays:
        """The kernel-layer view: one ``ProfileArrays`` namedtuple."""
        return K.ProfileArrays(
            flops=self.flops,
            mem_bytes=self.mem_bytes,
            collective_bytes=self.collective_bytes,
            pod_collective_bytes=self.pod_collective_bytes,
            model_flops=self.model_flops,
            num_devices=self.num_devices,
        )


def _as_profile_batch(profiles) -> ProfileBatch:
    if isinstance(profiles, ProfileBatch):
        return profiles
    return ProfileBatch.from_profiles(list(profiles))


def _as_machine_batch(machines) -> MachineBatch:
    if isinstance(machines, MachineBatch):
        return machines
    return MachineBatch.from_models(list(machines))


# --------------------------------------------------------------------------- #
# Batched timing + congruence -- thin wrappers over repro.core.kernels_xp
# --------------------------------------------------------------------------- #


def batched_step_time(
    profiles, machines, timing_model: str = "serial",
    backend: Optional[str] = None,
) -> np.ndarray:
    """``(A, V)`` step-time matrix -- vectorized ``timing.step_time``."""
    pb, mb = _as_profile_batch(profiles), _as_machine_batch(machines)
    be = K.get_backend(backend)
    return be.to_numpy(be.step_time(pb.arrays(), mb.arrays(), timing_model))


def default_beta_batched(
    profiles, machines, beta_ref: int = 0,
    backend: Optional[str] = None,
) -> np.ndarray:
    """Vectorized ``congruence.default_beta`` against variant ``beta_ref``.

    The paper's beta is a per-application user target held constant across
    variants (Table I compares architectures against one target), so the
    default derives from a single reference variant -- by convention the
    first ("baseline") column, matching ``dse.evaluate``.
    """
    pb, mb = _as_profile_batch(profiles), _as_machine_batch(machines)
    be = K.get_backend(backend)
    return be.to_numpy(
        be.default_beta(pb.arrays(), mb.select(beta_ref).arrays()))


@dataclasses.dataclass
class SweepResult:
    """Full ``(A, V)`` score tensor plus the Table I / Pareto extractions."""

    profiles: ProfileBatch
    machines: MachineBatch
    timing_model: str
    eps: float
    clamp: bool
    beta: np.ndarray                 # (A,) per-app target
    gamma: np.ndarray                # (A, V) baseline step times
    alphas: Dict[str, np.ndarray]    # subsystem value -> (A, V)
    scores: Dict[str, np.ndarray]    # ICS/HRCS/LBCS -> (A, V)
    aggregate: np.ndarray            # (A, V) L2 magnitudes
    backend: str = "numpy"           # kernel backend that produced the tensors

    # ------------------------------ lookups --------------------------- #

    @property
    def apps(self) -> List[str]:
        return list(self.profiles.names)

    @property
    def variant_names(self) -> List[str]:
        return list(self.machines.names)

    def app_index(self, app: str) -> int:
        return self.profiles.names.index(app)

    # --------------------------- extractions -------------------------- #

    def best_fit_indices(self) -> np.ndarray:
        """Per-app argmin over variants (lowest aggregate = best fit)."""
        return np.argmin(self.aggregate, axis=1)

    def best_fit(self, app: str) -> str:
        return self.machines.names[int(
            np.argmin(self.aggregate[self.app_index(app)]))]

    def aggregate_mean(self) -> np.ndarray:
        """Suite-mean aggregate per variant (Table I bottom row), shape (V,)."""
        return self.aggregate.mean(axis=0)

    def area(self, reference: MachineModel = TPU_V5E) -> np.ndarray:
        return self.machines.area(reference)

    def power(self, cost_model: CostModel = DEFAULT_COST_MODEL) -> np.ndarray:
        """Relative dynamic-power proxy per variant (``CostModel.power``)."""
        return cost_model.power(self.machines)

    def pareto_front(self, reference: MachineModel = TPU_V5E) -> List[int]:
        """Variant indices on the (area, mean aggregate) Pareto front.

        Both axes are minimized: cheaper silicon and better congruence fit.
        Returned sorted by increasing area; no returned point is dominated
        by any variant in the sweep (asserted in tests/test_sweep.py).
        """
        area = self.area(reference)
        agg = self.aggregate_mean()
        order = sorted(range(len(self.machines)),
                       key=lambda i: (area[i], agg[i]))
        front: List[int] = []
        best = np.inf
        for i in order:
            if agg[i] < best:
                front.append(i)
                best = agg[i]
        return front

    def pareto_front_3d(
        self, cost_model: CostModel = DEFAULT_COST_MODEL
    ) -> List[int]:
        """Variant indices on the (mean aggregate, area, power) Pareto front.

        All three objectives are minimized -- the full PPA trade-off of
        paper §I, with congruence standing in for "performance fit".  The
        lexicographic (area, power, aggregate) sort guarantees every
        potential dominator of a point precedes it, so checking new points
        against accepted front members is sufficient.  Returned sorted by
        increasing area.
        """
        agg = self.aggregate_mean()
        area = np.asarray(cost_model.area(self.machines))
        power = np.asarray(cost_model.power(self.machines))
        order = sorted(range(len(self.machines)),
                       key=lambda i: (area[i], power[i], agg[i]))
        front: List[int] = []
        for i in order:
            dominated = any(
                area[j] <= area[i] and power[j] <= power[i]
                and agg[j] <= agg[i]
                and (area[j] < area[i] or power[j] < power[i]
                     or agg[j] < agg[i])
                for j in front)
            if not dominated:
                front.append(i)
        return front

    def top_variants(self, k: int = 10) -> List[int]:
        """Variant indices with the lowest suite-mean aggregate."""
        order = np.argsort(self.aggregate_mean(), kind="stable")
        return [int(i) for i in order[:k]]

    # ----------------------------- reports ---------------------------- #

    def markdown(self, top_k: int = 10,
                 cost_model: CostModel = DEFAULT_COST_MODEL) -> str:
        """Top-``top_k`` variants by suite-mean aggregate + both fronts."""
        area = self.area()
        power = self.power(cost_model)
        agg = self.aggregate_mean()
        front = set(self.pareto_front())
        front3 = self.pareto_front_3d(cost_model)
        best_counts = np.bincount(self.best_fit_indices(),
                                  minlength=len(self.machines))
        lines = [
            f"sweep: {len(self.profiles)} apps x {len(self.machines)} "
            f"variants ({self.timing_model} timing, {self.backend} backend)",
            "",
            "| variant | mean aggregate | area | power | best-fit apps "
            "| pareto | peak_flops | hbm_bw | ici_bw x links "
            "| inter_pod_bw |",
            "|---" * 10 + "|",
        ]
        for i in self.top_variants(top_k):
            m = self.machines
            lines.append(
                f"| {m.names[i]} | {agg[i]:.4f} | {area[i]:.3f} "
                f"| {power[i]:.3f} "
                f"| {int(best_counts[i])} | {'*' if i in front else ''} "
                f"| {m.peak_flops[i]:.3e} | {m.hbm_bw[i]:.3e} "
                f"| {m.ici_bw[i]:.3e} x {int(m.ici_links[i])} "
                f"| {m.inter_pod_bw[i]:.3e} |")
        lines += ["", f"pareto front ({len(front)} variants, by area):", ""]
        for i in self.pareto_front():
            lines.append(
                f"- {self.machines.names[i]}: area={area[i]:.3f} "
                f"aggregate={agg[i]:.4f}")
        lines += ["", f"3-D pareto front (congruence x area x power, "
                      f"{len(front3)} variants, by area):", ""]
        for i in front3:
            lines.append(
                f"- {self.machines.names[i]}: area={area[i]:.3f} "
                f"power={power[i]:.3f} aggregate={agg[i]:.4f}")
        return "\n".join(lines)

    def to_json(self, top_k: Optional[int] = None,
                cost_model: CostModel = DEFAULT_COST_MODEL) -> dict:
        """JSON-serializable sweep summary (full score tensor omitted unless
        the sweep is small -- at 10k variants the matrix dwarfs the summary)."""
        area = self.area()
        power = self.power(cost_model)
        agg = self.aggregate_mean()
        front = self.pareto_front()
        best_idx = self.best_fit_indices()
        top = self.top_variants(top_k if top_k is not None
                                else min(len(self.machines), 32))
        out = {
            "num_apps": len(self.profiles),
            "num_variants": len(self.machines),
            "timing_model": self.timing_model,
            "backend": self.backend,
            "clamp": self.clamp,
            "apps": self.apps,
            "best_fit": {app: self.machines.names[int(best_idx[a])]
                         for a, app in enumerate(self.apps)},
            "beta_s": {app: float(self.beta[a])
                       for a, app in enumerate(self.apps)},
            "pareto_front": [
                {"variant": self.machines.names[i],
                 "area": float(area[i]),
                 "mean_aggregate": float(agg[i]),
                 "params": self.machines.params_row(i)}
                for i in front],
            "pareto_front_3d": [
                {"variant": self.machines.names[i],
                 "area": float(area[i]),
                 "power": float(power[i]),
                 "mean_aggregate": float(agg[i]),
                 "params": self.machines.params_row(i)}
                for i in self.pareto_front_3d(cost_model)],
            "top_variants": [
                {"variant": self.machines.names[i],
                 "area": float(area[i]),
                 "power": float(power[i]),
                 "mean_aggregate": float(agg[i]),
                 "best_fit_apps": [
                     app for a, app in enumerate(self.apps)
                     if int(best_idx[a]) == i],
                 "params": self.machines.params_row(i)}
                for i in top],
        }
        if len(self.machines) * len(self.profiles) <= 4096:
            out["aggregate"] = self.aggregate.tolist()
            out["scores"] = {k: v.tolist() for k, v in self.scores.items()}
        return out


def batched_congruence(
    profiles,
    machines,
    *,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = IDEAL_EPS,
    clamp: bool = False,
    backend: Optional[str] = None,
) -> SweepResult:
    """Vectorized ``profile_congruence`` over the full (apps x variants) grid.

    One ``kernels_xp.congruence_kernel`` pass computes gamma, all three
    alphas, the Eq. 1 scores and the L2 aggregates as ``(A, V)`` arrays --
    the paper's per-subsystem idealization loop becomes three scale
    substitutions on precomputed raw terms.

    ``beta`` may be None (per-app default derived from variant ``beta_ref``,
    matching ``dse.evaluate``), a scalar applied to every app, or an ``(A,)``
    array of per-app targets.  ``backend`` selects the kernel backend
    (``"numpy"``/``"jax"``; default resolves $REPRO_SWEEP_BACKEND, then
    numpy); the result tensors are always NumPy.
    """
    pb, mb = _as_profile_batch(profiles), _as_machine_batch(machines)
    if len(mb) == 0:
        raise ValueError("batched_congruence needs at least one machine variant")
    be = K.get_backend(backend)

    if beta is None:
        beta_vec = be.to_numpy(
            be.default_beta(pb.arrays(), mb.select(beta_ref).arrays()))
    else:
        beta_vec = np.broadcast_to(
            np.asarray(beta, dtype=np.float64), (len(pb),)).copy()

    out = be.congruence(pb.arrays(), mb.arrays(), beta_vec,
                        timing_model=timing_model, eps=eps, clamp=clamp)

    alphas = {
        Subsystem.COMPUTE.value: be.to_numpy(out.alpha_compute),
        Subsystem.MEMORY.value: be.to_numpy(out.alpha_memory),
        Subsystem.INTERCONNECT.value: be.to_numpy(out.alpha_interconnect),
    }
    scores = {
        "LBCS": be.to_numpy(out.lbcs),
        "HRCS": be.to_numpy(out.hrcs),
        "ICS": be.to_numpy(out.ics),
    }

    return SweepResult(
        profiles=pb,
        machines=mb,
        timing_model=timing_model,
        eps=eps,
        clamp=clamp,
        beta=beta_vec,
        gamma=be.to_numpy(out.gamma),
        alphas=alphas,
        scores=scores,
        aggregate=be.to_numpy(out.aggregate),
        backend=be.name,
    )


def run_sweep(
    profiles,
    *,
    space: Optional[ParamSpace] = None,
    n: int = 256,
    mode: str = "random",
    seed: int = 0,
    include_named: Sequence[MachineModel] = (),
    beta=None,
    beta_machine: Optional[MachineModel] = None,
    timing_model: str = "serial",
    clamp: bool = True,
    backend: Optional[str] = None,
) -> SweepResult:
    """One-call sweep: generate a population and score it.

    ``mode="random"`` draws ``n`` Halton samples; ``mode="grid"`` builds a
    full grid with ``ceil(n ** (1/d))`` points per dimension.  Any
    ``include_named`` models (e.g. the paper's baseline/denser/densest) are
    prepended.  When ``beta`` is None the per-app default target is derived
    against ``beta_machine``, defaulting to the first named model or, with
    no named models, the space's nominal chip -- never an arbitrary sampled
    design, so scores stay comparable across seeds.
    """
    profiles = _as_profile_batch(profiles)  # pack once; input may be a generator
    space = space or ParamSpace.default()
    if mode == "random":
        pop = space.sample(n, seed=seed)
    elif mode == "grid":
        per_dim = max(2, int(np.ceil(n ** (1.0 / max(len(space.dims), 1)))))
        pop = space.grid(per_dim)
    else:
        raise ValueError(f"unknown sweep mode {mode!r}")
    if include_named:
        pop = MachineBatch.concat(MachineBatch.from_models(include_named), pop)
    if beta is None:
        ref = beta_machine or (include_named[0] if include_named
                               else space.nominal)
        beta = default_beta_batched(
            profiles, MachineBatch.from_models([ref]), backend=backend)
    return batched_congruence(
        profiles, pop, beta=beta, timing_model=timing_model, clamp=clamp,
        backend=backend)
