"""Vectorized design-space sweep engine (paper §III at population scale).

The paper's central economics: packing/placement/routing (for us: the XLA
compile) is paid once per application, after which re-timing an architecture
variant is pure arithmetic.  The scalar DSE loop in ``repro.core.dse`` walks
(app, variant, subsystem) cells one at a time in Python, which wastes that
cheapness.  This module re-states the whole pipeline --
``subsystem_times`` -> ``step_time`` -> Eq. 1 ``congruence_score`` ->
aggregate (paper §II-B, §III-C) -- as struct-of-arrays NumPy kernels with
shape ``(A, V)`` (apps x variants), so sweeping thousands of machine designs
costs a handful of array ops.

Three layers:

  ParamSpace     -- bounded design space over the machine-model constants
                    (``peak_flops``, ``hbm_bw``, ``ici_bw``, ``ici_links``,
                    ``inter_pod_bw``, per-subsystem ``scale``); generates
                    populations by full grid or low-discrepancy (Halton)
                    random sampling, the paper's "denser / densest" axis
                    extended to a continuous sweep.
  MachineBatch / ProfileBatch
                 -- struct-of-arrays packings of ``MachineModel`` /
                    ``WorkloadProfile`` (one float64 array per field).
  batched_*      -- thin wrappers over the backend-agnostic kernels in
                    ``repro.core.kernels_xp`` (the SAME math the scalar
                    path runs at batch size 1), evaluated on a selectable
                    backend: ``"numpy"`` (default) or ``"jax"`` (jitted,
                    device-placed, ~1e-12 from NumPy under x64).

``SweepResult`` holds the full score tensor plus the DSE extractions the
paper's Table I points at: per-app best-fit variants (lowest aggregate =
smallest radar area, §III-C), the 2-D Pareto front of aggregate congruence
vs. silicon area, and the 3-D front over (congruence, area, power) via the
configurable ``repro.core.costmodel.CostModel`` (the PPA trade-off of §I).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import kernels_xp as K
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.costs import WorkloadProfile
from repro.core.machine import (
    IDEAL_EPS,
    MachineModel,
    Subsystem,
    TPU_V5E,
)

# The machine-model constants a sweep may vary, in canonical order.
SWEEP_PARAMS = (
    "peak_flops",
    "hbm_bw",
    "ici_bw",
    "ici_links",
    "inter_pod_bw",
    "scale_compute",
    "scale_memory",
    "scale_interconnect",
)


# --------------------------------------------------------------------------- #
# ParamSpace: grid + low-discrepancy population generators
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Dim:
    """One bounded sweep dimension.

    ``log=True`` spaces points geometrically -- hardware rates span decades,
    so a log grid is the natural "denser / densest" ladder.  ``integer``
    rounds to whole values (link counts).
    """

    lo: float
    hi: float
    log: bool = True
    integer: bool = False

    def points(self, k: int) -> np.ndarray:
        """``k`` grid points across the range (deduplicated if integer)."""
        if k <= 1:
            pts = np.array([self.hi if self.integer else
                            float(np.sqrt(self.lo * self.hi)) if self.log
                            else 0.5 * (self.lo + self.hi)])
        elif self.log:
            pts = np.geomspace(self.lo, self.hi, k)
        else:
            pts = np.linspace(self.lo, self.hi, k)
        if self.integer:
            pts = np.unique(np.rint(pts))
        return pts.astype(np.float64)

    def from_unit(self, u: np.ndarray) -> np.ndarray:
        """Map uniform [0, 1) samples onto the dimension's range."""
        u = np.asarray(u, dtype=np.float64)
        if self.integer:
            lo, hi = int(round(self.lo)), int(round(self.hi))
            return np.clip(np.floor(lo + (hi - lo + 1) * u), lo, hi)
        if self.log:
            return self.lo * (self.hi / self.lo) ** u
        return self.lo + (self.hi - self.lo) * u


_HALTON_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def _radical_inverse(index: np.ndarray, base: int) -> np.ndarray:
    """Van der Corput radical inverse of ``index`` in ``base`` (vectorized)."""
    idx = np.asarray(index, dtype=np.int64).copy()
    inv = np.zeros(idx.shape, dtype=np.float64)
    frac = 1.0 / base
    while np.any(idx > 0):
        inv += frac * (idx % base)
        idx //= base
        frac /= base
    return inv


def halton_at(indices, d: int, seed: int = 0) -> np.ndarray:
    """Rows ``indices`` of the seeded Halton sequence, shape ``(len, d)``.

    The radical inverse is elementwise in the index, so any subset of rows
    is byte-identical to slicing ``halton(n, d, seed)`` -- the property
    that lets ``PopulationStream`` regenerate an arbitrary shard of a
    mega-sweep population without materializing the rest.
    """
    if d > len(_HALTON_PRIMES):
        raise ValueError(f"halton supports at most {len(_HALTON_PRIMES)} dims")
    idx = np.asarray(indices, dtype=np.int64)
    shifts = np.random.default_rng(seed).random(d)
    out = np.empty((idx.shape[0], d), dtype=np.float64)
    for j in range(d):
        out[:, j] = (_radical_inverse(idx + 1, _HALTON_PRIMES[j])
                     + shifts[j]) % 1.0
    return out


def halton(n: int, d: int, seed: int = 0) -> np.ndarray:
    """``(n, d)`` low-discrepancy points in [0, 1).

    Halton sequence with a seeded Cranley-Patterson rotation so different
    seeds give different (still low-discrepancy) populations.
    """
    return halton_at(np.arange(n), d, seed=seed)


@dataclasses.dataclass
class ParamSpace:
    """Bounded machine design space around a ``nominal`` machine.

    ``dims`` maps a subset of ``SWEEP_PARAMS`` to ``Dim`` ranges; parameters
    not present stay pinned at the nominal machine's value.

    Example -- the default space sweeps every rate 4x below/above the
    nominal chip and generates populations by Halton sampling or full grid:

    >>> from repro.core import ParamSpace
    >>> space = ParamSpace.default(span=2.0, max_links=4)
    >>> pop = space.sample(8, seed=0)            # low-discrepancy draw
    >>> len(pop)
    8
    >>> d = space.dims["peak_flops"]
    >>> bool((pop.peak_flops >= d.lo).all() and (pop.peak_flops <= d.hi).all())
    True
    >>> grid = space.grid({"peak_flops": 3, "ici_links": 2})
    >>> len(grid)                                # 3 x 2 cross-product
    6
    """

    dims: Dict[str, Dim]
    nominal: MachineModel = TPU_V5E

    def __post_init__(self) -> None:
        for name in self.dims:
            if name not in SWEEP_PARAMS:
                raise KeyError(
                    f"unknown sweep parameter {name!r}; have {SWEEP_PARAMS}")

    @staticmethod
    def default(nominal: MachineModel = TPU_V5E, span: float = 4.0,
                max_links: int = 8) -> "ParamSpace":
        """The paper's density ladder as a continuous space: every rate swept
        geometrically ``span``x below/above the nominal chip, link count up
        to ``max_links``."""
        dims = {
            "peak_flops": Dim(nominal.peak_flops / span, nominal.peak_flops * span),
            "hbm_bw": Dim(nominal.hbm_bw / span, nominal.hbm_bw * span),
            "ici_bw": Dim(nominal.ici_bw / span, nominal.ici_bw * span),
            "ici_links": Dim(1, max_links, log=False, integer=True),
            "inter_pod_bw": Dim(nominal.inter_pod_bw / span,
                                nominal.inter_pod_bw * span),
        }
        return ParamSpace(dims=dims, nominal=nominal)

    @staticmethod
    def scale_space(nominal: MachineModel = TPU_V5E, span: float = 4.0,
                    max_links: int = 8, scale_span: float = 4.0
                    ) -> "ParamSpace":
        """``default()`` plus the per-subsystem idealization scales as
        swept dimensions (``scale_span``x below/above 1.0) -- the
        stress-test preset that exercises every ``SWEEP_PARAMS`` column
        at once, promoted from the test suite's local helper per the
        ROADMAP's generated-workload item.

        >>> space = ParamSpace.scale_space(scale_span=2.0)
        >>> sorted(space.dims) == sorted(SWEEP_PARAMS)
        True
        >>> space.dims["scale_compute"].lo
        0.5
        """
        space = ParamSpace.default(nominal=nominal, span=span,
                                   max_links=max_links)
        dims = dict(space.dims)
        for name in ("scale_compute", "scale_memory", "scale_interconnect"):
            dims[name] = Dim(1.0 / scale_span, scale_span)
        return ParamSpace(dims=dims, nominal=nominal)

    # ------------------------------------------------------------------ #

    def _nominal_value(self, name: str) -> float:
        if name.startswith("scale_"):
            return self.nominal.scale_for(Subsystem(name[len("scale_"):]))
        return float(getattr(self.nominal, name))

    def _columns_to_batch(self, cols: Dict[str, np.ndarray], n: int,
                          prefix: str) -> "MachineBatch":
        return self._columns_to_batch_at(cols, np.arange(n), prefix)

    def _columns_to_batch_at(self, cols: Dict[str, np.ndarray], indices,
                             prefix: str) -> "MachineBatch":
        """Pack generated columns, naming rows by their GLOBAL indices --
        so a regenerated shard carries the same names as the full batch."""
        idx = np.asarray(indices, dtype=np.int64)
        full = {}
        for name in SWEEP_PARAMS:
            if name in cols:
                full[name] = np.asarray(cols[name], dtype=np.float64)
            else:
                full[name] = np.full(idx.shape[0], self._nominal_value(name))
        return MachineBatch(
            names=[f"{prefix}{i:05d}" for i in idx], **full)

    def grid_axes(self, points: Union[int, Mapping[str, int]] = 3
                  ) -> Dict[str, np.ndarray]:
        """Per-dimension grid point arrays (the factors of ``grid``'s
        cross-product), WITHOUT materializing the product itself."""
        if isinstance(points, int):
            points = {name: points for name in self.dims}
        return {name: self.dims[name].points(k) for name, k in points.items()
                if name in self.dims}

    def grid(self, points: Union[int, Mapping[str, int]] = 3) -> "MachineBatch":
        """Full cross-product grid.

        ``points`` is either a per-dimension count mapping or one count
        applied to every dimension in the space.
        """
        axes = self.grid_axes(points)
        names = list(axes)
        combos = list(itertools.product(*(axes[n] for n in names)))
        cols = {n: np.array([c[i] for c in combos], dtype=np.float64)
                for i, n in enumerate(names)}
        return self._columns_to_batch(cols, len(combos), "grid-")

    def grid_at(self, indices, points: Union[int, Mapping[str, int]] = 3
                ) -> "MachineBatch":
        """Rows ``indices`` of ``grid(points)`` without building the grid.

        ``itertools.product`` emits combinations in row-major order, so row
        ``i`` unravels to per-dimension positions by mixed-radix division --
        an O(len(indices)) computation regardless of the grid's size.
        """
        axes = self.grid_axes(points)
        names = list(axes)
        lens = [len(axes[n]) for n in names]
        idx = np.asarray(indices, dtype=np.int64)
        cols = {}
        stride = 1
        strides = [0] * len(names)
        for j in range(len(names) - 1, -1, -1):
            strides[j] = stride
            stride *= lens[j]
        for j, n in enumerate(names):
            cols[n] = axes[n][(idx // strides[j]) % lens[j]]
        return self._columns_to_batch_at(cols, idx, "grid-")

    def sample(self, n: int, seed: int = 0) -> "MachineBatch":
        """``n`` low-discrepancy (Halton) samples across every dimension."""
        return self.sample_at(np.arange(n), seed=seed)

    def sample_at(self, indices, seed: int = 0) -> "MachineBatch":
        """Rows ``indices`` of ``sample(n, seed)`` -- byte-identical to
        slicing the full draw (``halton_at`` is elementwise in the index),
        which is what lets streamed mega-sweeps regenerate any shard."""
        names = list(self.dims)
        idx = np.asarray(indices, dtype=np.int64)
        unit = halton_at(idx, len(names), seed=seed)
        cols = {name: self.dims[name].from_unit(unit[:, j])
                for j, name in enumerate(names)}
        return self._columns_to_batch_at(cols, idx, "sweep-")


# --------------------------------------------------------------------------- #
# Struct-of-arrays packings
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class MachineBatch:
    """``V`` machine variants as one float64 array per model constant."""

    names: List[str]
    peak_flops: np.ndarray
    hbm_bw: np.ndarray
    ici_bw: np.ndarray
    ici_links: np.ndarray
    inter_pod_bw: np.ndarray
    scale_compute: np.ndarray
    scale_memory: np.ndarray
    scale_interconnect: np.ndarray

    def __len__(self) -> int:
        return len(self.names)

    @property
    def ici_bw_total(self) -> np.ndarray:
        return self.ici_bw * self.ici_links

    def scale_for(self, subsystem: Subsystem) -> np.ndarray:
        return {
            Subsystem.COMPUTE: self.scale_compute,
            Subsystem.MEMORY: self.scale_memory,
            Subsystem.INTERCONNECT: self.scale_interconnect,
        }[subsystem]

    @staticmethod
    def from_models(models: Sequence[MachineModel]) -> "MachineBatch":
        arr = lambda get: np.array([get(m) for m in models], dtype=np.float64)
        return MachineBatch(
            names=[m.name for m in models],
            peak_flops=arr(lambda m: m.peak_flops),
            hbm_bw=arr(lambda m: m.hbm_bw),
            ici_bw=arr(lambda m: m.ici_bw),
            ici_links=arr(lambda m: m.ici_links),
            inter_pod_bw=arr(lambda m: m.inter_pod_bw),
            scale_compute=arr(lambda m: m.scale_for(Subsystem.COMPUTE)),
            scale_memory=arr(lambda m: m.scale_for(Subsystem.MEMORY)),
            scale_interconnect=arr(lambda m: m.scale_for(Subsystem.INTERCONNECT)),
        )

    @staticmethod
    def concat(*batches: "MachineBatch") -> "MachineBatch":
        cat = lambda get: np.concatenate([get(b) for b in batches])
        return MachineBatch(
            names=[n for b in batches for n in b.names],
            peak_flops=cat(lambda b: b.peak_flops),
            hbm_bw=cat(lambda b: b.hbm_bw),
            ici_bw=cat(lambda b: b.ici_bw),
            ici_links=cat(lambda b: b.ici_links),
            inter_pod_bw=cat(lambda b: b.inter_pod_bw),
            scale_compute=cat(lambda b: b.scale_compute),
            scale_memory=cat(lambda b: b.scale_memory),
            scale_interconnect=cat(lambda b: b.scale_interconnect),
        )

    def slice(self, lo: int, hi: int) -> "MachineBatch":
        """Contiguous sub-batch ``[lo, hi)`` (one shard of a sharded sweep)."""
        sel = {name: getattr(self, name)[lo:hi] for name in SWEEP_PARAMS}
        return MachineBatch(names=self.names[lo:hi], **sel)

    def take(self, indices) -> "MachineBatch":
        """Arbitrary sub-batch by variant index (Pareto-survivor gathers)."""
        idx = np.asarray(indices, dtype=np.int64)
        sel = {name: getattr(self, name)[idx] for name in SWEEP_PARAMS}
        return MachineBatch(names=[self.names[i] for i in idx], **sel)

    def model(self, i: int) -> MachineModel:
        """Materialize variant ``i`` as a scalar ``MachineModel``."""
        return MachineModel(
            name=self.names[i],
            peak_flops=float(self.peak_flops[i]),
            hbm_bw=float(self.hbm_bw[i]),
            ici_bw=float(self.ici_bw[i]),
            ici_links=int(self.ici_links[i]),
            inter_pod_bw=float(self.inter_pod_bw[i]),
            scale={
                Subsystem.COMPUTE.value: float(self.scale_compute[i]),
                Subsystem.MEMORY.value: float(self.scale_memory[i]),
                Subsystem.INTERCONNECT.value: float(self.scale_interconnect[i]),
            },
        )

    def models(self) -> List[MachineModel]:
        return [self.model(i) for i in range(len(self))]

    def area(self, reference: MachineModel = TPU_V5E) -> np.ndarray:
        """Relative silicon/cost proxy per variant (see ``CostModel.area``;
        the default equal-weight model is used, matching the historical
        four-rate-mean proxy exactly)."""
        return CostModel(reference=reference).area(self)

    def arrays(self) -> K.MachineArrays:
        """The kernel-layer view: one ``MachineArrays`` namedtuple."""
        return K.MachineArrays(
            peak_flops=self.peak_flops,
            hbm_bw=self.hbm_bw,
            ici_bw=self.ici_bw,
            ici_links=self.ici_links,
            inter_pod_bw=self.inter_pod_bw,
            scale_compute=self.scale_compute,
            scale_memory=self.scale_memory,
            scale_interconnect=self.scale_interconnect,
        )

    def select(self, i: int) -> "MachineBatch":
        """Single-variant sub-batch (used as the default-beta reference)."""
        sel = {name: getattr(self, name)[i:i + 1] for name in SWEEP_PARAMS}
        return MachineBatch(names=[self.names[i]], **sel)

    def params_row(self, i: int) -> Dict[str, float]:
        return {name: float(getattr(self, name)[i]) for name in SWEEP_PARAMS}


@dataclasses.dataclass
class ProfileBatch:
    """``A`` workload profiles packed into the arrays the timing model reads.

    ``mem_bytes`` applies the scalar path's fallback (``hbm_bytes`` when
    positive, else raw ``bytes_accessed``) at pack time.
    """

    names: List[str]
    flops: np.ndarray
    mem_bytes: np.ndarray
    collective_bytes: np.ndarray
    pod_collective_bytes: np.ndarray
    model_flops: np.ndarray
    num_devices: np.ndarray
    profiles: List[WorkloadProfile]

    def __len__(self) -> int:
        return len(self.names)

    @staticmethod
    def from_profiles(profiles: Sequence[WorkloadProfile]) -> "ProfileBatch":
        profiles = list(profiles)
        return ProfileBatch(
            names=[p.name for p in profiles],
            flops=np.array([p.flops for p in profiles], dtype=np.float64),
            mem_bytes=np.array(
                [p.hbm_bytes if p.hbm_bytes > 0 else p.bytes_accessed
                 for p in profiles], dtype=np.float64),
            collective_bytes=np.array(
                [p.total_collective_bytes for p in profiles], dtype=np.float64),
            pod_collective_bytes=np.array(
                [p.pod_collective_bytes for p in profiles], dtype=np.float64),
            model_flops=np.array(
                [p.model_flops for p in profiles], dtype=np.float64),
            num_devices=np.array(
                [p.num_devices for p in profiles], dtype=np.float64),
            profiles=profiles,
        )

    def arrays(self) -> K.ProfileArrays:
        """The kernel-layer view: one ``ProfileArrays`` namedtuple."""
        return K.ProfileArrays(
            flops=self.flops,
            mem_bytes=self.mem_bytes,
            collective_bytes=self.collective_bytes,
            pod_collective_bytes=self.pod_collective_bytes,
            model_flops=self.model_flops,
            num_devices=self.num_devices,
        )

    @staticmethod
    def concat(*batches: "ProfileBatch") -> "ProfileBatch":
        """Concatenate suites along the app axis (micro-batch admission)."""
        cat = lambda get: np.concatenate([get(b) for b in batches])
        return ProfileBatch(
            names=[n for b in batches for n in b.names],
            flops=cat(lambda b: b.flops),
            mem_bytes=cat(lambda b: b.mem_bytes),
            collective_bytes=cat(lambda b: b.collective_bytes),
            pod_collective_bytes=cat(lambda b: b.pod_collective_bytes),
            model_flops=cat(lambda b: b.model_flops),
            num_devices=cat(lambda b: b.num_devices),
            profiles=[p for b in batches for p in b.profiles],
        )

    def take(self, indices) -> "ProfileBatch":
        """Sub-suite by app index (micro-batch scatter)."""
        idx = [int(i) for i in indices]
        return ProfileBatch(
            names=[self.names[i] for i in idx],
            flops=self.flops[idx],
            mem_bytes=self.mem_bytes[idx],
            collective_bytes=self.collective_bytes[idx],
            pod_collective_bytes=self.pod_collective_bytes[idx],
            model_flops=self.model_flops[idx],
            num_devices=self.num_devices[idx],
            profiles=[self.profiles[i] for i in idx],
        )


def _as_profile_batch(profiles) -> ProfileBatch:
    if isinstance(profiles, str):
        # Suite name ("zoo", "zoo-smoke:train", ...): every entry point
        # that packs profiles accepts the model-zoo suites by name.
        from repro.core.model_zoo import resolve_suite

        profiles = resolve_suite(profiles)
    if isinstance(profiles, ProfileBatch):
        return profiles
    return ProfileBatch.from_profiles(list(profiles))


def _as_machine_batch(machines) -> MachineBatch:
    if isinstance(machines, MachineBatch):
        return machines
    return MachineBatch.from_models(list(machines))


# --------------------------------------------------------------------------- #
# Batched timing + congruence -- thin wrappers over repro.core.kernels_xp
# --------------------------------------------------------------------------- #


def batched_step_time(
    profiles, machines, timing_model: str = "serial",
    backend: Optional[str] = None,
) -> np.ndarray:
    """``(A, V)`` step-time matrix -- vectorized ``timing.step_time``."""
    pb, mb = _as_profile_batch(profiles), _as_machine_batch(machines)
    be = K.get_backend(backend)
    return be.to_numpy(be.step_time(pb.arrays(), mb.arrays(), timing_model))


def default_beta_batched(
    profiles, machines, beta_ref: int = 0,
    backend: Optional[str] = None,
) -> np.ndarray:
    """Vectorized ``congruence.default_beta`` against variant ``beta_ref``.

    The paper's beta is a per-application user target held constant across
    variants (Table I compares architectures against one target), so the
    default derives from a single reference variant -- by convention the
    first ("baseline") column, matching ``dse.evaluate``.
    """
    pb, mb = _as_profile_batch(profiles), _as_machine_batch(machines)
    be = K.get_backend(backend)
    return be.to_numpy(
        be.default_beta(pb.arrays(), mb.select(beta_ref).arrays()))


def pareto_front_indices(area, aggregate) -> List[int]:
    """Indices on the 2-D (area, aggregate) Pareto front, both minimized.

    Sorted by increasing area; a point is admitted only when it strictly
    improves the best aggregate seen so far, so no returned point is
    dominated by any input point.  Shared by ``SweepResult.pareto_front``
    and the per-shard pre-filter in ``shard_sweep``.
    """
    area = np.asarray(area)
    aggregate = np.asarray(aggregate)
    order = sorted(range(len(area)), key=lambda i: (area[i], aggregate[i]))
    front: List[int] = []
    best = np.inf
    for i in order:
        if aggregate[i] < best:
            front.append(i)
            best = aggregate[i]
    return front


def pareto_front_indices_3d(aggregate, area, power) -> List[int]:
    """Indices on the 3-D (aggregate, area, power) front, all minimized.

    The lexicographic (area, power, aggregate) sort guarantees every
    potential dominator of a point precedes it, so checking new points
    against accepted front members is sufficient.  Sorted by increasing
    area.
    """
    aggregate = np.asarray(aggregate)
    area = np.asarray(area)
    power = np.asarray(power)
    order = sorted(range(len(area)),
                   key=lambda i: (area[i], power[i], aggregate[i]))
    front: List[int] = []
    for i in order:
        dominated = any(
            area[j] <= area[i] and power[j] <= power[i]
            and aggregate[j] <= aggregate[i]
            and (area[j] < area[i] or power[j] < power[i]
                 or aggregate[j] < aggregate[i])
            for j in front)
        if not dominated:
            front.append(i)
    return front


@dataclasses.dataclass
class SweepResult:
    """Full ``(A, V)`` score tensor plus the Table I / Pareto extractions."""

    profiles: ProfileBatch
    machines: MachineBatch
    timing_model: str
    eps: float
    clamp: bool
    beta: np.ndarray                 # (A,) per-app target
    gamma: np.ndarray                # (A, V) baseline step times
    alphas: Dict[str, np.ndarray]    # subsystem value -> (A, V)
    scores: Dict[str, np.ndarray]    # ICS/HRCS/LBCS -> (A, V)
    aggregate: np.ndarray            # (A, V) L2 magnitudes
    backend: str = "numpy"           # kernel backend that produced the tensors

    # ------------------------------ lookups --------------------------- #

    @property
    def apps(self) -> List[str]:
        return list(self.profiles.names)

    @property
    def variant_names(self) -> List[str]:
        return list(self.machines.names)

    def app_index(self, app: str) -> int:
        return self.profiles.names.index(app)

    # --------------------------- extractions -------------------------- #

    def best_fit_indices(self) -> np.ndarray:
        """Per-app argmin over variants (lowest aggregate = best fit)."""
        return np.argmin(self.aggregate, axis=1)

    def best_fit(self, app: str) -> str:
        return self.machines.names[int(
            np.argmin(self.aggregate[self.app_index(app)]))]

    def aggregate_mean(self) -> np.ndarray:
        """Suite-mean aggregate per variant (Table I bottom row), shape (V,)."""
        return self.aggregate.mean(axis=0)

    def area(self, reference: MachineModel = TPU_V5E) -> np.ndarray:
        return self.machines.area(reference)

    def power(self, cost_model: CostModel = DEFAULT_COST_MODEL) -> np.ndarray:
        """Relative dynamic-power proxy per variant (``CostModel.power``)."""
        return cost_model.power(self.machines)

    def pareto_front(self, reference: MachineModel = TPU_V5E) -> List[int]:
        """Variant indices on the (area, mean aggregate) Pareto front.

        Both axes are minimized: cheaper silicon and better congruence fit.
        Returned sorted by increasing area; no returned point is dominated
        by any variant in the sweep (asserted in tests/test_sweep.py).
        """
        return pareto_front_indices(self.area(reference),
                                    self.aggregate_mean())

    def pareto_front_3d(
        self, cost_model: CostModel = DEFAULT_COST_MODEL
    ) -> List[int]:
        """Variant indices on the (mean aggregate, area, power) Pareto front.

        All three objectives are minimized -- the full PPA trade-off of
        paper §I, with congruence standing in for "performance fit".
        Returned sorted by increasing area.
        """
        return pareto_front_indices_3d(self.aggregate_mean(),
                                       cost_model.area(self.machines),
                                       cost_model.power(self.machines))

    def top_variants(self, k: int = 10) -> List[int]:
        """Variant indices with the lowest suite-mean aggregate."""
        order = np.argsort(self.aggregate_mean(), kind="stable")
        return [int(i) for i in order[:k]]

    def seed_codesign(self, k: Optional[int] = None,
                      cost_model: CostModel = DEFAULT_COST_MODEL,
                      ) -> MachineBatch:
        """Pareto survivors as a warm-start seed for gradient co-design.

        The sweep answers "which sampled designs win?"; its winners are
        the natural SEEDS for the continuous descent in
        ``repro.core.codesign`` / ``repro.core.constrained``.  Returns the
        union of the 2-D and 3-D Pareto fronts (under ``cost_model``) plus
        every per-app best fit, deduplicated, ordered by suite-mean
        aggregate, optionally truncated to the best ``k`` -- ready to pass
        straight to ``grad_codesign`` / ``constrained_codesign`` as
        ``machines``.

        >>> from repro.core import WorkloadProfile, run_sweep
        >>> apps = [WorkloadProfile(name="app0", flops=2e14,
        ...                         hbm_bytes=1.5e11,
        ...                         collective_bytes={"all-reduce": 2e10},
        ...                         num_devices=256, model_flops=5e16)]
        >>> res = run_sweep(apps, n=64, seed=0)
        >>> seeds = res.seed_codesign(k=4)
        >>> 1 <= len(seeds) <= 4
        True
        >>> set(seeds.names) <= set(res.variant_names)
        True
        """
        agg = self.aggregate_mean()
        survivors = set(pareto_front_indices(cost_model.area(self.machines),
                                             agg))
        survivors.update(self.pareto_front_3d(cost_model))
        survivors.update(int(i) for i in self.best_fit_indices())
        order = sorted(survivors, key=lambda i: (agg[i], i))
        if k is not None:
            order = order[:k]
        return self.machines.take(order)

    def frontier(self, budgets, k: Optional[int] = None,
                 cost_model: CostModel = DEFAULT_COST_MODEL, **kwargs):
        """Trace the feasibility frontier J*(budget) from this sweep.

        The sweep's Pareto survivors (``seed_codesign``) warm-start
        ``repro.core.frontier.frontier_codesign`` over the same profile
        suite -- global exploration hands its winners to the budget
        continuation.  ``kwargs`` forward to ``frontier_codesign``
        (``power_budget=``, ``area_envelope=``, ``steps=``, ...).
        """
        from repro.core.frontier import frontier_codesign
        return frontier_codesign(
            self.profiles, self.seed_codesign(k=k, cost_model=cost_model),
            budgets, cost_model=cost_model, **kwargs)

    # ----------------------------- reports ---------------------------- #

    def markdown(self, top_k: Optional[int] = None,
                 cost_model: CostModel = DEFAULT_COST_MODEL) -> str:
        """Top-``top_k`` variants by suite-mean aggregate + both fronts.

        ``top_k=None`` means the default of 10 -- part of the uniform
        result protocol (every result type exposes ``markdown(top_k=...)``
        / ``to_json(top_k=...)``; see docs/serving.md)."""
        top_k = 10 if top_k is None else top_k
        area = self.area()
        power = self.power(cost_model)
        agg = self.aggregate_mean()
        front = set(self.pareto_front())
        front3 = self.pareto_front_3d(cost_model)
        best_counts = np.bincount(self.best_fit_indices(),
                                  minlength=len(self.machines))
        lines = [
            f"sweep: {len(self.profiles)} apps x {len(self.machines)} "
            f"variants ({self.timing_model} timing, {self.backend} backend)",
            "",
            "| variant | mean aggregate | area | power | best-fit apps "
            "| pareto | peak_flops | hbm_bw | ici_bw x links "
            "| inter_pod_bw |",
            "|---" * 10 + "|",
        ]
        for i in self.top_variants(top_k):
            m = self.machines
            lines.append(
                f"| {m.names[i]} | {agg[i]:.4f} | {area[i]:.3f} "
                f"| {power[i]:.3f} "
                f"| {int(best_counts[i])} | {'*' if i in front else ''} "
                f"| {m.peak_flops[i]:.3e} | {m.hbm_bw[i]:.3e} "
                f"| {m.ici_bw[i]:.3e} x {int(m.ici_links[i])} "
                f"| {m.inter_pod_bw[i]:.3e} |")
        lines += ["", f"pareto front ({len(front)} variants, by area):", ""]
        for i in self.pareto_front():
            lines.append(
                f"- {self.machines.names[i]}: area={area[i]:.3f} "
                f"aggregate={agg[i]:.4f}")
        lines += ["", f"3-D pareto front (congruence x area x power, "
                      f"{len(front3)} variants, by area):", ""]
        for i in front3:
            lines.append(
                f"- {self.machines.names[i]}: area={area[i]:.3f} "
                f"power={power[i]:.3f} aggregate={agg[i]:.4f}")
        return "\n".join(lines)

    def to_json(self, top_k: Optional[int] = None,
                cost_model: CostModel = DEFAULT_COST_MODEL) -> dict:
        """JSON-serializable sweep summary (full score tensor omitted unless
        the sweep is small -- at 10k variants the matrix dwarfs the summary)."""
        area = self.area()
        power = self.power(cost_model)
        agg = self.aggregate_mean()
        front = self.pareto_front()
        best_idx = self.best_fit_indices()
        top = self.top_variants(top_k if top_k is not None
                                else min(len(self.machines), 32))
        out = {
            "num_apps": len(self.profiles),
            "num_variants": len(self.machines),
            "timing_model": self.timing_model,
            "backend": self.backend,
            "clamp": self.clamp,
            "apps": self.apps,
            "best_fit": {app: self.machines.names[int(best_idx[a])]
                         for a, app in enumerate(self.apps)},
            "beta_s": {app: float(self.beta[a])
                       for a, app in enumerate(self.apps)},
            "pareto_front": [
                {"variant": self.machines.names[i],
                 "area": float(area[i]),
                 "mean_aggregate": float(agg[i]),
                 "params": self.machines.params_row(i)}
                for i in front],
            "pareto_front_3d": [
                {"variant": self.machines.names[i],
                 "area": float(area[i]),
                 "power": float(power[i]),
                 "mean_aggregate": float(agg[i]),
                 "params": self.machines.params_row(i)}
                for i in self.pareto_front_3d(cost_model)],
            "top_variants": [
                {"variant": self.machines.names[i],
                 "area": float(area[i]),
                 "power": float(power[i]),
                 "mean_aggregate": float(agg[i]),
                 "best_fit_apps": [
                     app for a, app in enumerate(self.apps)
                     if int(best_idx[a]) == i],
                 "params": self.machines.params_row(i)}
                for i in top],
        }
        if len(self.machines) * len(self.profiles) <= 4096:
            out["aggregate"] = self.aggregate.tolist()
            out["scores"] = {k: v.tolist() for k, v in self.scores.items()}
        return out

    # --------------------------- micro-batching ----------------------- #

    def app_slice(self, indices) -> "SweepResult":
        """Sub-result over a subset of app rows.

        Every kernel quantity is app-rowwise independent (each row is one
        app's profile scored against every variant), so slicing rows of a
        merged multi-suite sweep is byte-identical to running the sweep on
        the sub-suite directly -- the invariant the serving front door's
        micro-batching rests on (pinned in tests/test_serving.py).
        """
        idx = [int(i) for i in indices]
        return SweepResult(
            profiles=self.profiles.take(idx),
            machines=self.machines,
            timing_model=self.timing_model,
            eps=self.eps,
            clamp=self.clamp,
            beta=self.beta[idx],
            gamma=self.gamma[idx],
            alphas={k: v[idx] for k, v in self.alphas.items()},
            scores={k: v[idx] for k, v in self.scores.items()},
            aggregate=self.aggregate[idx],
            backend=self.backend,
        )


def batched_congruence(
    profiles,
    machines,
    *,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = IDEAL_EPS,
    clamp: bool = False,
    backend: Optional[str] = None,
) -> SweepResult:
    """Vectorized ``profile_congruence`` over the full (apps x variants) grid.

    One ``kernels_xp.congruence_kernel`` pass computes gamma, all three
    alphas, the Eq. 1 scores and the L2 aggregates as ``(A, V)`` arrays --
    the paper's per-subsystem idealization loop becomes three scale
    substitutions on precomputed raw terms.

    ``beta`` may be None (per-app default derived from variant ``beta_ref``,
    matching ``dse.evaluate``), a scalar applied to every app, or an ``(A,)``
    array of per-app targets.  ``backend`` selects the kernel backend
    (``"numpy"``/``"jax"``; default resolves $REPRO_SWEEP_BACKEND, then
    numpy); the result tensors are always NumPy.
    """
    pb, mb = _as_profile_batch(profiles), _as_machine_batch(machines)
    if len(mb) == 0:
        raise ValueError("batched_congruence needs at least one machine variant")
    be = K.get_backend(backend)

    if beta is None:
        beta_vec = be.to_numpy(
            be.default_beta(pb.arrays(), mb.select(beta_ref).arrays()))
    else:
        beta_vec = np.broadcast_to(
            np.asarray(beta, dtype=np.float64), (len(pb),)).copy()

    out = be.congruence(pb.arrays(), mb.arrays(), beta_vec,
                        timing_model=timing_model, eps=eps, clamp=clamp)

    alphas = {
        Subsystem.COMPUTE.value: be.to_numpy(out.alpha_compute),
        Subsystem.MEMORY.value: be.to_numpy(out.alpha_memory),
        Subsystem.INTERCONNECT.value: be.to_numpy(out.alpha_interconnect),
    }
    scores = {
        "LBCS": be.to_numpy(out.lbcs),
        "HRCS": be.to_numpy(out.hrcs),
        "ICS": be.to_numpy(out.ics),
    }

    return SweepResult(
        profiles=pb,
        machines=mb,
        timing_model=timing_model,
        eps=eps,
        clamp=clamp,
        beta=beta_vec,
        gamma=be.to_numpy(out.gamma),
        alphas=alphas,
        scores=scores,
        aggregate=be.to_numpy(out.aggregate),
        backend=be.name,
    )


def _population(space: ParamSpace, n: int, mode: str, seed: int,
                include_named: Sequence[MachineModel]) -> MachineBatch:
    """The population ``run_sweep`` and ``shard_sweep`` share.

    Kept in one place so a sharded sweep scores the exact same variants
    (names included) as the single-device sweep it replaces.
    """
    if mode == "random":
        pop = space.sample(n, seed=seed)
    elif mode == "grid":
        per_dim = max(2, int(np.ceil(n ** (1.0 / max(len(space.dims), 1)))))
        pop = space.grid(per_dim)
    else:
        raise ValueError(f"unknown sweep mode {mode!r}")
    if include_named:
        pop = MachineBatch.concat(MachineBatch.from_models(include_named), pop)
    return pop


# --------------------------------------------------------------------------- #
# Streamed populations: V >> RAM without ever holding the full MachineBatch
# --------------------------------------------------------------------------- #


class PopulationStream:
    """Index-addressable population source for mega-sweeps.

    ``_population`` materializes all ``V`` variants up front -- fine to a
    few million, fatal at 100M+.  A stream instead REGENERATES any index
    range on demand: Halton rows are elementwise in the sample index
    (``ParamSpace.sample_at``) and grid rows unravel by mixed-radix
    division (``grid_at``), so ``batch(lo, hi)`` for any shard is
    byte-identical to ``_population(...)[lo:hi]`` while only that shard
    ever exists in memory.  Named models (the paper's baseline ladder) are
    prepended exactly as ``_population`` prepends them.

    ``load_population`` returns the second flavor: fields memory-mapped
    from a ``save_population`` directory, for populations generated
    elsewhere (or expensive spaces worth generating once).

    >>> from repro.core import ParamSpace
    >>> from repro.core.sweep import PopulationStream, _population
    >>> space = ParamSpace.default()
    >>> stream = PopulationStream(space, 1000, seed=3)
    >>> full = _population(space, 1000, "random", 3, [])
    >>> shard = stream.batch(400, 500)
    >>> shard.names == full.names[400:500]
    True
    >>> bool((shard.peak_flops == full.peak_flops[400:500]).all())
    True
    """

    def __init__(self, space: ParamSpace, n: int, mode: str = "random",
                 seed: int = 0,
                 include_named: Sequence[MachineModel] = ()):
        self.space = space
        self.mode = mode
        self.seed = seed
        self._n_request = n
        self._named_models = list(include_named)
        self.named = (MachineBatch.from_models(self._named_models)
                      if self._named_models else None)
        if mode == "random":
            self._grid_points = None
            self._gen_n = int(n)
        elif mode == "grid":
            per_dim = max(2, int(np.ceil(
                n ** (1.0 / max(len(space.dims), 1)))))
            self._grid_points = per_dim
            lens = [len(a) for a in space.grid_axes(per_dim).values()]
            self._gen_n = int(np.prod(lens)) if lens else 1
        else:
            raise ValueError(f"unknown sweep mode {mode!r}")
        self._fields = None  # set by _from_dir for the memory-mapped flavor
        self._names_arr = None

    @classmethod
    def _from_dir(cls, path: str) -> "PopulationStream":
        obj = cls.__new__(cls)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        obj.space = None
        obj.mode = "mmap"
        obj.seed = 0
        obj._n_request = int(meta["num_variants"])
        obj._named_models = []
        obj.named = None
        obj._grid_points = None
        obj._gen_n = int(meta["num_variants"])
        obj._fields = {
            name: np.load(os.path.join(path, f"{name}.npy"), mmap_mode="r")
            for name in SWEEP_PARAMS}
        obj._names_arr = np.load(os.path.join(path, "names.npy"),
                                 mmap_mode="r")
        obj.path = path
        return obj

    # ------------------------------------------------------------------ #

    def __len__(self) -> int:
        k = len(self.named) if self.named is not None else 0
        return k + self._gen_n

    @property
    def num_named(self) -> int:
        return len(self.named) if self.named is not None else 0

    def _generated(self, idx: np.ndarray) -> MachineBatch:
        """Generated rows by 0-based GENERATED index (named rows excluded)."""
        if self._fields is not None:
            sel = {name: np.asarray(arr[idx], dtype=np.float64)
                   for name, arr in self._fields.items()}
            return MachineBatch(
                names=[str(n) for n in self._names_arr[idx]], **sel)
        if self.mode == "random":
            return self.space.sample_at(idx, seed=self.seed)
        return self.space.grid_at(idx, self._grid_points)

    def batch(self, lo: int, hi: int) -> MachineBatch:
        """Contiguous ``[lo, hi)`` slice -- one shard of a streamed sweep."""
        k = self.num_named
        parts = []
        if lo < k:
            parts.append(self.named.slice(lo, min(hi, k)))
        if hi > k:
            parts.append(self._generated(np.arange(max(lo - k, 0), hi - k)))
        return parts[0] if len(parts) == 1 else MachineBatch.concat(*parts)

    def take(self, indices) -> MachineBatch:
        """Arbitrary rows by global index (the survivor re-score gather)."""
        idx = np.asarray(indices, dtype=np.int64)
        k = self.num_named
        if k == 0:
            return self._generated(idx)
        named_mask = idx < k
        if named_mask.all():
            return self.named.take(idx)
        if not named_mask.any():
            return self._generated(idx - k)
        named_part = self.named.take(idx[named_mask])
        gen_part = self._generated(idx[~named_mask] - k)
        pos_named = np.nonzero(named_mask)[0]
        pos_gen = np.nonzero(~named_mask)[0]
        fields = {}
        for name in SWEEP_PARAMS:
            col = np.empty(idx.shape[0], dtype=np.float64)
            col[pos_named] = getattr(named_part, name)
            col[pos_gen] = getattr(gen_part, name)
            fields[name] = col
        names: List[str] = [""] * idx.shape[0]
        for j, nm in zip(pos_named, named_part.names):
            names[j] = nm
        for j, nm in zip(pos_gen, gen_part.names):
            names[j] = nm
        return MachineBatch(names=names, **fields)

    def materialize(self) -> MachineBatch:
        """The full batch (smoke-scale equality tests; do NOT call at 100M)."""
        if self._fields is not None:
            return self.batch(0, len(self))
        return _population(self.space, self._n_request, self.mode, self.seed,
                           self._named_models)

    # ------------------------------------------------------------------ #

    def _name_width(self) -> int:
        if self._names_arr is not None:
            return self._names_arr.dtype.itemsize // 4
        prefix = "sweep-" if self.mode == "random" else "grid-"
        digits = max(5, len(str(max(self._gen_n - 1, 0))))
        width = len(prefix) + digits
        if self.named is not None:
            width = max(width, max(len(n) for n in self.named.names))
        return width

    def signature(self) -> str:
        """Cheap identity for checkpoint-compatibility checks."""
        if self._fields is not None:
            return f"mmap:{os.path.abspath(self.path)}:{self._gen_n}"
        named = ",".join(m.name for m in self._named_models)
        return (f"gen:{self.mode}:{self.seed}:{self._n_request}:"
                f"[{named}]:{self.space!r}")


def save_population(path: str, population, shard_size: int = 1 << 16) -> str:
    """Write a population to ``path/`` as memory-mappable arrays.

    One float64 ``.npy`` per sweep parameter plus fixed-width unicode
    ``names.npy`` and a ``meta.json``; written shard-by-shard through
    ``np.lib.format.open_memmap`` so saving a ``PopulationStream`` never
    materializes it.  Float64 round-trips exactly, so a sweep over
    ``load_population(path)`` is byte-identical to one over the source.
    """
    if not isinstance(population, (MachineBatch, PopulationStream)):
        population = _as_machine_batch(population)
    os.makedirs(path, exist_ok=True)
    v = len(population)
    if isinstance(population, MachineBatch):
        width = max((len(n) for n in population.names), default=1)
        get = population.slice
    else:
        width = population._name_width()
        get = population.batch
    mm = {
        name: np.lib.format.open_memmap(
            os.path.join(path, f"{name}.npy"), mode="w+",
            dtype=np.float64, shape=(v,))
        for name in SWEEP_PARAMS}
    names_mm = np.lib.format.open_memmap(
        os.path.join(path, "names.npy"), mode="w+",
        dtype=f"<U{max(width, 1)}", shape=(v,))
    for lo in range(0, v, shard_size):
        hi = min(lo + shard_size, v)
        b = get(lo, hi)
        for name in SWEEP_PARAMS:
            mm[name][lo:hi] = getattr(b, name)
        names_mm[lo:hi] = b.names
    for arr in list(mm.values()) + [names_mm]:
        arr.flush()
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"version": 1, "num_variants": v,
                   "params": list(SWEEP_PARAMS)}, f)
    return path


def load_population(path: str) -> PopulationStream:
    """Memory-mapped ``PopulationStream`` over a ``save_population`` dir."""
    return PopulationStream._from_dir(path)


def _resolve_beta(profiles: ProfileBatch, beta, beta_machine,
                  include_named: Sequence[MachineModel],
                  space: ParamSpace, backend) -> np.ndarray:
    """Per-app target vector under the shared run_sweep/shard_sweep
    convention: explicit beta wins; otherwise derive against
    ``beta_machine``, the first named model, or the space's nominal chip --
    never an arbitrary sampled design, so scores stay comparable across
    seeds and shard counts."""
    if beta is None:
        ref = beta_machine or (include_named[0] if include_named
                               else space.nominal)
        return default_beta_batched(
            profiles, MachineBatch.from_models([ref]), backend=backend)
    return np.broadcast_to(
        np.asarray(beta, dtype=np.float64), (len(profiles),)).copy()


def run_sweep(
    profiles,
    *,
    space: Optional[ParamSpace] = None,
    n: int = 256,
    mode: str = "random",
    seed: int = 0,
    include_named: Sequence[MachineModel] = (),
    beta=None,
    beta_machine: Optional[MachineModel] = None,
    timing_model: str = "serial",
    clamp: bool = True,
    backend: Optional[str] = None,
    population: Optional[MachineBatch] = None,
) -> SweepResult:
    """One-call sweep: generate a population and score it.

    ``mode="random"`` draws ``n`` Halton samples; ``mode="grid"`` builds a
    full grid with ``ceil(n ** (1/d))`` points per dimension.  Any
    ``include_named`` models (e.g. the paper's baseline/denser/densest) are
    prepended.  When ``beta`` is None the per-app default target is derived
    against ``beta_machine``, defaulting to the first named model or, with
    no named models, the space's nominal chip.  ``backend`` picks the
    kernel backend (``"numpy"``/``"jax"``/``"pallas"``; default resolves
    $REPRO_SWEEP_BACKEND, then numpy).  ``population`` bypasses generation
    entirely with a pre-built ``MachineBatch`` (cache hook for the serving
    front door).

    Example (synthetic single-app suite):

    >>> from repro.core import WorkloadProfile, run_sweep
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> res = run_sweep(apps, n=64, seed=0)
    >>> len(res.machines)
    64
    >>> res.best_fit("app0") in res.variant_names
    True
    >>> front = res.pareto_front()          # 2-D: aggregate vs area
    >>> front == sorted(front, key=lambda i: res.area()[i])
    True
    """
    profiles = _as_profile_batch(profiles)  # pack once; input may be a generator
    space = space or ParamSpace.default()
    # ``population`` bypasses generation with a pre-built batch -- the
    # serving front door's population-cache hook (same space/n/mode/seed
    # produce the same batch, so a cached batch scores byte-identically)
    pop = (population if population is not None
           else _population(space, n, mode, seed, include_named))
    beta = _resolve_beta(profiles, beta, beta_machine, include_named, space,
                         backend)
    return batched_congruence(
        profiles, pop, beta=beta, timing_model=timing_model, clamp=clamp,
        backend=backend)


# --------------------------------------------------------------------------- #
# Sharded mega-sweeps: split the population across a mesh, pre-filter per
# shard, merge fronts on the host
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class ShardedSweepResult:
    """Pareto-complete summary of a sharded sweep.

    A mega-sweep's full ``(A, V)`` tensor never exists in one place -- each
    shard's scores are reduced to per-variant statistics and a Pareto
    candidate set, then discarded.  ``result`` is a full ``SweepResult``
    over the surviving candidates only (their global sweep indices are in
    ``candidate_indices``), which is *front-complete*: every variant on the
    global 2-D or 3-D Pareto front survives pre-filtering, so
    ``pareto_front()`` here names exactly the variants a single-device
    ``run_sweep`` over the same population would name (pinned in
    tests/test_sweep.py).

    Front-completeness only holds for the silicon axes the shards were
    pre-filtered with, so the extraction methods take NO cost-model
    override: they always use the ``cost_model`` the sweep ran with (to
    rank under different weights, re-run ``shard_sweep`` with that
    ``cost_model=``) -- pruned variants cannot be recovered post hoc.
    """

    result: SweepResult              # survivors only, fully scored
    candidate_indices: np.ndarray    # survivors' indices into the full sweep
    num_variants: int                # full population size V
    num_shards: int
    mesh_axis: str                   # shard layout, e.g. "variants=4 mesh"
    best_fit_map: Dict[str, str]     # app -> best variant over ALL V
    cost_model: CostModel            # the model the pre-filter ran with
    streamed: bool = False           # population generated/mapped per shard
    resumed_shards: int = 0          # shards skipped via checkpoint resume

    # ------------------------------ lookups --------------------------- #

    @property
    def apps(self) -> List[str]:
        return self.result.apps

    @property
    def backend(self) -> str:
        return self.result.backend

    def best_fit(self, app: str) -> str:
        """Best-fit variant over the FULL population (merged across shards)."""
        return self.best_fit_map[app]

    # --------------------------- extractions -------------------------- #

    def pareto_front(self) -> List[int]:
        """2-D (area, aggregate) front under the sweep's cost model.
        Indices are into ``result`` (the survivor set) -- use
        ``pareto_names`` for population-stable identifiers."""
        return pareto_front_indices(
            self.cost_model.area(self.result.machines),
            self.result.aggregate_mean())

    def pareto_front_3d(self) -> List[int]:
        """3-D (aggregate, area, power) front under the sweep's cost model."""
        return pareto_front_indices_3d(
            self.result.aggregate_mean(),
            self.cost_model.area(self.result.machines),
            self.cost_model.power(self.result.machines))

    def pareto_names(self) -> List[str]:
        return [self.result.machines.names[i] for i in self.pareto_front()]

    def seed_codesign(self, k: Optional[int] = None) -> MachineBatch:
        """Pareto survivors as a warm-start seed for gradient co-design.

        Delegates to ``SweepResult.seed_codesign`` over the survivor set
        under the cost model the shards were pre-filtered with (the only
        axes front-completeness holds for) -- so a mega-sweep's winners
        feed ``grad_codesign`` / ``constrained_codesign`` exactly like a
        single-device sweep's would.
        """
        return self.result.seed_codesign(k=k, cost_model=self.cost_model)

    def frontier(self, budgets, k: Optional[int] = None, **kwargs):
        """J*(budget) frontier from the mega-sweep's survivors, traced
        under the cost model the shards were pre-filtered with (see
        ``SweepResult.frontier``)."""
        return self.result.frontier(budgets, k=k,
                                    cost_model=self.cost_model, **kwargs)

    # ----------------------------- reports ---------------------------- #

    def markdown(self, top_k: Optional[int] = None) -> str:
        layout = self.mesh_axis + (", streamed" if self.streamed else "")
        header = (f"sharded sweep: {self.num_variants} variants across "
                  f"{self.num_shards} shards ({layout}); "
                  f"{len(self.result.machines)} Pareto candidates kept")
        return header + "\n\n" + self.result.markdown(top_k, self.cost_model)

    def to_json(self, top_k: Optional[int] = None) -> dict:
        out = self.result.to_json(top_k=top_k, cost_model=self.cost_model)
        out.update(
            num_variants=self.num_variants,
            num_candidates=len(self.result.machines),
            num_shards=self.num_shards,
            mesh_axis=self.mesh_axis,
            streamed=self.streamed,
            resumed_shards=self.resumed_shards,
            best_fit={app: self.best_fit_map[app] for app in self.apps},
        )
        return out


def _shard_bounds(v: int, num_shards: int) -> List[Tuple[int, int]]:
    """Contiguous, near-equal ``[lo, hi)`` shard ranges covering ``[0, v)``."""
    base, extra = divmod(v, num_shards)
    bounds, lo = [], 0
    for s in range(num_shards):
        hi = lo + base + (1 if s < extra else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


#: Default shard width when streaming without an explicit ``num_shards`` --
#: bounds the regenerated chunk (and the sharded (A, chunk) score slice) to
#: a few MB regardless of V.
STREAM_SHARD_VARIANTS = 65536


def _sweep_signature(pop_tag: str, v: int, num_shards: int, backend_name: str,
                     timing_model: str, clamp: bool, keep_top: int,
                     cost_model: CostModel, beta_vec: np.ndarray) -> str:
    """Configuration fingerprint stored with every sweep checkpoint.

    ``resume=`` refuses to merge state produced under a different
    population, backend, shard layout or scoring config -- silently mixing
    those would produce plausible-looking wrong fronts.
    """
    h = hashlib.blake2b(digest_size=16)
    for part in (pop_tag, str(v), str(num_shards), backend_name,
                 timing_model, str(bool(clamp)), str(int(keep_top)),
                 repr(cost_model)):
        h.update(part.encode())
        h.update(b"\0")
    h.update(np.asarray(beta_vec, dtype=np.float64).tobytes())
    return h.hexdigest()


def shard_sweep(
    profiles,
    *,
    space: Optional[ParamSpace] = None,
    n: int = 1024,
    mode: str = "random",
    seed: int = 0,
    include_named: Sequence[MachineModel] = (),
    beta=None,
    beta_machine: Optional[MachineModel] = None,
    timing_model: str = "serial",
    clamp: bool = True,
    backend: Optional[str] = None,
    num_shards: Optional[int] = None,
    mesh=None,
    keep_top: int = 16,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    progress=None,
    stream: bool = False,
    population=None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    checkpoint_keep: int = 2,
) -> ShardedSweepResult:
    """Sharded ``run_sweep`` for populations that outgrow one device.

    Same population, beta convention and scoring as ``run_sweep`` (same
    ``space``/``n``/``mode``/``seed`` give bitwise-identical variants), but
    the ``(A, V)`` score tensor is never materialized in one place.  Every
    backend walks the population in ``num_shards`` contiguous chunks;
    backends with a distribution strategy additionally split each chunk's
    variant axis over ``mesh`` (built via ``repro.launch.mesh``; default
    one ``("variants",)`` axis over every local device):

      * **jax backend** -- machine arrays placed with
        ``jax.sharding.NamedSharding``, so the jitted kernels partition
        the chunk and each device holds only its ``(A, chunk/ndev)``
        slice (``JaxBackend.sharded_stats``).
      * **pallas backend** -- ONE fused ``pallas_call`` under
        ``jax.shard_map``: every device runs the fused kernel over its
        slice and reduces on-device (``PallasBackend.sharded_stats``).
      * **numpy / custom backends** -- host-chunked scoring, peak memory
        ``O(A * V / num_shards)``.

    Either way, each shard is reduced *in place* to per-variant suite-mean
    aggregates and per-app minima (gather-free: only O(V) + O(A) statistics
    leave the shard).  The host then pre-filters each shard to its local
    Pareto candidates -- every globally non-dominated point is locally
    non-dominated, so the union of local fronts contains the global front
    -- merges in the per-app argmins and per-shard top-``keep_top``, and
    re-scores only the survivors into the full ``SweepResult`` carried by
    the returned ``ShardedSweepResult``.

    Example (1-device mesh; the front matches ``run_sweep`` exactly):

    >>> from repro.core import WorkloadProfile, run_sweep, shard_sweep
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> sharded = shard_sweep(apps, n=128, num_shards=4)
    >>> single = run_sweep(apps, n=128)
    >>> sharded.pareto_names() == [single.machines.names[i]
    ...                            for i in single.pareto_front()]
    True
    >>> sharded.best_fit("app0") == single.best_fit("app0")
    True

    **Streaming** (``stream=True``, or passing a ``PopulationStream`` /
    ``load_population`` dir as ``population=``): each shard's variants are
    regenerated (or memory-mapped) on demand, so neither the ``(A, V)``
    tensor nor the full ``MachineBatch`` ever exists -- V is bounded by
    disk/patience, not RAM.  Streamed shards are byte-identical to slices
    of the materialized population, so results match exactly.

    **Resume** (``checkpoint_dir=``): after every shard the merged per-app
    minima + Pareto survivors are written atomically through
    ``repro.checkpoint.store``; ``resume=True`` restores the latest
    checkpoint (refusing a config mismatch), skips completed shards and
    returns byte-identical fronts to an uninterrupted run.
    """
    pb = _as_profile_batch(profiles)
    space = space or ParamSpace.default()
    be = K.get_backend(backend)

    # ---- population source: materialized batch or per-shard stream
    src: Optional[PopulationStream] = None
    pop: Optional[MachineBatch] = None
    if population is not None:
        if isinstance(population, PopulationStream):
            src = population
            pop_tag = src.signature()
        else:
            pop = _as_machine_batch(population)
            h = hashlib.blake2b("\0".join(pop.names).encode(),
                                digest_size=16)
            pop_tag = f"batch:{len(pop)}:{h.hexdigest()}"
    elif stream:
        src = PopulationStream(space, n, mode=mode, seed=seed,
                               include_named=list(include_named))
        pop_tag = src.signature()
    else:
        pop = _population(space, n, mode, seed, include_named)
        named = ",".join(m.name for m in include_named)
        pop_tag = f"gen:{mode}:{seed}:{n}:[{named}]:{space!r}"
    v = len(src) if src is not None else len(pop)
    beta_vec = _resolve_beta(pb, beta, beta_machine, include_named, space, be)

    # ---- mesh: only for backends with a distribution strategy (numpy and
    # custom backends stay host-chunked and never touch jax device state)
    distributed = type(be).sharded_stats is not K.Backend.sharded_stats
    if mesh is None and distributed:
        from repro.launch import mesh as MESH

        mesh = MESH.make_variant_mesh()
    mesh_axis = (f"{mesh.axis_names[0]}={mesh.size} mesh"
                 if mesh is not None and distributed else "host-chunked")

    default_shards = mesh.size if mesh is not None else 1
    if src is not None:
        # streaming exists to bound memory: never let one shard regrow to V
        default_shards = max(default_shards,
                             -(-v // STREAM_SHARD_VARIANTS))
    num_shards = max(1, min(num_shards or default_shards, v))
    bounds = _shard_bounds(v, num_shards)
    pad_to = max(hi - lo for lo, hi in bounds)

    def shard_batch(lo: int, hi: int) -> MachineBatch:
        return src.batch(lo, hi) if src is not None else pop.slice(lo, hi)

    # ---- resumable state: merged per-app best fits + survivor indices
    app_min = np.full(len(pb), np.inf)
    app_idx = np.zeros(len(pb), dtype=np.int64)
    survivors: set = set()
    start_shard = 0
    config_sig = None
    if checkpoint_dir is not None:
        from repro.checkpoint import store as ckpt

        config_sig = _sweep_signature(pop_tag, v, num_shards, be.name,
                                      timing_model, clamp, keep_top,
                                      cost_model, beta_vec)
        if resume and ckpt.latest_step(checkpoint_dir) is not None:
            tree_like = {"app_idx": app_idx, "app_min": app_min,
                         "survivors": np.zeros(0, dtype=np.int64)}
            state, extra = ckpt.restore(checkpoint_dir, tree_like)
            if extra.get("config") != config_sig:
                raise ValueError(
                    f"checkpoint in {checkpoint_dir!r} was written by a "
                    "different sweep configuration; refusing to resume "
                    "(pass resume=False or a fresh checkpoint_dir)")
            app_min = np.asarray(state["app_min"], dtype=np.float64)
            app_idx = np.asarray(state["app_idx"], dtype=np.int64)
            survivors = set(int(i) for i in state["survivors"])
            start_shard = int(extra["completed_shards"])
    elif resume:
        raise ValueError("resume=True requires checkpoint_dir=")

    # ---- statistics pass, shard by shard: each shard is reduced IN PLACE
    # to per-variant suite means + per-app minima (gather-free on a mesh:
    # only O(V_shard) + O(A) rows leave the devices), pre-filtered to its
    # local Pareto candidates, then discarded.
    # ``progress(shard_index, num_shards, lo, hi)`` fires after each
    # shard's statistics land (serving streams these as shard-by-shard
    # events; a raising callback aborts the sweep -- the cancellation
    # hook; the just-saved checkpoint makes the abort resumable).
    for s, (lo, hi) in enumerate(bounds):
        if s < start_shard:
            continue
        mb = shard_batch(lo, hi)
        stats = None
        if mesh is not None and distributed:
            stats = be.sharded_stats(pb.arrays(), mb.arrays(), beta_vec,
                                     mesh, timing_model=timing_model,
                                     clamp=clamp, pad_to=pad_to)
        if stats is None:
            out = be.congruence(pb.arrays(), mb.arrays(), beta_vec,
                                timing_model=timing_model, clamp=clamp)
            agg = be.to_numpy(out.aggregate)
            agg_mean_s = agg.mean(axis=0)
            local_idx = np.argmin(agg, axis=1)
            local_min = agg[np.arange(len(pb)), local_idx]
        else:
            agg_mean_s, local_min, local_idx = stats
        # strict < keeps the first-occurrence argmin across shards in
        # index order, matching a single global argmin
        better = local_min < app_min
        app_min = np.where(better, local_min, app_min)
        app_idx = np.where(better, local_idx + lo, app_idx)

        area_s = np.asarray(cost_model.area(mb))
        power_s = np.asarray(cost_model.power(mb))
        survivors.update(
            lo + i for i in pareto_front_indices(area_s, agg_mean_s))
        survivors.update(
            lo + i for i in pareto_front_indices_3d(agg_mean_s, area_s,
                                                    power_s))
        order = np.argsort(agg_mean_s, kind="stable")[:keep_top]
        survivors.update(int(lo + i) for i in order)

        if checkpoint_dir is not None:
            ckpt.save(
                checkpoint_dir, s + 1,
                {"app_idx": app_idx, "app_min": app_min,
                 "survivors": np.array(sorted(survivors), dtype=np.int64)},
                extra={"config": config_sig, "completed_shards": s + 1,
                       "num_shards": num_shards, "num_variants": v})
            ckpt.retain(checkpoint_dir, keep=checkpoint_keep)
        if progress is not None:
            progress(s, num_shards, lo, hi)

    # ---- re-score the survivor union into a full (front-complete) result
    candidate_set = set(survivors)
    candidate_set.update(int(i) for i in app_idx)
    candidates = np.array(sorted(candidate_set), dtype=np.int64)
    cand_batch = (src.take(candidates) if src is not None
                  else pop.take(candidates))
    result = batched_congruence(
        pb, cand_batch, beta=beta_vec, timing_model=timing_model,
        clamp=clamp, backend=be)
    cand_pos = {int(g): j for j, g in enumerate(candidates)}
    return ShardedSweepResult(
        result=result,
        candidate_indices=candidates,
        num_variants=v,
        num_shards=num_shards,
        mesh_axis=mesh_axis,
        best_fit_map={app: cand_batch.names[cand_pos[int(app_idx[i])]]
                      for i, app in enumerate(pb.names)},
        cost_model=cost_model,
        streamed=src is not None,
        resumed_shards=start_shard,
    )
