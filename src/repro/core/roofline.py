"""Three-term roofline analysis over dry-run artifacts (required §Roofline).

    compute term      = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term       = HLO_bytes / (chips x HBM_bw)
    collective term   = collective_bytes / (chips x link_bw)

``cost_analysis`` reports per-device work, so dividing per-device work by the
per-chip rate is identical to global work / (chips x rate).

Also reports MODEL_FLOPS = 6*N*D (dense train) / 6*N_active*D (MoE) /
2*N*D (inference), the usefulness ratio MODEL_FLOPS / HLO_FLOPs, the dominant
term, and the roofline fraction (how close the dominant term pins us to peak).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.costs import WorkloadProfile
from repro.core.machine import MachineModel, Subsystem
from repro.core.timing import subsystem_times


@dataclasses.dataclass
class RooflineReport:
    name: str
    arch: str
    shape: str
    mesh: str
    machine: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    mfu_bound: float             # model-FLOPs utilization at the overlap bound
    roofline_fraction: float     # useful compute time / dominant term
    step_time_overlap_s: float
    step_time_serial_s: float
    bytes_per_device: float
    collective_bytes_per_device: float
    peak_memory_gb: float

    def as_dict(self) -> dict:
        """Strict-JSON-safe dict (inverse: ``from_dict``).

        Zero-rate machines and zero-FLOP cells produce inf/nan terms;
        ``json.dump(..., allow_nan=False)`` rejects those and the default
        ``Infinity``/``NaN`` spellings are not valid JSON anyway.  Non-finite
        floats are encoded as the strings ``"inf"`` / ``"-inf"`` / ``"nan"``,
        which ``from_dict`` turns back into the exact float values.
        """
        out = {}
        for key, value in dataclasses.asdict(self).items():
            if isinstance(value, float) and not math.isfinite(value):
                value = str(value)  # "inf" | "-inf" | "nan"
            out[key] = value
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "RooflineReport":
        """Rebuild a report from ``as_dict`` output (round-trip pinned in
        tests/test_model_zoo.py)."""
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown RooflineReport fields {sorted(unknown)}")
        kw = {}
        for f in dataclasses.fields(cls):
            value = d[f.name]
            if f.type == "float" and isinstance(value, str):
                value = float(value)
            kw[f.name] = value
        return cls(**kw)

    def one_liner(self) -> str:
        return (
            f"{self.name}: compute={self.compute_s:.3e}s memory={self.memory_s:.3e}s "
            f"collective={self.collective_s:.3e}s dominant={self.dominant} "
            f"useful={self.useful_ratio:.2f} frac={self.roofline_fraction:.2f}"
        )


def analyze(profile: WorkloadProfile, machine: MachineModel) -> RooflineReport:
    times = subsystem_times(profile, machine)
    dominant = times.dominant

    # Ideal time = useful model FLOPs at full fleet peak.
    if profile.model_flops > 0 and profile.num_devices > 0:
        ideal_s = profile.model_flops / (profile.num_devices * machine.peak_flops)
    else:
        ideal_s = math.nan

    overlap_s = times.total_overlap
    serial_s = times.total_serial
    useful = profile.useful_flops_ratio
    mfu_bound = ideal_s / overlap_s if overlap_s > 0 and not math.isnan(ideal_s) else math.nan
    frac = (
        ideal_s / times.term(dominant)
        if times.term(dominant) > 0 and not math.isnan(ideal_s)
        else math.nan
    )

    return RooflineReport(
        name=profile.name,
        arch=profile.arch,
        shape=profile.shape,
        mesh=profile.mesh,
        machine=machine.name,
        compute_s=times.compute,
        memory_s=times.memory,
        collective_s=times.interconnect,
        dominant=dominant.value,
        model_flops=profile.model_flops,
        hlo_flops_global=profile.global_flops,
        useful_ratio=useful,
        mfu_bound=mfu_bound,
        roofline_fraction=frac,
        step_time_overlap_s=overlap_s,
        step_time_serial_s=serial_s,
        bytes_per_device=profile.bytes_accessed,
        collective_bytes_per_device=profile.total_collective_bytes,
        peak_memory_gb=profile.peak_memory_bytes / 1e9,
    )


def model_flops_for(
    *,
    params_active: float,
    tokens: int,
    step_kind: str,
) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for training, 2*N*D for inference."""
    mult = 6.0 if step_kind == "train" else 2.0
    return mult * params_active * tokens


def markdown_table(reports: list, *, title: Optional[str] = None) -> str:
    """Render a list of RooflineReports as the EXPERIMENTS.md roofline table."""
    lines = []
    if title:
        lines.append(f"### {title}")
        lines.append("")
    lines.append(
        "| cell | mesh | compute (s) | memory (s) | collective (s) | dominant "
        "| MODEL_FLOPS | useful ratio | roofline frac | peak mem/dev (GB) |"
    )
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in reports:
        lines.append(
            f"| {r.arch}/{r.shape} | {r.mesh} | {r.compute_s:.3e} | {r.memory_s:.3e} "
            f"| {r.collective_s:.3e} | **{r.dominant}** | {r.model_flops:.3e} "
            f"| {r.useful_ratio:.3f} | {r.roofline_fraction:.3f} "
            f"| {r.peak_memory_gb:.2f} |"
        )
    return "\n".join(lines)
