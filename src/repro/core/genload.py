"""Generated-workload stress populations (SPRING-style; ROADMAP item).

The named zoo suites cover a few dozen real cells; the congruence scores
are only trustworthy if they behave sanely *off* those suites.  Following
SPRING (PAPERS.md), the cheapest way to stress the methodology across the
whole workload space is a randomly generated application population:
``AppSpace`` is the workload-side mirror of ``ParamSpace`` -- a bounded
knob space over per-device compute / bandwidth / collective intensities
that samples ``WorkloadProfile``s instead of machine variants, so an
``(A x V)`` cross-product sweep stresses every layer built on the batched
kernels (scoring, fronts, co-design, packing) with arbitrarily many apps.

Sampling is INDEX-ADDRESSED exactly like ``PopulationStream``: both the
Halton mode (elementwise radical inverse) and the counter-based RNG mode
regenerate any index subset byte-identically to slicing the full draw, so
streamed shards equal the materialized population (pinned in
tests/test_genload.py).

Generated suites travel as strings through the ONE suite grammar
(``repro.core.model_zoo.validate_suite_name`` / ``resolve_suite``):

    gen:<count>[:seed=<int>][:mode=halton|rng]

which makes them accepted everywhere zoo suites are -- ``run_sweep``,
``shard_sweep``, every co-design mode, ``CodesignSpec.suite``, the
serving front door and the CLIs (``scripts/sweep.py --gen``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.costs import WorkloadProfile
from repro.core.sweep import Dim, ProfileBatch, halton_at

#: The workload knobs an ``AppSpace`` may vary, in canonical order.
#: Each knob is a scalar per generated app; ``_profile_of_row`` maps a
#: knob row onto ``WorkloadProfile`` fields.
APP_PARAMS = (
    "flops",                 # per-device HLO FLOPs per step
    "intensity",             # arithmetic intensity (FLOPs/byte) -> hbm_bytes
    "collective_fraction",   # collective traffic as a fraction of HBM bytes
    "pod_fraction",          # share of collective bytes crossing the pod axis
    "allreduce_mix",         # all-reduce vs all-to-all split of the traffic
    "log2_devices",          # mesh size as a power of two
    "useful_ratio",          # model_flops / global HLO FLOPs (remat waste)
)

#: Index-addressed sampling modes (both regenerate any index subset).
GEN_MODES = ("halton", "rng")


@dataclasses.dataclass
class AppSpace:
    """Bounded synthetic-workload space over the ``APP_PARAMS`` knobs.

    The workload-side mirror of ``ParamSpace``: ``dims`` maps knob names
    to ``Dim`` ranges and populations are drawn by seeded low-discrepancy
    (Halton) or counter-based RNG sampling, index-addressed either way.

    >>> from repro.core.genload import AppSpace
    >>> space = AppSpace.default()
    >>> pop = space.sample(6, seed=0)
    >>> len(pop), pop.names[0]
    (6, 'gen-00000')
    >>> shard = space.sample_at(range(2, 5), seed=0)
    >>> shard.names == pop.names[2:5]
    True
    >>> bool((shard.flops == pop.flops[2:5]).all())
    True
    """

    dims: Dict[str, Dim]

    def __post_init__(self) -> None:
        for name in self.dims:
            if name not in APP_PARAMS:
                raise KeyError(
                    f"unknown workload knob {name!r}; have {APP_PARAMS}")
        missing = [n for n in APP_PARAMS if n not in self.dims]
        if missing:
            raise KeyError(f"AppSpace is missing knobs {missing}")

    @staticmethod
    def default() -> "AppSpace":
        """Training-shaped stress ranges: three decades of per-device
        FLOPs, intensities from bandwidth-bound to MXU-bound, collective
        shares from negligible to dominant, meshes of 8..4096 chips."""
        return AppSpace(dims={
            "flops": Dim(1e12, 2e15),
            "intensity": Dim(8.0, 2048.0),
            "collective_fraction": Dim(1e-3, 0.5),
            "pod_fraction": Dim(0.0, 0.5, log=False),
            "allreduce_mix": Dim(0.0, 1.0, log=False),
            "log2_devices": Dim(3, 12, log=False, integer=True),
            "useful_ratio": Dim(0.3, 0.95, log=False),
        })

    # ------------------------------------------------------------------ #

    def _unit_at(self, idx: np.ndarray, seed: int, mode: str) -> np.ndarray:
        """``(len(idx), D)`` uniform [0, 1) draws, elementwise in the index.

        Halton rows come from the shared ``halton_at`` (the same rotation
        ``ParamSpace`` uses); RNG rows key a fresh counter-based generator
        on ``(seed, index)`` so row ``i`` never depends on how many other
        rows were drawn -- the property that makes streamed sampling equal
        materialized sampling in BOTH modes.
        """
        d = len(APP_PARAMS)
        if mode == "halton":
            return halton_at(idx, d, seed=seed)
        if mode == "rng":
            out = np.empty((idx.shape[0], d), dtype=np.float64)
            for r, i in enumerate(idx):
                out[r] = np.random.default_rng([seed, int(i)]).random(d)
            return out
        raise ValueError(f"unknown generation mode {mode!r}; have {GEN_MODES}")

    def _profile_of_row(self, index: int, row: Dict[str, float]
                        ) -> WorkloadProfile:
        """One knob row -> a consistent ``WorkloadProfile``.

        Derived rather than independent fields keep every sample
        physically coherent: bytes follow from FLOPs and intensity,
        collective traffic is a fraction of those bytes, and the analytic
        model FLOPs stay below the HLO count (``useful_ratio < 1``).
        """
        flops = row["flops"]
        hbm = flops / row["intensity"]
        coll = row["collective_fraction"] * hbm
        mix = row["allreduce_mix"]
        nd = int(2 ** int(row["log2_devices"]))
        return WorkloadProfile(
            name=f"gen-{index:05d}",
            arch="genload",
            step_kind="train",
            num_devices=nd,
            flops=flops,
            bytes_accessed=hbm,
            hbm_bytes=hbm,
            collective_bytes={"all-reduce": mix * coll,
                              "all-to-all": (1.0 - mix) * coll},
            pod_collective_bytes=row["pod_fraction"] * coll,
            model_flops=row["useful_ratio"] * flops * nd,
        )

    def profiles_at(self, indices, seed: int = 0, mode: str = "halton"
                    ) -> List[WorkloadProfile]:
        """Profiles for the given GLOBAL indices (names carry the index)."""
        idx = np.asarray(list(indices) if not isinstance(indices, np.ndarray)
                         else indices, dtype=np.int64)
        unit = self._unit_at(idx, seed, mode)
        names = list(self.dims)
        cols = {name: self.dims[name].from_unit(unit[:, j])
                for j, name in enumerate(names)}
        return [self._profile_of_row(int(i), {n: float(cols[n][r])
                                              for n in names})
                for r, i in enumerate(idx)]

    def sample_at(self, indices, seed: int = 0, mode: str = "halton"
                  ) -> ProfileBatch:
        """Rows ``indices`` of ``sample(n, seed, mode)`` -- byte-identical
        to slicing the full draw (the streamed == materialized property)."""
        return ProfileBatch.from_profiles(
            self.profiles_at(indices, seed=seed, mode=mode))

    def sample(self, n: int, seed: int = 0, mode: str = "halton"
               ) -> ProfileBatch:
        """``n`` generated apps as a ``ProfileBatch``."""
        return self.sample_at(np.arange(n), seed=seed, mode=mode)


# --------------------------------------------------------------------------- #
# Generated-suite strings (the gen:* arm of the ONE suite grammar)
# --------------------------------------------------------------------------- #

GEN_SUITE_PREFIX = "gen"


def is_gen_suite(suite) -> bool:
    """Cheap dispatch test: does this suite string name a generated suite?"""
    return (isinstance(suite, str)
            and suite.partition(":")[0] == GEN_SUITE_PREFIX)


def parse_gen_suite(suite: str) -> Tuple[int, int, str]:
    """``gen:<count>[:seed=<int>][:mode=halton|rng]`` -> (n, seed, mode).

    >>> from repro.core.genload import parse_gen_suite
    >>> parse_gen_suite("gen:64")
    (64, 0, 'halton')
    >>> parse_gen_suite("gen:32:seed=7:mode=rng")
    (32, 7, 'rng')
    >>> parse_gen_suite("gen")
    Traceback (most recent call last):
        ...
    ValueError: generated suite 'gen' needs a count: gen:<count>[:seed=<int>][:mode=halton|rng]
    """
    grammar = "gen:<count>[:seed=<int>][:mode=halton|rng]"
    if not isinstance(suite, str):
        raise ValueError(f"suite must be a string, got {type(suite).__name__}")
    parts = suite.split(":")
    if parts[0] != GEN_SUITE_PREFIX:
        raise ValueError(f"not a generated suite {suite!r}; expected {grammar}")
    if len(parts) < 2:
        raise ValueError(f"generated suite {suite!r} needs a count: {grammar}")
    try:
        n = int(parts[1])
    except ValueError:
        raise ValueError(f"bad count {parts[1]!r} in generated suite "
                         f"{suite!r}; expected {grammar}") from None
    if n <= 0:
        raise ValueError(f"generated suite count must be positive, got {n}")
    seed, mode = 0, "halton"
    for part in parts[2:]:
        key, sep, value = part.partition("=")
        if not sep or key not in ("seed", "mode"):
            raise ValueError(f"bad option {part!r} in generated suite "
                             f"{suite!r}; expected {grammar}")
        if key == "seed":
            try:
                seed = int(value)
            except ValueError:
                raise ValueError(f"bad seed {value!r} in generated suite "
                                 f"{suite!r}; expected an integer") from None
        else:
            if value not in GEN_MODES:
                raise ValueError(f"unknown generation mode {value!r} in "
                                 f"suite {suite!r}; have {GEN_MODES}")
            mode = value
    return n, seed, mode


def resolve_gen_suite(suite: str) -> List[WorkloadProfile]:
    """Generated-suite string -> profile list (default ``AppSpace``).

    Regeneration is deterministic in the string alone -- the same suite
    name always yields the same profiles, so generated suites memoize and
    micro-batch through the serving front door exactly like zoo suites.
    """
    n, seed, mode = parse_gen_suite(suite)
    return AppSpace.default().profiles_at(np.arange(n), seed=seed, mode=mode)
