"""Feasibility frontier J*(budget): warm-started budget continuation.

``constrained_codesign`` answers "what is the best machine under THIS
budget?"; early design exploration asks the inverse question -- "how much
fabric do I actually need?" -- which is the feasibility frontier

    J*(b) = min { J(m) : CostModel.area(m) <= b, m in the span box }

traced over a whole schedule of area budgets.  Running one cold
constrained descent per budget answers it at n times the price; this
module traces the entire frontier for little more than ONE constrained
run by warm-started continuation:

  * budgets are visited loosest -> tightest;
  * the first (loosest) budget gets a full descent from the seeds;
  * each tighter budget starts from the previous optimum, RE-PROJECTED
    onto the smaller feasible set (the projection is the first thing the
    shared descent loop applies), and only a short refinement descent
    runs -- the optimum under budget ``b`` is almost always a short
    projected step from the optimum under the next-looser budget;
  * the active budget enters the jitted retraction as a TRACED scalar
    (``backtracking_descent``'s ``retract_args``), so the whole sweep
    shares one compiled objective/gradient/projection -- continuation
    pays n small descents and ONE compile, where n cold runs would pay n
    full descents.

Monotonicity is enforced BY CONSTRUCTION, not hoped for: the feasible
sets are nested (``b <= b'`` implies ``S(b) ⊆ S(b')``), so any machine
found under a tighter budget is also feasible under every looser one --
after the trace, solutions are propagated tightest -> loosest and a
looser budget adopts a tighter budget's machine whenever it scored
better.  The returned ``J*`` is therefore non-increasing in the budget
across every FEASIBLE point, exactly like the true frontier.
(Unattainable budgets -- below the span box's area floor -- are flagged
``feasible=False`` and record the floor point as a best effort; a floor
point violates its budget, so its J sits outside the frontier and is
excluded from the monotonicity contract, pinned in
tests/test_frontier.py.)

The continuation-vs-cold-start price is measured by
``python benchmarks/run.py frontier`` (artifact:
benchmarks/out/frontier_codesign.md); ``docs/frontier.md`` is the worked
guide and ``SweepResult.frontier`` bridges population sweeps into
frontier traces via the ``seed_codesign`` warm starts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import kernels_xp as K
from repro.core.codesign import (
    _as_batches,
    _objective_terms,
    backtracking_descent,
    machine_arrays_from_theta,
    params_of_theta,
    resolve_beta,
    theta_box,
)
from repro.core.codesign import OPT_FIELDS
from repro.core.constrained import (
    FEASIBLE_RTOL,
    budget_feasible,
    project_to_budgets,
    validate_area_envelope,
)
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.machine import MachineModel


def _validate_budget_schedule(budgets) -> List[float]:
    """Ascending, deduplicated, all-positive budget schedule as floats."""
    try:
        out = sorted({float(b) for b in budgets})
    except TypeError as exc:
        raise ValueError(
            f"budgets must be an iterable of numbers, got {budgets!r}"
        ) from exc
    if not out:
        raise ValueError("frontier_codesign needs at least one budget")
    for b in out:
        if not b > 0.0:
            raise ValueError(f"budgets must be positive, got {b!r}")
    return out


@dataclasses.dataclass
class FrontierResult:
    """One traced feasibility frontier (all arrays indexed by budget,
    ascending -- so ``objective`` is non-increasing left to right over
    the ``feasible`` points; infeasible rows are best-effort floor
    points).

    ``per_seed_objective`` keeps the RAW per-(budget, seed) descent
    outcomes before the monotone propagation, for diagnostics; the
    ``objective``/``best_*`` fields are the frontier proper.

    >>> import numpy as np
    >>> r = FrontierResult(
    ...     budgets=np.array([0.5, 1.0, 2.0]),
    ...     objective=np.array([3.0, 1.2, 1.0]),
    ...     best_names=["a", "a", "b"],
    ...     best_params=[{"peak_flops": 1e14, "hbm_bw": 1e11, "ici_bw": 1e10,
    ...                   "ici_links": 4.0, "inter_pod_bw": 1e10,
    ...                   "scale_compute": 1.0, "scale_memory": 1.0,
    ...                   "scale_interconnect": 1.0}] * 3,
    ...     area=np.array([0.5, 1.0, 1.6]), power=np.array([0.6, 1.1, 1.7]),
    ...     feasible=np.array([True, True, True]),
    ...     per_seed_objective=np.array([[3.0], [1.2], [1.0]]),
    ...     seed_names=["a"], steps=4, refine_steps=2, warm_start=True)
    >>> len(r)
    3
    >>> float(r.knee())               # diminishing returns set in at 1.0
    1.0
    >>> r.best_at(1.5).name           # largest traced budget <= 1.5
    'a+frontier@1'
    >>> bool(np.all(np.diff(r.objective) <= 0))
    True
    """

    budgets: np.ndarray              # (N,) ascending area budgets
    objective: np.ndarray            # (N,) J*(budget); non-increasing
                                     # across the feasible points
    best_names: List[str]            # (N,) winning seed name per budget
    best_params: List[Dict[str, float]]  # (N,) full machine params
    area: np.ndarray                 # (N,) CostModel.area of the winner
    power: np.ndarray                # (N,) CostModel.power of the winner
    feasible: np.ndarray             # (N,) bool (False: budget unattainable)
    per_seed_objective: np.ndarray   # (N, V) raw continuation outcomes
    seed_names: List[str]
    steps: int
    refine_steps: int
    warm_start: bool
    power_budget: Optional[float] = None
    area_envelope: Optional[Dict[str, float]] = None
    suffix: str = "+frontier"
    # Continuation state (``keep_state=True``): raw per-budget theta plus
    # the final backtracking learning rate, so a later trace can warm-start
    # from the nearest already-solved budget (the serving front door's
    # frontier cache).
    continuation: Optional[Dict[float, np.ndarray]] = None
    final_lr: Optional[np.ndarray] = None    # (V,) per-variant backtracking lr
    # Implicit sensitivities (PR 10), attached by ``frontier_codesign``
    # unless ``sensitivities=False``: per-budget ``dJ*/d(area budget)``
    # (zero on propagated flat segments -- the area constraint is slack
    # there), the full per-constraint shadow prices, and the constraint
    # column names.  NaN rows are infeasible floor points.
    dJ_dbudget: Optional[np.ndarray] = None          # (N,)
    shadow_prices: Optional[np.ndarray] = None       # (N, C)
    sensitivity_constraints: Optional[Tuple[str, ...]] = None

    def __len__(self) -> int:
        return len(self.budgets)

    def _sensitivity_blob(self, i: int) -> dict:
        """Per-point sensitivity keys for ``to_json`` ({} when absent or
        the row is an infeasible floor point)."""
        if (self.dJ_dbudget is None
                or not np.isfinite(self.dJ_dbudget[i])):
            return {}
        return {
            "dJ_dbudget": float(self.dJ_dbudget[i]),
            "shadow_prices": {
                c: float(self.shadow_prices[i, j])
                for j, c in enumerate(self.sensitivity_constraints)},
        }

    def _rows(self, top_k: Optional[int]) -> List[int]:
        """Budget rows to report: all, or the ``top_k`` best-objective
        points (ascending budget order preserved)."""
        if top_k is None:
            return list(range(len(self)))
        keep = sorted(range(len(self)),
                      key=lambda i: (float(self.objective[i]), i))[:top_k]
        return sorted(keep)

    # --------------------------- extractions -------------------------- #

    def best_model(self, i: int) -> MachineModel:
        """The frontier machine at budget index ``i`` (name carries the
        budget so sweeping several frontiers stays unambiguous)."""
        p = self.best_params[i]
        return MachineModel(
            name=f"{self.best_names[i]}{self.suffix}"
                 f"@{self.budgets[i]:g}",
            peak_flops=p["peak_flops"],
            hbm_bw=p["hbm_bw"],
            ici_bw=p["ici_bw"],
            ici_links=int(round(p["ici_links"])),
            inter_pod_bw=p["inter_pod_bw"],
            scale={"compute": p["scale_compute"],
                   "memory": p["scale_memory"],
                   "interconnect": p["scale_interconnect"]},
        )

    def best_at(self, budget: float) -> MachineModel:
        """Best traced machine affordable within ``budget``: the frontier
        point at the largest traced budget ``<= budget`` (feasible sets
        are nested, so that machine fits under ``budget`` too).  Raises
        when ``budget`` is below every traced point or only unattainable
        points fit."""
        idx = [i for i in range(len(self)) if
               self.budgets[i] <= budget * (1.0 + FEASIBLE_RTOL)
               and bool(self.feasible[i])]
        if not idx:
            raise ValueError(
                f"no feasible frontier point within budget {budget!r}; "
                f"traced budgets: {np.round(self.budgets, 4).tolist()}")
        return self.best_model(idx[-1])

    def knee(self) -> float:
        """The budget where diminishing returns set in: the feasible point
        farthest from the chord joining the tightest and loosest feasible
        frontier points in the normalized (budget, J*) plane -- the classic
        max-distance-to-chord knee.  A flat frontier's knee is its
        tightest feasible budget (spending more buys nothing); fewer than
        three feasible points degenerate the chord, returning the loosest.
        """
        idx = np.nonzero(self.feasible)[0]
        if len(idx) == 0:
            raise ValueError("no feasible frontier points")
        b, j = self.budgets[idx], self.objective[idx]
        if len(idx) < 3:
            return float(b[-1])
        bn = (b - b[0]) / ((b[-1] - b[0]) or 1.0)
        jn = (j - j[-1]) / ((j[0] - j[-1]) or 1.0)
        # Chord runs (0, 1) -> (1, 0); distance is |bn + jn - 1| / sqrt(2).
        dist = np.abs(bn + jn - 1.0)
        return float(b[int(np.argmax(dist))])

    # ----------------------------- reports ---------------------------- #

    def markdown(self, top_k: Optional[int] = None) -> str:
        knee = self.knee() if bool(np.any(self.feasible)) else None
        lines = [
            f"feasibility frontier: {len(self)} area budgets, "
            f"{len(self.seed_names)} seeds, "
            f"{'warm-started continuation' if self.warm_start else 'cold starts'} "
            f"({self.steps} + {self.refine_steps}/budget steps)",
            "",
            "| area budget | J*(budget) | best seed | area | power "
            "| feasible | knee |"
            + (" dJ*/db | shadow price |"
               if self.dJ_dbudget is not None else ""),
            "|---" * (9 if self.dJ_dbudget is not None else 7) + "|",
        ]
        for i in self._rows(top_k):
            row = (
                f"| {self.budgets[i]:.4g} | {self.objective[i]:.4f} "
                f"| {self.best_names[i]} | {self.area[i]:.3f} "
                f"| {self.power[i]:.3f} "
                f"| {'yes' if self.feasible[i] else 'NO'} "
                f"| {'*' if knee is not None and self.budgets[i] == knee else ''} |")
            if self.dJ_dbudget is not None:
                dj = float(self.dJ_dbudget[i])
                row += (f" {dj:.4f} | {-dj:.4f} |"
                        if np.isfinite(dj) else " - | - |")
            lines.append(row)
        if self.dJ_dbudget is not None:
            lines += ["", "shadow price = -dJ*/d(area budget): the "
                          "first-order J* gain per unit of extra area "
                          "budget (0 on flat, slack segments)."]
        if self.area_envelope:
            lines += ["", f"per-subsystem envelopes: {self.area_envelope}"]
        if self.power_budget is not None:
            lines += ["", f"power budget (fixed): {self.power_budget}"]
        return "\n".join(lines)

    def to_json(self, top_k: Optional[int] = None) -> dict:
        out = {
            "budgets": [float(b) for b in self.budgets],
            "objective": [float(j) for j in self.objective],
            "seed_names": list(self.seed_names),
            "steps": self.steps,
            "refine_steps": self.refine_steps,
            "warm_start": self.warm_start,
            "points": [
                {"budget": float(self.budgets[i]),
                 "objective": float(self.objective[i]),
                 "best_seed": self.best_names[i],
                 "area": float(self.area[i]),
                 "power": float(self.power[i]),
                 "feasible": bool(self.feasible[i]),
                 "params": self.best_params[i],
                 **self._sensitivity_blob(i)}
                for i in self._rows(top_k)],
        }
        if self.sensitivity_constraints is not None:
            out["sensitivity_constraints"] = list(
                self.sensitivity_constraints)
        if bool(np.any(self.feasible)):
            out["knee"] = self.knee()
        if self.power_budget is not None:
            out["power_budget"] = self.power_budget
        if self.area_envelope:
            out["area_envelope"] = dict(self.area_envelope)
        return out


_FRONTIER_DEFAULTS = dict(
    budgets=None, power_budget=None, area_envelope=None, steps=100,
    refine_steps=None, lr=0.1, span=16.0, beta=None, timing_model="serial",
    cost_model=DEFAULT_COST_MODEL, w_area=0.1, w_power=0.05,
    warm_start=True, projection="shift",
)


def frontier_codesign(
    profiles,
    machines,
    budgets: Optional[Sequence[float]] = None,
    *,
    power_budget: Optional[float] = None,
    area_envelope: Optional[Mapping[str, float]] = None,
    steps: Optional[int] = None,
    refine_steps: Optional[int] = None,
    lr: Optional[float] = None,
    span: Optional[float] = None,
    beta=None,
    beta_ref: int = 0,
    timing_model: Optional[str] = None,
    eps: float = K.IDEAL_EPS,
    cost_model: Optional[CostModel] = None,
    w_area: Optional[float] = None,
    w_power: Optional[float] = None,
    warm_start: Optional[bool] = None,
    projection: Optional[str] = None,
    warm_theta: Optional[np.ndarray] = None,
    warm_lr=None,                      # scalar or (V,) per-variant lr
    keep_state: bool = False,
    sensitivities: bool = True,
    spec=None,
) -> FrontierResult:
    """Trace J*(budget) over a schedule of area budgets by continuation.

    ``budgets`` is any iterable of positive area budgets (deduplicated and
    traced loosest -> tightest internally; the result is reported in
    ascending budget order).  ``power_budget`` and ``area_envelope`` are
    HELD FIXED across the sweep -- only the scalar area budget moves, so
    the frontier isolates one axis exactly like the paper's
    "how much fabric?" question.  ``steps`` is the full descent at the
    loosest budget; each tighter budget re-projects the previous optimum
    and refines for ``refine_steps`` (default ``max(steps // 5, 1)``).
    ``warm_start=False`` runs every budget cold from the seeds (same code
    path; used by the benchmark to price the continuation).  Descent is
    projected-gradient (``projection`` picks the shift or Euclidean
    retraction); every frontier point is feasible to ``FEASIBLE_RTOL``
    whenever its budget is attainable inside the span box.

    Example (two budgets, the named seeds; J* never worsens with budget):

    >>> import numpy as np
    >>> from repro.core import VARIANTS, WorkloadProfile, frontier_codesign
    >>> from repro.core.sweep import MachineBatch
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> fr = frontier_codesign(apps, MachineBatch.from_models(VARIANTS),
    ...                        budgets=[1.2, 0.6], steps=4, refine_steps=2)
    >>> fr.budgets.tolist()
    [0.6, 1.2]
    >>> bool(np.all(np.diff(fr.objective) <= 1e-12))   # monotone J*
    True
    >>> bool(fr.feasible.all())
    True
    >>> bool((fr.area <= fr.budgets * (1 + 1e-9)).all())
    True

    A ``spec=CodesignSpec(...)`` fills unset parameters, ``budgets``
    included (explicit keyword > spec field > default).  ``warm_theta`` /
    ``warm_lr`` resume the continuation from a previous run's saved state
    (the serving front door's warm-start cache): the loosest budget then
    refines for ``refine_steps`` instead of descending ``steps`` cold.
    ``keep_state=True`` attaches the per-budget raw thetas and final
    backtracking lr to the result (``continuation`` / ``final_lr``) so a
    later, tighter schedule can resume.
    """
    from repro.core.spec import resolve_spec

    r = resolve_spec(spec, _FRONTIER_DEFAULTS, dict(
        budgets=budgets, power_budget=power_budget,
        area_envelope=area_envelope, steps=steps, refine_steps=refine_steps,
        lr=lr, span=span, beta=beta, timing_model=timing_model,
        cost_model=cost_model, w_area=w_area, w_power=w_power,
        warm_start=warm_start, projection=projection))
    budgets, power_budget = r["budgets"], r["power_budget"]
    area_envelope, steps, refine_steps = (r["area_envelope"], r["steps"],
                                          r["refine_steps"])
    lr, span, beta, timing_model = r["lr"], r["span"], r["beta"], \
        r["timing_model"]
    cost_model, w_area, w_power = r["cost_model"], r["w_area"], r["w_power"]
    warm_start, projection = r["warm_start"], r["projection"]

    if budgets is None:
        raise ValueError("frontier_codesign needs a budget schedule "
                         "(budgets=... or spec.budgets)")
    asc = _validate_budget_schedule(budgets)
    area_envelope = validate_area_envelope(area_envelope)
    if power_budget is not None and not power_budget > 0.0:
        raise ValueError(f"power_budget must be positive, got {power_budget!r}")
    if refine_steps is None:
        refine_steps = max(steps // 5, 1)
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    theta0, lo, hi = theta_box(mb, span)

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

        def objective(theta):
            m = machine_arrays_from_theta(jnp, theta, fixed)
            return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                    eps, cost_model, w_area, w_power)

        def retract(theta, budget):
            # ``budget`` is TRACED: one compiled projection serves every
            # budget in the schedule (the continuation's compile economy).
            out, _ = project_to_budgets(
                jnp, theta, lo_j, hi_j, fixed, cost_model, budget,
                power_budget, area_envelope=area_envelope, method=projection)
            return out

        cache: dict = {}
        # A caller-provided warm_theta (e.g. the serving cache's nearest
        # already-solved budget) replaces the cold seeds: the loosest
        # budget then only refines, exactly like an interior budget would.
        resumed = warm_start and warm_theta is not None
        theta = backend.asarray(warm_theta if resumed else theta0)
        lr_v = (warm_lr if resumed and warm_lr is not None else lr)
        raw: Dict[float, np.ndarray] = {}
        raw_obj: Dict[float, np.ndarray] = {}
        for j, b in enumerate(reversed(asc)):          # loosest -> tightest
            warm = warm_start and (j > 0 or resumed)
            n_steps = refine_steps if warm else steps
            start = theta if warm_start else backend.asarray(theta0)
            start_lr = lr_v if warm_start else lr
            theta_b, f_b, _, _, lr_out = backtracking_descent(
                jax, jnp, start, objective, n_steps, start_lr,
                retract=retract, retract_args=(backend.asarray(float(b)),),
                cache=cache)
            if warm_start:
                theta, lr_v = theta_b, lr_out
            raw[b] = backend.to_numpy(theta_b)
            raw_obj[b] = np.asarray(f_b)

    # Monotone propagation, tightest -> loosest: a tighter budget's winner
    # is feasible at every looser budget, so carrying the incumbent up
    # makes J* non-increasing in the budget BY CONSTRUCTION.
    n = len(asc)
    objective_arr = np.empty(n)
    area_arr, power_arr = np.empty(n), np.empty(n)
    feasible_arr = np.zeros(n, dtype=bool)
    best_names: List[str] = [""] * n
    best_params: List[Dict[str, float]] = [{}] * n
    seed_idx = np.zeros(n, dtype=int)
    per_seed = np.stack([raw_obj[b] for b in asc], axis=0)
    carry = None
    for i, b in enumerate(asc):
        th_i, f_i = raw[b], raw_obj[b]
        m_i = machine_arrays_from_theta(np, th_i, fixed_np)
        feas_i = budget_feasible(np, m_i, cost_model, b, power_budget,
                                 area_envelope=area_envelope)
        k = int(np.argmin(np.where(feas_i, f_i, np.inf))
                if bool(feas_i.any()) else np.argmin(f_i))
        cand = {
            "obj": float(f_i[k]),
            "params": params_of_theta(th_i[k], fixed_np, k),
            "name": mb.names[k],
            "seed": k,
            "feasible": bool(feas_i[k]),
            "area": float(np.asarray(cost_model.area(m_i))[k]),
            "power": float(np.asarray(cost_model.power(m_i))[k]),
        }
        if carry is not None and (not cand["feasible"]
                                  or carry["obj"] < cand["obj"]):
            cand = carry
        if cand["feasible"]:
            carry = cand
        objective_arr[i] = cand["obj"]
        best_names[i] = cand["name"]
        best_params[i] = cand["params"]
        seed_idx[i] = cand["seed"]
        feasible_arr[i] = cand["feasible"]
        area_arr[i] = cand["area"]
        power_arr[i] = cand["power"]

    # First-order implicit sensitivities at each frontier point: the
    # budget rows act as "variants" (per-row fixed arrays + per-row area
    # budget), one KKT solve on the converged designs -- see
    # repro.core.implicit.  Propagated rows have a slack area constraint,
    # so their shadow price is 0, matching the flat frontier segment.
    dj_db = prices = constraint_names = None
    if sensitivities and bool(feasible_arr.any()):
        from repro.core.implicit import _first_order_report

        row_fixed = K.MachineArrays(**{
            f: np.array([p[f] for p in best_params], dtype=np.float64)
            for f in K.MachineArrays._fields})
        theta_rows = np.log(np.stack(
            [[p[f] for f in OPT_FIELDS] for p in best_params]))
        rep = _first_order_report(
            pb, best_names, row_fixed, theta_rows, lo[seed_idx],
            hi[seed_idx], area_budget=np.asarray(asc),
            power_budget=power_budget, area_envelope=area_envelope,
            cost_model=cost_model, beta_np=beta_np,
            timing_model=timing_model, eps=eps, w_area=w_area,
            w_power=w_power)
        prices = np.where(feasible_arr[:, None], rep.multipliers, np.nan)
        dj_db = -prices[:, 0]            # area is always column 0 here
        constraint_names = rep.constraint_names

    return FrontierResult(
        budgets=np.asarray(asc),
        objective=objective_arr,
        best_names=best_names,
        best_params=best_params,
        area=area_arr,
        power=power_arr,
        feasible=feasible_arr,
        per_seed_objective=per_seed,
        seed_names=list(mb.names),
        steps=steps,
        refine_steps=refine_steps,
        warm_start=warm_start,
        power_budget=power_budget,
        area_envelope=area_envelope,
        continuation=dict(raw) if keep_state else None,
        final_lr=np.asarray(lr_v) if keep_state else None,
        dJ_dbudget=dj_db,
        shadow_prices=prices,
        sensitivity_constraints=constraint_names,
    )
