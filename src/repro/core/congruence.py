"""Congruence scores -- the paper's Eq. 1 and the three-score report.

    Score_i = 1 - (alpha_i - beta_i) / (gamma_i - beta_i)          (Eq. 1)

  gamma  : unmodified step time (baseline timing result)
  alpha_i: step time with subsystem i idealized (near-zero delay)
  beta_i : user-defined target time

Score -> 1: subsystem i dominates (prime co-design target).
Score -> 0: subsystem i barely affects the critical path.

The aggregate application-architecture congruence score is the L2 magnitude
of the (HRCS, LBCS, ICS) vector (paper §III-C), extensible to n dimensions;
*lower* aggregate = smaller radar area = better overall fit.

The Eq. 1 / roofline arithmetic lives in ``repro.core.kernels_xp`` (one
backend-agnostic copy shared with the batched sweep engine); this module is
the scalar adapter producing full per-cell ``CongruenceReport`` objects,
including the per-component extended decomposition.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.core import kernels_xp as K
from repro.core.costs import COLLECTIVE_KINDS, WorkloadProfile
from repro.core.machine import (
    ALL_SUBSYSTEMS,
    IDEAL_EPS,
    MachineModel,
    Subsystem,
)
from repro.core.timing import (
    TimingBreakdown,
    machine_arrays,
    profile_arrays,
    subsystem_times,
)

# Paper score names keyed by the TPU subsystem they profile (DESIGN.md §2).
SCORE_NAMES = {
    Subsystem.INTERCONNECT: "ICS",
    Subsystem.MEMORY: "HRCS",
    Subsystem.COMPUTE: "LBCS",
}


def congruence_score(alpha: float, gamma: float, beta: float) -> float:
    """Eq. 1, verbatim.  Degenerate when gamma == beta (no headroom)."""
    denom = gamma - beta
    if denom == 0.0:
        return 0.0
    return 1.0 - (alpha - beta) / denom


@dataclasses.dataclass
class CongruenceReport:
    """Full congruence profile of one (application, machine-variant) pair."""

    name: str
    machine: str
    timing_model: str
    gamma: float                      # baseline step time (s)
    beta: float                       # target step time (s)
    alphas: Dict[str, float]          # subsystem -> idealized step time (s)
    scores: Dict[str, float]          # "ICS"/"HRCS"/"LBCS" -> Eq. 1 score
    extended: Dict[str, float]        # per-component decomposition (paper §II-B)
    baseline: TimingBreakdown

    @property
    def ics(self) -> float:
        return self.scores["ICS"]

    @property
    def hrcs(self) -> float:
        return self.scores["HRCS"]

    @property
    def lbcs(self) -> float:
        return self.scores["LBCS"]

    @property
    def aggregate(self) -> float:
        """L2 magnitude of the (HRCS, LBCS, ICS) vector (paper Table I)."""
        return math.sqrt(self.ics ** 2 + self.hrcs ** 2 + self.lbcs ** 2)

    @property
    def dominant(self) -> str:
        return max(self.scores, key=lambda k: self.scores[k])

    def radar_row(self) -> Dict[str, float]:
        return {"ICS": self.ics, "HRCS": self.hrcs, "LBCS": self.lbcs}

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "machine": self.machine,
            "timing_model": self.timing_model,
            "gamma_s": self.gamma,
            "beta_s": self.beta,
            "alphas_s": dict(self.alphas),
            "scores": dict(self.scores),
            "extended": dict(self.extended),
            "aggregate": self.aggregate,
            "dominant": self.dominant,
        }


def default_beta(
    profile: WorkloadProfile,
    machine: MachineModel,
    baseline: Optional[TimingBreakdown] = None,
) -> float:
    """Default user target: the ideal-compute step time.

    The paper's beta is a user-defined target delay (0.2 ns in §III-C --
    optimistic but nonzero).  Our analogue: the time the step would take if it
    ran useful model FLOPs at full MXU peak -- optimistic, nonzero, and
    workload-scaled.  Falls back to a small fraction of gamma when analytic
    model FLOPs are unavailable.

    Callers that already hold the baseline ``TimingBreakdown`` (e.g.
    ``profile_congruence``) pass it via ``baseline`` so the single timing
    pass is shared instead of re-derived here.
    """
    if baseline is None:
        baseline = subsystem_times(profile, machine)
    gamma = baseline.total_serial
    if profile.model_flops > 0 and profile.num_devices > 0:
        t = profile.model_flops / (profile.num_devices * machine.peak_flops)
        # beta must sit below gamma for Eq. 1 to be meaningful.
        return min(t, 0.5 * gamma)
    return 0.05 * gamma


def profile_congruence(
    profile: WorkloadProfile,
    machine: MachineModel,
    *,
    beta: Optional[float] = None,
    timing_model: str = "serial",
    eps: float = IDEAL_EPS,
    clamp: bool = False,
) -> CongruenceReport:
    """Compute ICS / HRCS / LBCS for one workload on one machine variant.

    This performs the paper's loop: one baseline timing (gamma), then one
    re-timing per subsystem with that subsystem idealized (alpha_i) -- all
    through the shared ``kernels_xp.congruence_kernel`` at batch size 1.
    The compiled artifact is never touched; only the machine model changes.
    """
    baseline = subsystem_times(profile, machine)
    if beta is None:
        beta = default_beta(profile, machine, baseline=baseline)

    with np.errstate(divide="ignore", invalid="ignore"):
        out = K.congruence_kernel(
            np, profile_arrays(profile), machine_arrays(machine),
            np.asarray([beta], dtype=np.float64),
            timing_model, eps, clamp)

    gamma = float(out.gamma[0, 0])
    alphas = {
        Subsystem.COMPUTE.value: float(out.alpha_compute[0, 0]),
        Subsystem.MEMORY.value: float(out.alpha_memory[0, 0]),
        Subsystem.INTERCONNECT.value: float(out.alpha_interconnect[0, 0]),
    }
    scores = {
        "LBCS": float(out.lbcs[0, 0]),
        "HRCS": float(out.hrcs[0, 0]),
        "ICS": float(out.ics[0, 0]),
    }

    extended = extended_decomposition(profile, machine, gamma=gamma, beta=beta,
                                      timing_model=timing_model, eps=eps,
                                      clamp=clamp, times=baseline)

    return CongruenceReport(
        name=profile.name,
        machine=machine.name,
        timing_model=timing_model,
        gamma=gamma,
        beta=beta,
        alphas=alphas,
        scores=scores,
        extended=extended,
        baseline=baseline,
    )


def extended_decomposition(
    profile: WorkloadProfile,
    machine: MachineModel,
    *,
    gamma: float,
    beta: float,
    timing_model: str,
    eps: float = IDEAL_EPS,
    clamp: bool = False,
    times: Optional[TimingBreakdown] = None,
) -> Dict[str, float]:
    """Per-component congruence (paper §II-B: 'the methodology can be extended
    to separately evaluate each component type').

    ICS decomposes per collective kind; LBCS into MXU (dot) vs VPU
    (everything else).  Each sub-score idealizes only that component's share
    of its subsystem's time, via linearity of the timing model.  ``clamp``
    applies the same [0, 1] clip as the top-level scores, so a clamped
    report is clamped throughout.  Callers already holding the baseline
    ``TimingBreakdown`` pass it via ``times`` to skip the re-timing.
    """
    out: Dict[str, float] = {}
    if times is None:
        times = subsystem_times(profile, machine)

    def score(alpha: float) -> float:
        s = congruence_score(alpha, gamma, beta)
        return min(1.0, max(0.0, s)) if clamp else s

    # --- ICS per collective kind ------------------------------------- #
    total_coll = profile.total_collective_bytes
    if total_coll > 0 and times.interconnect > 0:
        for kind in COLLECTIVE_KINDS:
            frac = profile.collective_bytes.get(kind, 0.0) / total_coll
            removed = times.interconnect * frac * (1.0 - eps)
            alpha = _retime_minus(times, timing_model, Subsystem.INTERCONNECT, removed)
            out[f"ICS[{kind}]"] = score(alpha)

    # --- LBCS: MXU vs VPU --------------------------------------------- #
    if profile.flops > 0 and times.compute > 0:
        mxu_frac = min(1.0, profile.dot_flops / profile.flops) if profile.dot_flops else 0.0
        for label, frac in (("mxu", mxu_frac), ("vpu", 1.0 - mxu_frac)):
            removed = times.compute * frac * (1.0 - eps)
            alpha = _retime_minus(times, timing_model, Subsystem.COMPUTE, removed)
            out[f"LBCS[{label}]"] = score(alpha)

    return out


def _retime_minus(
    times: TimingBreakdown, timing_model: str, subsystem: Subsystem, removed: float
) -> float:
    """Step time after shaving ``removed`` seconds off one subsystem term."""
    terms = {
        Subsystem.COMPUTE: times.compute,
        Subsystem.MEMORY: times.memory,
        Subsystem.INTERCONNECT: times.interconnect,
    }
    terms[subsystem] = max(0.0, terms[subsystem] - removed)
    return float(K.combine(
        np, terms[Subsystem.COMPUTE], terms[Subsystem.MEMORY],
        terms[Subsystem.INTERCONNECT], timing_model))
