"""Pallas-fused congruence backend -- the third registered kernel backend.

The numpy and jax backends in ``repro.core.kernels_xp`` evaluate the
congruence pipeline as a chain of whole-array ops: every intermediate
(three raw roofline terms, three scaled terms, gamma, three idealized
alphas) is its own ``(A, V)`` array, materialized in host RAM or HBM
between steps.  At mega-sweep scale (V in the millions) that traffic, not
the arithmetic, is the cost.

This backend collapses the whole ``raw_times -> combine -> eq1 ->
congruence`` chain into ONE ``pl.pallas_call``: the grid tiles the variant
axis, each program pulls a ``(_M_ROWS, TILE_V)`` machine tile and the full
``(_P_ROWS, A)`` profile stack into VMEM, computes every intermediate
in-register/VMEM, and writes only the ``(_OUT_ROWS, A, TILE_V)`` result
tile back out -- no intermediate ever touches HBM.

Crucially the kernel BODY is not a new copy of the math: it calls the very
same ``congruence_kernel`` / ``step_time_kernel`` / ``default_beta_kernel``
functions from ``kernels_xp`` with ``xp = jax.numpy``, so the repo-wide
"one copy of the Eq. 1 math" invariant survives.  Pallas contributes the
fusion and tiling, not a re-derivation.

Precision: TPUs have no f64, so this backend computes in float32.  The
equivalence tests pin ``pallas == numpy`` to ~1e-3 (f32 epsilon amplified
by the Eq. 1 cancellation ``(alpha - beta) / (gamma - beta)``) instead of
the ~1e-12 the x64 jax backend achieves.

Interpreter fallback: on any non-TPU platform (CPU CI included) the kernel
runs under ``pallas_call(interpret=True)`` -- slower, but the same tiling
and the same f32 math, so CI pins the exact code path that ships to TPU.
Override with ``REPRO_PALLAS_INTERPRET=1`` / ``=0``.

Importing this module registers the backend; ``kernels_xp.get_backend``
also lazily imports it on first ``backend="pallas"`` request, so callers
never need to import it explicitly.
"""

from __future__ import annotations

import functools
import os
from typing import Callable, Dict

import numpy as np

from repro.core.kernels_xp import (
    Backend,
    CongruenceArrays,
    MachineArrays,
    ProfileArrays,
    congruence_kernel,
    default_beta_kernel,
    register_backend,
    step_time_kernel,
)
from repro.core.machine import IDEAL_EPS

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

#: Variant-axis tile: one fused program scores (A, TILE_V) cells entirely
#: in VMEM.  512 = 4 f32 sublane groups x 128 lanes; at 10 apps the full
#: working set (7+8 input rows, 8 output rows x A) stays well under the
#: ~16 MB VMEM budget.
TILE_V = 512

_P_ROWS = 7   # the 6 ProfileArrays fields + the (A,) beta target, stacked
_M_ROWS = 8   # the 8 MachineArrays fields, stacked
_OUT_ROWS = 8  # gamma, 3 alphas, LBCS/HRCS/ICS, aggregate

_LANES = 128  # f32 lane width; the variant axis is padded to a multiple


def _round_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def _profile_rows(p_ref) -> ProfileArrays:
    return ProfileArrays(*(p_ref[i] for i in range(6)))


def _machine_rows(m_ref) -> MachineArrays:
    return MachineArrays(*(m_ref[i] for i in range(_M_ROWS)))


# --------------------------------------------------------------------------- #
# Kernel bodies -- thin Ref plumbing around the shared kernels_xp math
# --------------------------------------------------------------------------- #


def _congruence_body(jnp, timing_model, eps, clamp, p_ref, m_ref, out_ref):
    """Fused pass over one (A, TILE_V) tile: every intermediate stays in VMEM."""
    out = congruence_kernel(jnp, _profile_rows(p_ref), _machine_rows(m_ref),
                            p_ref[6], timing_model, eps, clamp)
    out_ref[0] = out.gamma
    out_ref[1] = out.alpha_compute
    out_ref[2] = out.alpha_memory
    out_ref[3] = out.alpha_interconnect
    out_ref[4] = out.lbcs
    out_ref[5] = out.hrcs
    out_ref[6] = out.ics
    out_ref[7] = out.aggregate


def _step_time_body(jnp, timing_model, p_ref, m_ref, out_ref):
    out_ref[...] = step_time_kernel(
        jnp, _profile_rows(p_ref), _machine_rows(m_ref), timing_model)


def _default_beta_body(jnp, p_ref, m_ref, out_ref):
    out_ref[0] = default_beta_kernel(
        jnp, _profile_rows(p_ref), _machine_rows(m_ref))


# --------------------------------------------------------------------------- #
# The backend
# --------------------------------------------------------------------------- #


class PallasBackend(Backend):
    """Fused f32 Pallas evaluation, tiled over the variant axis.

    ``interpret=None`` (the default) auto-selects: compiled on TPU,
    interpreter mode everywhere else, overridable via
    ``$REPRO_PALLAS_INTERPRET``.  ``tile_v`` is the variant tile per fused
    program (clamped down for small populations; the variant axis is padded
    with benign 1.0 columns to a tile multiple and sliced on the way out).
    """

    name = "pallas"
    differentiable = False

    def __init__(self, interpret: bool = None, tile_v: int = TILE_V):
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        self._jax, self._jnp, self._pl = jax, jnp, pl
        if interpret is None:
            env = os.environ.get(INTERPRET_ENV, "")
            if env:
                interpret = env.lower() not in ("0", "false", "no")
            else:
                interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        self.tile_v = int(tile_v)
        self._jit_cache: Dict[str, Callable] = {}

    # -- conversions ---------------------------------------------------- #

    def asarray(self, a):
        return self._jnp.asarray(a, dtype=self._jnp.float32)

    def to_numpy(self, a) -> np.ndarray:
        return np.asarray(a)

    # -- packing -------------------------------------------------------- #

    def _profile_stack(self, p: ProfileArrays, beta=None) -> np.ndarray:
        """Stack profile fields (and optionally beta) into one f32 matrix."""
        rows = list(p) + ([] if beta is None else [beta])
        return np.stack([np.asarray(r, dtype=np.float32) for r in rows])

    def _machine_stack(self, m: MachineArrays):
        """``(_M_ROWS, V_pad)`` f32 stack, padded to a tile multiple.

        Pad columns are all-1.0 machines: every rate and scale is positive,
        so the padded cells compute garbage-but-finite values that the
        output slice drops -- no NaN/inf ever enters the kernel.
        """
        stack = np.stack([np.asarray(f, dtype=np.float32) for f in m])
        v = stack.shape[1]
        tile = min(self.tile_v, _round_up(max(v, 1), _LANES))
        v_pad = _round_up(max(v, 1), tile)
        if v_pad != v:
            pad = np.ones((_M_ROWS, v_pad - v), dtype=np.float32)
            stack = np.concatenate([stack, pad], axis=1)
        return stack, tile, v

    # -- fused entry points --------------------------------------------- #

    def _jitted(self, key: str, fn: Callable, static) -> Callable:
        if key not in self._jit_cache:
            self._jit_cache[key] = self._jax.jit(fn, static_argnames=static)
        return self._jit_cache[key]

    def _tiled_call(self, body, p_stack, m_stack, tile: int, out_rows: int):
        """One fused ``pallas_call`` over the variant grid.

        Shapes are static under jit, so the grid / specs are rebuilt only
        on retrace.  ``out_rows == 0`` means a 2-D ``(A, V)`` output (step
        time); otherwise the output is an ``(out_rows, A, V)`` stack.
        """
        pl = self._pl
        p_rows, a = p_stack.shape
        m_rows, v_pad = m_stack.shape
        grid = (v_pad // tile,)
        in_specs = [
            pl.BlockSpec((p_rows, a), lambda i: (0, 0)),
            pl.BlockSpec((m_rows, tile), lambda i: (0, i)),
        ]
        if out_rows:
            out_shape = self._jax.ShapeDtypeStruct(
                (out_rows, a, v_pad), self._jnp.float32)
            out_specs = pl.BlockSpec((out_rows, a, tile), lambda i: (0, 0, i))
        else:
            out_shape = self._jax.ShapeDtypeStruct(
                (a, v_pad), self._jnp.float32)
            out_specs = pl.BlockSpec((a, tile), lambda i: (0, i))
        return pl.pallas_call(
            body,
            out_shape=out_shape,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            interpret=self.interpret,
        )(p_stack, m_stack)

    def step_time(self, p, m, timing_model="serial"):
        m_stack, tile, v = self._machine_stack(m)
        fn = self._jitted(
            "step_time",
            lambda p_stack, m_stack, timing_model, tile: self._tiled_call(
                functools.partial(_step_time_body, self._jnp, timing_model),
                p_stack, m_stack, tile, 0),
            ("timing_model", "tile"))
        out = fn(self.asarray(self._profile_stack(p)), self.asarray(m_stack),
                 timing_model=timing_model, tile=tile)
        return self.to_numpy(out)[:, :v]

    def default_beta(self, p, m_ref):
        """Per-app beta via the same shared kernel, one ungridded call.

        The reference is a single variant, so there is nothing to tile --
        the whole (rows x 1) problem is one VMEM-resident program.
        """
        pl = self._pl
        p_stack = self.asarray(self._profile_stack(p))
        m_stack = self.asarray(
            np.stack([np.asarray(f, dtype=np.float32) for f in m_ref]))
        fn = self._jitted(
            "default_beta",
            lambda p_stack, m_stack: pl.pallas_call(
                functools.partial(_default_beta_body, self._jnp),
                out_shape=self._jax.ShapeDtypeStruct(
                    (1, p_stack.shape[1]), self._jnp.float32),
                interpret=self.interpret,
            )(p_stack, m_stack),
            ())
        return self.to_numpy(fn(p_stack, m_stack))[0]

    def congruence(self, p, m, beta, timing_model="serial",
                   eps=IDEAL_EPS, clamp=False) -> CongruenceArrays:
        m_stack, tile, v = self._machine_stack(m)
        fn = self._jitted(
            "congruence",
            lambda p_stack, m_stack, timing_model, eps, clamp, tile:
                self._tiled_call(
                    functools.partial(_congruence_body, self._jnp,
                                      timing_model, eps, clamp),
                    p_stack, m_stack, tile, _OUT_ROWS),
            ("timing_model", "eps", "clamp", "tile"))
        out = fn(self.asarray(self._profile_stack(p, beta)),
                 self.asarray(m_stack),
                 timing_model=timing_model, eps=eps, clamp=clamp, tile=tile)
        out = self.to_numpy(out)[:, :, :v]
        return CongruenceArrays(
            gamma=out[0],
            beta=np.asarray(beta),
            alpha_compute=out[1],
            alpha_memory=out[2],
            alpha_interconnect=out[3],
            lbcs=out[4],
            hrcs=out[5],
            ics=out[6],
            aggregate=out[7],
        )

    # -- mesh-sharded statistics pass ----------------------------------- #

    def sharded_stats(self, p, m, beta, mesh, timing_model="serial",
                      clamp=False, pad_to=None):
        """ONE fused ``pallas_call`` with the variant axis split over ``mesh``.

        ``jax.shard_map`` hands each device its local slice of the machine
        stack (profiles replicated); the device runs the same gridded fused
        kernel as ``congruence`` over its slice, then reduces ON-DEVICE to
        the per-variant suite means and per-app min/argmin.  Global variant
        indices come from ``lax.axis_index`` -- pad and out-of-chunk
        columns are masked to ``+inf`` before the min, so the merge is
        exact.  Only the ``(V_local,)`` means and ``(A,)`` rows leave the
        device; the ``(A, V_local)`` score tile is never gathered.

        The host-side merge over the per-device ``(ndev, A)`` stacks picks
        the first device attaining the min, and each device's argmin is the
        first in its slice -- device order equals index order, so the
        combined argmin is first-occurrence, matching the numpy reference.
        """
        jax, jnp = self._jax, self._jnp
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax<0.5 keeps it under experimental
            from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        axis = mesh.axis_names[0]
        ndev = int(mesh.size)
        v = int(np.asarray(m.peak_flops).shape[0])
        if v == 0:
            return None

        # Per-device slice width: cover max(v, pad_to) variants, rounded so
        # every device holds the same tile-aligned slice.
        target = max(v, int(pad_to or 0))
        local = -(-target // ndev)
        tile = min(self.tile_v, _round_up(max(local, 1), _LANES))
        local_pad = _round_up(max(local, 1), tile)
        v_pad = local_pad * ndev

        m_stack = np.stack([np.asarray(f, dtype=np.float32) for f in m])
        if v_pad != v:
            pad = np.ones((_M_ROWS, v_pad - v), dtype=np.float32)
            m_stack = np.concatenate([m_stack, pad], axis=1)
        p_stack = self._profile_stack(p, beta)
        a = p_stack.shape[1]

        mesh_key = (axis, tuple(int(d.id) for d in mesh.devices.flat))
        key = (f"sharded/{a}/{v}/{local_pad}/{tile}/{timing_model}/"
               f"{clamp}/{mesh_key}")
        if key not in self._jit_cache:
            body = functools.partial(_congruence_body, self._jnp,
                                     timing_model, IDEAL_EPS, clamp)

            def local_stats(p_s, m_local):
                out = self._pl.pallas_call(
                    body,
                    out_shape=jax.ShapeDtypeStruct(
                        (_OUT_ROWS, a, local_pad), jnp.float32),
                    grid=(local_pad // tile,),
                    in_specs=[
                        self._pl.BlockSpec((_P_ROWS, a), lambda i: (0, 0)),
                        self._pl.BlockSpec((_M_ROWS, tile), lambda i: (0, i)),
                    ],
                    out_specs=self._pl.BlockSpec(
                        (_OUT_ROWS, a, tile), lambda i: (0, 0, i)),
                    interpret=self.interpret,
                )(p_s, m_local)
                agg = out[_OUT_ROWS - 1]
                lo = jax.lax.axis_index(axis) * local_pad
                valid = (lo + jnp.arange(local_pad)) < v
                masked = jnp.where(valid[None, :], agg, jnp.inf)
                return (agg.mean(axis=0),
                        masked.min(axis=1)[None, :],
                        (masked.argmin(axis=1) + lo)[None, :])

            fn = shard_map(
                local_stats,
                mesh=mesh,
                in_specs=(PartitionSpec(), PartitionSpec(None, axis)),
                out_specs=(PartitionSpec(axis), PartitionSpec(axis),
                           PartitionSpec(axis)),
                check_rep=False,
            )
            self._jit_cache[key] = self._jax.jit(fn)

        agg, mins, idxs = self._jit_cache[key](
            self.asarray(p_stack), self.asarray(m_stack))
        agg = np.asarray(agg)[:v].astype(np.float64)
        mins = np.asarray(mins)          # (ndev, A)
        idxs = np.asarray(idxs)          # (ndev, A) global-within-chunk
        dev = np.argmin(mins, axis=0)    # first device attaining the min
        cols = np.arange(mins.shape[1])
        return (agg,
                mins[dev, cols].astype(np.float64),
                idxs[dev, cols].astype(np.int64))


register_backend("pallas", PallasBackend)
