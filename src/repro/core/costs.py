"""Workload cost extraction from compiled XLA artifacts.

This is the analogue of VPR's post-route netlist: we run the expensive step
(``jax.jit(step).lower(...).compile()``) exactly once per
(architecture x shape x mesh) cell and extract a ``WorkloadProfile`` that all
congruence scoring / DSE passes reuse without recompiling -- the paper's
"reuse packing/placement/routing, re-run only timing analysis" discipline.

Sources:
  * ``compiled.cost_analysis()``      -> HLO FLOPs / bytes accessed (per device)
  * ``compiled.memory_analysis()``    -> per-device memory footprint
  * ``compiled.as_text()``            -> post-SPMD HLO; we parse per-kind
                                         collective bytes (not in cost_analysis)
                                         and MXU (dot/conv) FLOPs.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, Iterable, List, Optional, Tuple

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1, "f4e2m1fn": 1,
    "token": 0, "opaque": 0,
}

# One HLO shape like  bf16[128,4096]{1,0:T(8,128)}  or  f32[] or pred[4]
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")
# Instruction definition:  %name = <type(s)> opcode(...)
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_bytes(dtype: str, dims: str) -> int:
    width = _DTYPE_BYTES.get(dtype)
    if width is None:
        return 0
    if not dims:
        return width  # scalar
    n = 1
    for d in dims.split(","):
        d = d.strip()
        if d:
            n *= int(d)
    return n * width


def _first_shapes_bytes(text: str) -> int:
    """Total bytes across every shape literal found in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _split_result_and_rest(defn: str) -> Tuple[str, str]:
    """Split '<type> opcode(operands), attrs' into (result_type_str, rest).

    The result type is either a single shape or a tuple '(shape, shape, ...)'.
    """
    defn = defn.strip()
    if defn.startswith("("):
        depth = 0
        for i, ch in enumerate(defn):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return defn[: i + 1], defn[i + 1:]
        return defn, ""
    m = _SHAPE_RE.match(defn)
    if m:
        return defn[: m.end()], defn[m.end():]
    return "", defn


def _extract_call_operands(rest: str) -> str:
    """Return the text inside the opcode's parentheses."""
    i = rest.find("(")
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                return rest[i + 1: j]
    return rest[i + 1:]


@dataclasses.dataclass
class HloStats:
    """Costs parsed out of post-partitioning HLO text."""

    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVE_KINDS}
    )
    pod_collective_bytes: float = 0.0  # traffic whose replica groups cross pods
    dot_flops: float = 0.0
    dot_count: int = 0
    hbm_bytes: float = 0.0  # TPU-fusion-aware HBM traffic estimate
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


# TPU HBM-traffic model over the CPU-compiled artifact (DESIGN.md §2):
# XLA:CPU leaves convert/broadcast/copy/transpose and elementwise chains
# unfused, so raw "bytes accessed" wildly overstates what the TPU backend
# (which fuses those into neighbours) would stream from HBM.  We count only
# kernel-boundary ops:
#   dot/convolution/fusion  -> operands + result (one kernel: read ins, write out)
#   collectives             -> operand bytes (already in the ICI term, but they
#                              also pass HBM once)
#   dynamic-(update-)slice, gather, scatter -> result (KV-cache style traffic)
#   reduce                  -> operands (reads the big tensor)
#   parameter               -> result (each input buffer read once)
# Everything else (elementwise, convert, broadcast, copy, transpose, bitcast,
# reshape, iota, constant, tuple plumbing) is assumed fused: 0 HBM bytes.
_HBM_OPERAND_OPS = ("dot", "convolution", "fusion")
_HBM_RESULT_OPS = ("dot", "convolution", "fusion", "parameter",
                   "dynamic-update-slice", "dynamic-slice", "gather",
                   "scatter", "all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute", "sort")
_HBM_REDUCE_OPS = ("reduce", "reduce-window")


# replica_groups comes in two prints:
#   explicit:  replica_groups={{0,1},{2,3}}
#   iota:      replica_groups=[4,2]<=[2,4]T(1,0)   (reshape+transpose+regroup)
_RG_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9,{} ]*\})\}")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_STP_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]*)\}")


def _parse_replica_groups(line: str) -> Optional[List[List[int]]]:
    """Return the device groups of a collective instruction, or None."""
    m = _RG_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        reshape = [int(x) for x in m.group(2).split(",")]
        n = 1
        for r in reshape:
            n *= r
        devices = list(range(n))
        if m.group(3):
            perm = [int(x) for x in m.group(3).split(",")]
            # reshape to `reshape`, transpose by perm, flatten
            import itertools

            strides = [0] * len(reshape)
            acc = 1
            for i in range(len(reshape) - 1, -1, -1):
                strides[i] = acc
                acc *= reshape[i]
            out = []
            tdims = [reshape[p] for p in perm]
            for idx in itertools.product(*[range(d) for d in tdims]):
                flat = sum(idx[j] * strides[perm[j]] for j in range(len(perm)))
                out.append(flat)
            devices = out
        group_size = dims[-1] if len(dims) > 1 else dims[0]
        num_groups = n // group_size
        return [
            devices[g * group_size: (g + 1) * group_size] for g in range(num_groups)
        ]
    m = _RG_EXPLICIT_RE.search(line)
    if m:
        groups = []
        for grp in re.findall(r"\{([0-9, ]*)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(ids)
        return groups or None
    m = _STP_RE.search(line)
    if m:  # collective-permute: treat each pair as a group
        groups = []
        for grp in re.findall(r"\{([0-9, ]*)\}", "{" + m.group(1) + "}"):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if len(ids) == 2:
                groups.append(ids)
        return groups or None
    return None


def _crosses_pod(groups: Optional[List[List[int]]], devices_per_pod: int) -> bool:
    if not groups or devices_per_pod <= 0:
        return False
    for g in groups:
        pods = {d // devices_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


def parse_hlo_stats(hlo_text: str, *, devices_per_pod: int = 0) -> HloStats:
    """Parse optimized HLO text for collective traffic and MXU dot FLOPs.

    Per the roofline spec, collective bytes are the summed operand sizes of
    every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute instruction.  Operands in HLO full text may carry
    inline types (``all-reduce(f32[512] %add.5)``); when they do not we
    resolve them through a symbol table of instruction result shapes, then
    fall back to the collective's own result shape.

    ``devices_per_pod`` > 0 additionally attributes bytes whose replica
    groups span pod boundaries to ``pod_collective_bytes`` (charged at the
    slower inter-pod rate by the timing model).
    """
    stats = HloStats()
    symbol_types: Dict[str, str] = {}
    fusion_bodies: set = set()

    comp_header = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")

    # Pass 1: symbol table + computations called by fusion instructions.
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, defn = m.group(1), m.group(2)
        result_type, rest = _split_result_and_rest(defn)
        if result_type:
            symbol_types[name] = result_type
        if re.match(r"\s*fusion\(", rest):
            cm = re.search(r"calls=%?([\w.\-]+)", rest)
            if cm:
                fusion_bodies.add(cm.group(1))

    # Pass 2: collectives, dots and HBM traffic, with computation scoping:
    # ops inside fusion bodies are already accounted at the fusion call site;
    # `parameter` counts only in ENTRY (nested computations re-declare params).
    in_entry = False
    in_fusion_body = False
    for line in hlo_text.splitlines():
        hm = comp_header.match(line)
        if hm and "=" not in line.split("(")[0]:
            in_entry = bool(hm.group(1))
            name = hm.group(2)
            in_fusion_body = (name in fusion_bodies
                              or name.startswith("fused_"))
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        if in_fusion_body:
            continue
        defn = m.group(2)
        result_type, rest = _split_result_and_rest(defn)
        rest_stripped = rest.strip()
        opcode_match = re.match(r"([\w\-]+)", rest_stripped)
        if not opcode_match:
            continue
        opcode = opcode_match.group(1)
        if opcode == "parameter" and not in_entry:
            continue
        stats.op_counts[opcode] = stats.op_counts.get(opcode, 0) + 1

        # ----- collectives --------------------------------------------- #
        kind = None
        for k in COLLECTIVE_KINDS:
            # all-gather-start / all-reduce-done etc. count once at -start;
            # plain forms count directly.
            if opcode == k or opcode == k + "-start":
                kind = k
                break
        if kind is not None:
            operands = _extract_call_operands(rest_stripped)
            nbytes = _first_shapes_bytes(operands)
            if nbytes == 0:
                # Operands printed without inline types: resolve via symbols.
                for ref in re.findall(r"%([\w.\-]+)", operands):
                    nbytes += _first_shapes_bytes(symbol_types.get(ref, ""))
            if nbytes == 0:
                nbytes = _first_shapes_bytes(result_type)
            stats.collective_bytes[kind] += float(nbytes)
            stats.collective_counts[kind] += 1
            stats.hbm_bytes += float(nbytes)  # collective payload passes HBM
            if devices_per_pod and _crosses_pod(
                _parse_replica_groups(rest_stripped), devices_per_pod
            ):
                stats.pod_collective_bytes += float(nbytes)
            continue

        # ----- MXU work (dot / convolution) ----------------------------- #
        if opcode in ("dot", "convolution"):
            flops = _dot_flops(result_type, rest_stripped, symbol_types)
            stats.dot_flops += flops
            stats.dot_count += 1

        # ----- TPU HBM traffic model ------------------------------------ #
        result_bytes = _first_shapes_bytes(result_type)
        operand_bytes = 0
        if opcode in _HBM_OPERAND_OPS or opcode in _HBM_REDUCE_OPS:
            operands = _extract_call_operands(rest_stripped)
            operand_bytes = _first_shapes_bytes(operands)
            if operand_bytes == 0:
                for ref in re.findall(r"%([\w.\-]+)", operands):
                    operand_bytes += _first_shapes_bytes(symbol_types.get(ref, ""))
        if opcode in _HBM_OPERAND_OPS:
            stats.hbm_bytes += operand_bytes + result_bytes
        elif opcode in _HBM_REDUCE_OPS:
            stats.hbm_bytes += operand_bytes
        elif opcode in _HBM_RESULT_OPS or (
                opcode.endswith("-start") and opcode[:-6] in _HBM_RESULT_OPS):
            stats.hbm_bytes += result_bytes

    return stats


def _dot_flops(result_type: str, rest: str, symbol_types: Dict[str, str]) -> float:
    """FLOPs of one dot: 2 * result_elements * contraction_size."""
    rm = _SHAPE_RE.match(result_type.strip())
    if not rm:
        return 0.0
    result_elems = 1
    if rm.group(2):
        for d in rm.group(2).split(","):
            if d.strip():
                result_elems *= int(d)
    operands = _extract_call_operands(rest)
    lhs_m = _SHAPE_RE.search(operands)
    if lhs_m is None:
        # Operand printed as bare %ref: resolve the first operand's type.
        refs = re.findall(r"%([\w.\-]+)", operands)
        if refs:
            lhs_m = _SHAPE_RE.search(symbol_types.get(refs[0], ""))
    if lhs_m is None:
        return 0.0
    lhs_dims = [int(d) for d in lhs_m.group(2).split(",") if d.strip()] if lhs_m.group(2) else []
    contract = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
    k = 1
    if contract and contract.group(1):
        for idx in contract.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    elif lhs_dims:
        k = lhs_dims[-1]
    return 2.0 * result_elems * k


# --------------------------------------------------------------------------- #
# WorkloadProfile
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class WorkloadProfile:
    """Everything the timing/congruence/roofline passes need for one cell.

    FLOPs/bytes are PER DEVICE (XLA compiles the per-device SPMD program, so
    ``cost_analysis`` reports per-device work).  Roofline terms therefore
    divide by per-chip rates; multiply by ``num_devices`` for global totals.
    """

    name: str
    arch: str = ""
    shape: str = ""
    mesh: str = ""
    step_kind: str = "train"      # train | prefill | decode
    num_devices: int = 1
    flops: float = 0.0            # per-device HLO FLOPs
    bytes_accessed: float = 0.0   # per-device HLO bytes
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    pod_collective_bytes: float = 0.0   # share of traffic crossing the pod axis
    dot_flops: float = 0.0
    dot_count: int = 0
    hbm_bytes: float = 0.0              # per-device TPU HBM-traffic estimate
    peak_memory_bytes: float = 0.0      # per-device, from memory_analysis
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    model_flops: float = 0.0            # analytic 6*N*D (train) / 2*N*D (infer), GLOBAL
    tokens: int = 0
    params: float = 0.0                 # total parameter count
    params_active: float = 0.0          # active (MoE-aware) parameter count
    compile_seconds: float = 0.0
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    @property
    def global_flops(self) -> float:
        return self.flops * self.num_devices

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs -- catches remat/redundancy waste."""
        if self.global_flops <= 0:
            return math.nan
        return self.model_flops / self.global_flops

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "WorkloadProfile":
        known = {f.name for f in dataclasses.fields(WorkloadProfile)}
        return WorkloadProfile(**{k: v for k, v in d.items() if k in known})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @staticmethod
    def load(path: str) -> "WorkloadProfile":
        with open(path) as f:
            return WorkloadProfile.from_json(json.load(f))


def _parse_memory_analysis(mem) -> Dict[str, float]:
    """memory_analysis() returns an object or str depending on backend.

    The device footprint estimate is arguments + temps + (outputs - aliased):
    donated inputs alias outputs, and XLA's own peak_memory_in_bytes on the
    CPU backend omits temps, so we take the max of both views.
    """
    out = {"argument": 0.0, "output": 0.0, "temp": 0.0, "peak": 0.0,
           "alias": 0.0}
    if mem is None:
        return out
    for attr, key in (
        ("argument_size_in_bytes", "argument"),
        ("output_size_in_bytes", "output"),
        ("temp_size_in_bytes", "temp"),
        ("alias_size_in_bytes", "alias"),
        ("peak_memory_in_bytes", "peak"),
    ):
        val = getattr(mem, attr, None)
        if val is not None:
            out[key] = float(val)
    footprint = (out["argument"] + out["temp"]
                 + max(0.0, out["output"] - out["alias"]))
    out["peak"] = max(out["peak"], footprint)
    return out


def profile_from_compiled(
    name: str,
    compiled,
    *,
    arch: str = "",
    shape: str = "",
    mesh: str = "",
    step_kind: str = "train",
    num_devices: int = 1,
    model_flops: float = 0.0,
    tokens: int = 0,
    params: float = 0.0,
    params_active: float = 0.0,
    compile_seconds: float = 0.0,
    hlo_text: Optional[str] = None,
    devices_per_pod: int = 0,
    meta: Optional[dict] = None,
) -> WorkloadProfile:
    """Build a WorkloadProfile from a ``jax`` Compiled object."""
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else (cost_list or {})
    if hlo_text is None:
        hlo_text = compiled.as_text()
    stats = parse_hlo_stats(hlo_text, devices_per_pod=devices_per_pod)
    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:  # pragma: no cover - backend-dependent
        pass
    memd = _parse_memory_analysis(mem)

    return WorkloadProfile(
        name=name,
        arch=arch,
        shape=shape,
        mesh=mesh,
        step_kind=step_kind,
        num_devices=num_devices,
        flops=float(cost.get("flops", 0.0) or 0.0),
        bytes_accessed=float(cost.get("bytes accessed", 0.0) or 0.0),
        transcendentals=float(cost.get("transcendentals", 0.0) or 0.0),
        collective_bytes=dict(stats.collective_bytes),
        collective_counts=dict(stats.collective_counts),
        pod_collective_bytes=stats.pod_collective_bytes,
        dot_flops=stats.dot_flops,
        hbm_bytes=stats.hbm_bytes,
        dot_count=stats.dot_count,
        peak_memory_bytes=memd["peak"],
        argument_bytes=memd["argument"],
        output_bytes=memd["output"],
        temp_bytes=memd["temp"],
        model_flops=model_flops,
        tokens=tokens,
        params=params,
        params_active=params_active,
        compile_seconds=compile_seconds,
        meta=dict(meta or {}),
    )
