"""Gradient-based machine co-design: ``jax.grad`` through the shared kernels.

The sweep engine answers "which of these sampled designs fits best?"; this
module answers the continuous version -- "in which direction should the
design move?" -- by differentiating a scalarized multi-objective

    J(m) = mean-over-apps aggregate congruence
           + w_area * CostModel.area(m) + w_power * CostModel.power(m)

with respect to the *log* of the provisioned rates (``peak_flops``,
``hbm_bw``, ``ici_bw``, ``inter_pod_bw``).  Descent is on log-rates, NOT
raw rates: log-parameterization keeps the rates positive and makes one
step a multiplicative change, matching how hardware design points actually
move (2x the MXUs, 1.5x the HBM stacks).  The ``span`` clip bounds the
feasible box in that same log space -- each rate is confined to
``[seed/span, seed*span]``, i.e. ``log(rate)`` to ``log(seed) +- log(span)``
-- so every operator downstream (the backtracking retraction here, the
budget projection in ``repro.core.constrained``) composes in one
coordinate system.

This is only possible because the timing/Eq. 1 math lives in ONE traceable
place (``repro.core.kernels_xp``): the JAX backend evaluates the identical
kernel the NumPy sweep runs, so the gradient descends the surface the sweep
scores.  ``ici_links`` (integer) and the per-subsystem degradation
``scale_*`` factors are held fixed at their seed values here; the
constrained subsystem (``repro.core.constrained``) relaxes ``ici_links``
continuously and rounds with repair.

The objective uses unclamped Eq. 1 scores: clamping to [0, 1] zeroes the
gradient wherever a score saturates, which is exactly where a dominated
subsystem most needs a push.  Descent uses per-variant backtracking (halve
the step on failure, grow it on success), so every accepted update strictly
decreases that variant's objective -- the acceptance property
``tests/test_codesign.py`` pins.

Entry points:
  scalarized_objective -- evaluate J per variant (NumPy in, NumPy out)
  grad_codesign        -- descend J from a MachineBatch seed; returns a
                          ``CodesignResult`` with per-variant trajectories
                          and the optimized ``MachineModel`` designs.

Constrained descent (area/power budgets), joint machine+sharding-variant
descent and the ``ici_links`` integer relaxation live in
``repro.core.constrained`` and reuse this module's descent machinery;
``docs/codesign.md`` is the worked optimization guide.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import kernels_xp as K
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.machine import MachineModel

#: The machine constants the gradient may move, in theta column order.
#: ``repro.core.constrained`` appends a 5th column, ``log(ici_links)``,
#: when the integer relaxation is enabled.
OPT_FIELDS = ("peak_flops", "hbm_bw", "ici_bw", "inter_pod_bw")


def _as_batches(profiles, machines):
    from repro.core.sweep import _as_machine_batch, _as_profile_batch
    return _as_profile_batch(profiles), _as_machine_batch(machines)


def machine_arrays_from_theta(xp, theta, fixed: K.MachineArrays) -> K.MachineArrays:
    """Rebuild ``MachineArrays`` with rates ``exp(theta)``, rest from seed.

    ``theta`` has one column per ``OPT_FIELDS`` entry; a 5th column, when
    present, carries ``log(ici_links)`` (the continuous relaxation used by
    ``repro.core.constrained``), otherwise links stay at the seed value.
    """
    links = (xp.exp(theta[:, 4]) if theta.shape[1] == len(OPT_FIELDS) + 1
             else fixed.ici_links)
    return K.MachineArrays(
        peak_flops=xp.exp(theta[:, 0]),
        hbm_bw=xp.exp(theta[:, 1]),
        ici_bw=xp.exp(theta[:, 2]),
        ici_links=links,
        inter_pod_bw=xp.exp(theta[:, 3]),
        scale_compute=fixed.scale_compute,
        scale_memory=fixed.scale_memory,
        scale_interconnect=fixed.scale_interconnect,
    )


def _objective_terms(xp, p: K.ProfileArrays, m: K.MachineArrays, beta,
                     timing_model: str, eps: float, cost_model: CostModel,
                     w_area: float, w_power: float, app_weights=None):
    """Per-variant (V,) scalarized objective -- the traceable core.

    ``app_weights`` (``(A, V)``, each column summing to 1 -- every workload
    group contributes weight ``1/n_groups`` spread over its members)
    replaces the plain mean over apps; the joint machine+variant descent
    uses it to select (hard) or mix (softmax) sharding variants of the
    same application.
    """
    out = K.congruence_kernel(xp, p, m, beta, timing_model, eps, clamp=False)
    if app_weights is None:
        fit = xp.mean(out.aggregate, axis=0)
    else:
        fit = xp.sum(app_weights * out.aggregate, axis=0)
    return fit + w_area * cost_model.area(m) + w_power * cost_model.power(m)


def theta_box(machines, span: float, optimize_links: bool = False,
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Seed log-rates and the span clip's feasible box, as ``(V, D)`` arrays.

    Returns ``(theta0, lo, hi)`` with one column per ``OPT_FIELDS`` entry
    plus, when ``optimize_links`` is set, a trailing ``log(ici_links)``
    column floored at ``log(1)`` (a pod link count cannot drop below one).
    """
    from repro.core.sweep import _as_machine_batch
    mb = _as_machine_batch(machines)
    cols = [np.asarray(getattr(mb, f), dtype=np.float64) for f in OPT_FIELDS]
    if optimize_links:
        cols.append(np.asarray(mb.ici_links, dtype=np.float64))
    theta0 = np.log(np.stack(cols, axis=1))
    lo, hi = theta0 - np.log(span), theta0 + np.log(span)
    if optimize_links:
        lo[:, -1] = np.maximum(lo[:, -1], 0.0)
        theta0[:, -1] = np.maximum(theta0[:, -1], lo[:, -1])
    return theta0, lo, hi


def backtracking_descent(
    jax, jnp, theta0, obj_fn: Callable, steps: int, lr: float,
    retract: Callable, aux_fn: Optional[Callable] = None,
    obj_args: Tuple = (), retract_args: Tuple = (),
    cache: Optional[Dict[str, Callable]] = None,
) -> Tuple[object, object, List[np.ndarray], List[np.ndarray], object]:
    """Per-variant backtracking line search on ``obj_fn`` (shared by every
    co-design mode).

    ``retract`` maps a raw gradient candidate back onto the feasible set
    (the span-clip box for unconstrained descent, the budget projection of
    ``repro.core.constrained`` for projected-gradient mode); it is applied
    AFTER the gradient step, so accepted iterates are always feasible.
    ``aux_fn(theta) -> (V,)`` optionally records a per-step diagnostic
    (the constraint-violation trace).  ``lr`` may be a scalar or a ``(V,)``
    per-variant array -- multi-round callers (the joint/Lagrangian outer
    loops) pass the previous round's adapted rates back in so restarts do
    not re-pay the warm-up.

    ``obj_args`` are extra TRACED positional arguments forwarded to
    ``obj_fn(theta, *obj_args)``; round-varying state (Lagrange
    multipliers, selection weights, softmax temperature) belongs there,
    not in a fresh closure per round.  ``retract_args`` do the same for
    ``retract(theta, *retract_args)`` -- the budget-continuation frontier
    (``repro.core.frontier``) passes the active budget as a traced scalar
    so ONE compiled projection serves the whole budget sweep.  With a
    ``cache`` dict (reused across calls WITH THE SAME
    ``obj_fn``/``retract``), the jitted obj/grad/retract compile once and
    later rounds retrace only on shape changes.  Returns the final
    ``theta``, final per-variant objective, the accepted-objective history
    (seed included), the aux history and the adapted per-variant ``lr``.
    """
    cache = {} if cache is None else cache
    if "obj" not in cache:
        cache["obj"] = jax.jit(obj_fn)
        cache["grad"] = jax.jit(jax.grad(
            lambda th, *a: jnp.sum(obj_fn(th, *a))))
        cache["retract"] = jax.jit(retract)
        cache["aux"] = jax.jit(aux_fn) if aux_fn is not None else None
    obj_j, grad_j = cache["obj"], cache["grad"]
    retract_j, aux_j = cache["retract"], cache["aux"]

    theta = retract_j(theta0, *retract_args)
    f_cur = obj_j(theta, *obj_args)
    lr_v = jnp.broadcast_to(jnp.asarray(lr, dtype=theta.dtype),
                            (theta.shape[0],))
    history = [np.asarray(f_cur)]
    aux = [] if aux_j is None else [np.asarray(aux_j(theta))]
    for _ in range(steps):
        g = grad_j(theta, *obj_args)
        cand = retract_j(theta - lr_v[:, None] * g, *retract_args)
        f_new = obj_j(cand, *obj_args)
        ok = f_new < f_cur
        theta = jnp.where(ok[:, None], cand, theta)
        f_cur = jnp.where(ok, f_new, f_cur)
        lr_v = jnp.where(ok, lr_v * 1.2, lr_v * 0.5)
        history.append(np.asarray(f_cur))
        if aux_j is not None:
            aux.append(np.asarray(aux_j(theta)))
    return theta, f_cur, history, aux, lr_v


@dataclasses.dataclass
class CodesignResult:
    """Outcome of one gradient co-design run (all arrays per-variant).

    Every mode (unconstrained, projected, Lagrangian, joint) returns this
    one type; the feasibility fields are populated whenever a budget was in
    force and ``feasibility_report()`` renders them.  Doctest (fields are
    plain NumPy; no descent needed to exercise the accessors):

    >>> import numpy as np
    >>> r = CodesignResult(
    ...     names=["a", "b"], objective_seed=np.array([2.0, 3.0]),
    ...     objective_final=np.array([1.0, 2.5]),
    ...     seed_params=[{}, {}], final_params=[{}, {}],
    ...     trajectory=np.array([[2.0, 3.0], [1.0, 2.5]]), steps=1,
    ...     w_area=0.1, w_power=0.05)
    >>> r.best
    0
    >>> r.improvement.tolist()
    [1.0, 0.5]
    """

    names: List[str]
    objective_seed: np.ndarray       # (V,) J at the seed designs
    objective_final: np.ndarray      # (V,) J after descent
    seed_params: List[Dict[str, float]]
    final_params: List[Dict[str, float]]
    trajectory: np.ndarray           # (steps+1, V) accepted J per step
    steps: int
    w_area: float
    w_power: float
    # ---- co-design mode + feasibility report (PR 4) ------------------- #
    mode: str = "unconstrained"      # unconstrained|projected|lagrangian|joint-*
    suffix: str = "+grad"            # appended to optimized variant names
    area_budget: Optional[float] = None
    power_budget: Optional[float] = None
    #: Per-subsystem area envelopes (PR 5): rate field -> budget on
    #: ``CostModel.subsystem_area`` -- one extra constraint per entry.
    area_envelope: Optional[Dict[str, float]] = None
    area_final: Optional[np.ndarray] = None      # (V,) CostModel.area
    power_final: Optional[np.ndarray] = None     # (V,) CostModel.power
    feasible: Optional[np.ndarray] = None        # (V,) bool, None = no budget
    violation_trace: Optional[np.ndarray] = None  # (T, V) relative violation
    selection_names: Optional[List[List[str]]] = None  # joint: (V,)(G,) picks
    #: Augmented-Lagrangian shadow-price estimates (PR 10): ``(V, C)``
    #: multipliers against the ABSOLUTE budgets, one column per
    #: ``constraint_names`` entry (cross-checkable against the implicit
    #: sensitivities in ``repro.core.implicit``).  Lagrangian mode only.
    multipliers: Optional[np.ndarray] = None
    constraint_names: Optional[Tuple[str, ...]] = None

    @property
    def improvement(self) -> np.ndarray:
        """Per-variant objective decrease (positive = better)."""
        return self.objective_seed - self.objective_final

    @property
    def best(self) -> int:
        """Index of the best FEASIBLE variant (best overall if no budget)."""
        if self.feasible is not None and bool(np.any(self.feasible)):
            obj = np.where(self.feasible, self.objective_final, np.inf)
            return int(np.argmin(obj))
        return int(np.argmin(self.objective_final))

    def best_model(self) -> MachineModel:
        return self.models()[self.best]

    def models(self) -> List[MachineModel]:
        out = []
        for name, params in zip(self.names, self.final_params):
            out.append(MachineModel(
                name=f"{name}{self.suffix}",
                peak_flops=params["peak_flops"],
                hbm_bw=params["hbm_bw"],
                ici_bw=params["ici_bw"],
                ici_links=int(round(params["ici_links"])),
                inter_pod_bw=params["inter_pod_bw"],
                scale={"compute": params["scale_compute"],
                       "memory": params["scale_memory"],
                       "interconnect": params["scale_interconnect"]},
            ))
        return out

    def feasibility_report(self) -> dict:
        """Budgets, final (area, power) and per-variant feasibility.

        ``max_violation`` is the worst relative constraint violation seen
        along the descent (0.0 everywhere for projected mode, damped toward
        0 for Lagrangian -- the trace itself is in ``violation_trace``).
        """
        if (self.area_budget is None and self.power_budget is None
                and not self.area_envelope):
            return {"constrained": False, "mode": self.mode}
        rep = {
            "constrained": True,
            "mode": self.mode,
            "area_budget": self.area_budget,
            "power_budget": self.power_budget,
            "all_feasible": bool(np.all(self.feasible)),
            "variants": [
                {"name": f"{n}{self.suffix}",
                 "area": float(self.area_final[i]),
                 "power": float(self.power_final[i]),
                 "feasible": bool(self.feasible[i])}
                for i, n in enumerate(self.names)],
        }
        if self.area_envelope:
            rep["area_envelope"] = dict(self.area_envelope)
        if self.violation_trace is not None and len(self.violation_trace):
            rep["max_violation"] = float(np.max(self.violation_trace))
            rep["final_violation"] = float(np.max(self.violation_trace[-1]))
        if self.multipliers is not None:
            rep["shadow_prices"] = {
                c: [float(x) for x in self.multipliers[:, j]]
                for j, c in enumerate(self.constraint_names)}
        return rep

    def _variant_order(self, top_k: Optional[int]) -> List[int]:
        """Variant indices to report: all, or the ``top_k`` best by final
        objective (feasible variants first, matching ``best``'s tie-break;
        original seed order preserved within the kept set)."""
        if top_k is None:
            return list(range(len(self.names)))
        obj = np.asarray(self.objective_final, dtype=float)
        if self.feasible is not None:
            obj = np.where(np.asarray(self.feasible, bool), obj, np.inf)
        keep = sorted(range(len(self.names)),
                      key=lambda i: (float(obj[i]), i))[:top_k]
        return sorted(keep)

    def to_json(self, top_k: Optional[int] = None) -> dict:
        order = self._variant_order(top_k)
        blob = {
            "steps": self.steps,
            "mode": self.mode,
            "w_area": self.w_area,
            "w_power": self.w_power,
            "best_variant": f"{self.names[self.best]}{self.suffix}",
            "variants": [
                {"name": f"{self.names[i]}{self.suffix}",
                 "objective_seed": float(self.objective_seed[i]),
                 "objective_final": float(self.objective_final[i]),
                 "seed_params": self.seed_params[i],
                 "final_params": self.final_params[i]}
                for i in order],
        }
        if (self.area_budget is not None or self.power_budget is not None
                or self.area_envelope):
            blob["feasibility"] = self.feasibility_report()
        if self.selection_names is not None:
            blob["selection"] = {
                f"{self.names[i]}{self.suffix}": self.selection_names[i]
                for i in order}
        return blob

    def markdown(self, top_k: Optional[int] = None) -> str:
        """GitHub-flavoured summary table (the uniform result protocol:
        every sweep/co-design result renders via ``markdown``/``to_json``
        so the serving front door needs exactly one renderer)."""
        order = self._variant_order(top_k)
        has_budget = self.feasible is not None
        head = "| variant | J seed | J final | improvement |"
        rule = "|---|---|---|---|"
        if has_budget:
            head += " area | power | feasible |"
            rule += "---|---|---|"
        lines = [head, rule]
        for i in order:
            star = " *" if i == self.best else ""
            row = (f"| {self.names[i]}{self.suffix}{star} "
                   f"| {float(self.objective_seed[i]):.4f} "
                   f"| {float(self.objective_final[i]):.4f} "
                   f"| {float(self.improvement[i]):+.4f} |")
            if has_budget:
                row += (f" {float(self.area_final[i]):.3f} "
                        f"| {float(self.power_final[i]):.3f} "
                        f"| {'yes' if bool(self.feasible[i]) else 'NO'} |")
            lines.append(row)
        lines.append("")
        lines.append(f"mode: {self.mode}; steps: {self.steps}; "
                     f"best: {self.names[self.best]}{self.suffix}")
        return "\n".join(lines)


def params_of_theta(theta_row: np.ndarray, fixed_np: K.MachineArrays,
                    i: int) -> Dict[str, float]:
    """One variant's full parameter dict from a log-rate row + seed arrays."""
    d = {f: float(np.exp(theta_row[j])) for j, f in enumerate(OPT_FIELDS)}
    d["ici_links"] = (float(np.exp(theta_row[len(OPT_FIELDS)]))
                      if len(theta_row) == len(OPT_FIELDS) + 1
                      else float(fixed_np.ici_links[i]))
    d["scale_compute"] = float(fixed_np.scale_compute[i])
    d["scale_memory"] = float(fixed_np.scale_memory[i])
    d["scale_interconnect"] = float(fixed_np.scale_interconnect[i])
    return d


def resolve_beta(pb, mb, beta, beta_ref: int) -> np.ndarray:
    """The codesign beta convention: per-app default derived from variant
    ``beta_ref`` (frozen during descent -- the paper's beta is a user
    target, not a design variable), or an explicit scalar/(A,) target."""
    if beta is None:
        return K.get_backend("numpy").default_beta(
            pb.arrays(), mb.select(beta_ref).arrays())
    return np.broadcast_to(
        np.asarray(beta, dtype=np.float64), (len(pb),)).copy()


def scalarized_objective(
    profiles,
    machines,
    *,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = K.IDEAL_EPS,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    w_area: float = 0.1,
    w_power: float = 0.05,
) -> np.ndarray:
    """Evaluate J for every variant (NumPy reference; shape ``(V,)``).

    Uses the same default-beta convention as ``batched_congruence``: when
    ``beta`` is None the per-app target derives from variant ``beta_ref``.
    """
    pb, mb = _as_batches(profiles, machines)
    beta = np.broadcast_to(
        np.asarray(resolve_beta(pb, mb, beta, beta_ref), dtype=np.float64),
        (len(pb),))
    with np.errstate(divide="ignore", invalid="ignore"):
        return _objective_terms(np, pb.arrays(), mb.arrays(), beta,
                                timing_model, eps, cost_model,
                                w_area, w_power)


def grad_codesign(
    profiles,
    machines,
    *,
    steps: int = 100,
    lr: float = 0.1,
    span: float = 16.0,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = K.IDEAL_EPS,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    w_area: float = 0.1,
    w_power: float = 0.05,
) -> CodesignResult:
    """Descend J from a seed population by ``jax.grad`` on log-rates.

    ``machines`` is the seed -- typically the named variants
    (``MachineBatch.from_models(VARIANTS)``); every seed design descends
    independently (the objective sums per-variant terms, so the gradient
    does not couple them).  ``beta`` follows the sweep convention (per-app
    default from variant ``beta_ref``, frozen during descent -- the paper's
    beta is a user target, not a design variable).

    Descent runs on the LOG of each rate; ``span`` clips ``log(rate)`` to
    ``[log(seed) - log(span), log(seed) + log(span)]`` -- i.e. the rate to
    ``[seed/span, seed*span]`` -- keeping designs inside a plausible
    process envelope.  That clip box is exactly the feasible box the
    constrained modes (``repro.core.constrained``) intersect with the
    area/power budget set, and the combined clip+projection operator there
    is order-invariant with this clip (pinned in tests/test_constrained.py).
    ``lr`` is the initial per-variant step on log-rates, adapted by
    backtracking (x1.2 on success, x0.5 on failure), so the accepted
    objective sequence is monotone non-increasing per variant.

    Example (descend the three named seeds for a few steps):

    >>> from repro.core import VARIANTS, WorkloadProfile, grad_codesign
    >>> from repro.core.sweep import MachineBatch
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> cd = grad_codesign(apps, MachineBatch.from_models(VARIANTS), steps=3)
    >>> cd.names
    ['baseline', 'denser', 'densest']
    >>> bool((cd.improvement >= 0).all())     # backtracking never regresses
    True
    >>> cd.best_model().peak_flops > 0
    True
    >>> cd.mode
    'unconstrained'
    """
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    theta0, lo, hi = theta_box(mb, span)

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

        def per_variant(theta):
            m = machine_arrays_from_theta(jnp, theta, fixed)
            return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                    eps, cost_model, w_area, w_power)

        theta, f_cur, history, _, _ = backtracking_descent(
            jax, jnp, backend.asarray(theta0), per_variant, steps, lr,
            retract=lambda th: jnp.clip(th, lo_j, hi_j))
        theta_np = backend.to_numpy(theta)
        f_final = backend.to_numpy(f_cur)

    final_m = machine_arrays_from_theta(np, theta_np, fixed_np)
    return CodesignResult(
        names=list(mb.names),
        objective_seed=np.asarray(history[0]),
        objective_final=np.asarray(f_final),
        seed_params=[params_of_theta(theta0[i], fixed_np, i)
                     for i in range(len(mb))],
        final_params=[params_of_theta(theta_np[i], fixed_np, i)
                      for i in range(len(mb))],
        trajectory=np.stack(history, axis=0),
        steps=steps,
        w_area=w_area,
        w_power=w_power,
        mode="unconstrained",
        area_final=np.asarray(cost_model.area(final_m)),
        power_final=np.asarray(cost_model.power(final_m)),
    )
