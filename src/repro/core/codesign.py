"""Gradient-based machine co-design: ``jax.grad`` through the shared kernels.

The sweep engine answers "which of these sampled designs fits best?"; this
module answers the continuous version -- "in which direction should the
design move?" -- by differentiating a scalarized multi-objective

    J(m) = mean-over-apps aggregate congruence
           + w_area * CostModel.area(m) + w_power * CostModel.power(m)

with respect to the *log* of the provisioned rates (``peak_flops``,
``hbm_bw``, ``ici_bw``, ``inter_pod_bw``).  Log-parameterization keeps the
rates positive and makes one step a multiplicative change, matching how
hardware design points actually move (2x the MXUs, 1.5x the HBM stacks).

This is only possible because the timing/Eq. 1 math lives in ONE traceable
place (``repro.core.kernels_xp``): the JAX backend evaluates the identical
kernel the NumPy sweep runs, so the gradient descends the surface the sweep
scores.  ``ici_links`` (integer) and the per-subsystem degradation
``scale_*`` factors are held fixed at their seed values.

The objective uses unclamped Eq. 1 scores: clamping to [0, 1] zeroes the
gradient wherever a score saturates, which is exactly where a dominated
subsystem most needs a push.  Descent uses per-variant backtracking (halve
the step on failure, grow it on success), so every accepted update strictly
decreases that variant's objective -- the acceptance property
``tests/test_codesign.py`` pins.

Entry points:
  scalarized_objective -- evaluate J per variant (NumPy in, NumPy out)
  grad_codesign        -- descend J from a MachineBatch seed; returns a
                          ``CodesignResult`` with per-variant trajectories
                          and the optimized ``MachineModel`` designs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import kernels_xp as K
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.machine import MachineModel

#: The machine constants the gradient may move, in theta column order.
OPT_FIELDS = ("peak_flops", "hbm_bw", "ici_bw", "inter_pod_bw")


def _as_batches(profiles, machines):
    from repro.core.sweep import _as_machine_batch, _as_profile_batch
    return _as_profile_batch(profiles), _as_machine_batch(machines)


def _machine_arrays_from_theta(xp, theta, fixed: K.MachineArrays) -> K.MachineArrays:
    """Rebuild ``MachineArrays`` with rates ``exp(theta)``, rest from seed."""
    return K.MachineArrays(
        peak_flops=xp.exp(theta[:, 0]),
        hbm_bw=xp.exp(theta[:, 1]),
        ici_bw=xp.exp(theta[:, 2]),
        ici_links=fixed.ici_links,
        inter_pod_bw=xp.exp(theta[:, 3]),
        scale_compute=fixed.scale_compute,
        scale_memory=fixed.scale_memory,
        scale_interconnect=fixed.scale_interconnect,
    )


def _objective_terms(xp, p: K.ProfileArrays, m: K.MachineArrays, beta,
                     timing_model: str, eps: float, cost_model: CostModel,
                     w_area: float, w_power: float):
    """Per-variant (V,) scalarized objective -- the traceable core."""
    out = K.congruence_kernel(xp, p, m, beta, timing_model, eps, clamp=False)
    fit = xp.mean(out.aggregate, axis=0)
    return fit + w_area * cost_model.area(m) + w_power * cost_model.power(m)


def scalarized_objective(
    profiles,
    machines,
    *,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = K.IDEAL_EPS,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    w_area: float = 0.1,
    w_power: float = 0.05,
) -> np.ndarray:
    """Evaluate J for every variant (NumPy reference; shape ``(V,)``).

    Uses the same default-beta convention as ``batched_congruence``: when
    ``beta`` is None the per-app target derives from variant ``beta_ref``.
    """
    pb, mb = _as_batches(profiles, machines)
    be = K.get_backend("numpy")
    if beta is None:
        beta = be.default_beta(pb.arrays(), mb.select(beta_ref).arrays())
    beta = np.broadcast_to(np.asarray(beta, dtype=np.float64), (len(pb),))
    with np.errstate(divide="ignore", invalid="ignore"):
        return _objective_terms(np, pb.arrays(), mb.arrays(), beta,
                                timing_model, eps, cost_model,
                                w_area, w_power)


@dataclasses.dataclass
class CodesignResult:
    """Outcome of one gradient co-design run (all arrays per-variant)."""

    names: List[str]
    objective_seed: np.ndarray       # (V,) J at the seed designs
    objective_final: np.ndarray      # (V,) J after descent
    seed_params: List[Dict[str, float]]
    final_params: List[Dict[str, float]]
    trajectory: np.ndarray           # (steps+1, V) accepted J per step
    steps: int
    w_area: float
    w_power: float

    @property
    def improvement(self) -> np.ndarray:
        """Per-variant objective decrease (positive = better)."""
        return self.objective_seed - self.objective_final

    @property
    def best(self) -> int:
        return int(np.argmin(self.objective_final))

    def best_model(self) -> MachineModel:
        return self.models()[self.best]

    def models(self) -> List[MachineModel]:
        out = []
        for name, params in zip(self.names, self.final_params):
            out.append(MachineModel(
                name=f"{name}+grad",
                peak_flops=params["peak_flops"],
                hbm_bw=params["hbm_bw"],
                ici_bw=params["ici_bw"],
                ici_links=int(round(params["ici_links"])),
                inter_pod_bw=params["inter_pod_bw"],
                scale={"compute": params["scale_compute"],
                       "memory": params["scale_memory"],
                       "interconnect": params["scale_interconnect"]},
            ))
        return out

    def to_json(self) -> dict:
        return {
            "steps": self.steps,
            "w_area": self.w_area,
            "w_power": self.w_power,
            "best_variant": f"{self.names[self.best]}+grad",
            "variants": [
                {"name": f"{n}+grad",
                 "objective_seed": float(js),
                 "objective_final": float(jf),
                 "seed_params": sp,
                 "final_params": fp}
                for n, js, jf, sp, fp in zip(
                    self.names, self.objective_seed, self.objective_final,
                    self.seed_params, self.final_params)],
        }


def grad_codesign(
    profiles,
    machines,
    *,
    steps: int = 100,
    lr: float = 0.1,
    span: float = 16.0,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = K.IDEAL_EPS,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    w_area: float = 0.1,
    w_power: float = 0.05,
) -> CodesignResult:
    """Descend J from a seed population by ``jax.grad`` on log-rates.

    ``machines`` is the seed -- typically the named variants
    (``MachineBatch.from_models(VARIANTS)``); every seed design descends
    independently (the objective sums per-variant terms, so the gradient
    does not couple them).  ``beta`` follows the sweep convention (per-app
    default from variant ``beta_ref``, frozen during descent -- the paper's
    beta is a user target, not a design variable).  ``span`` clips each
    rate to [seed/span, seed*span], keeping designs inside a plausible
    process envelope.  ``lr`` is the initial per-variant step on log-rates,
    adapted by backtracking (x1.2 on success, x0.5 on failure), so the
    accepted objective sequence is monotone non-increasing per variant.

    Example (descend the three named seeds for a few steps):

    >>> from repro.core import VARIANTS, WorkloadProfile, grad_codesign
    >>> from repro.core.sweep import MachineBatch
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> cd = grad_codesign(apps, MachineBatch.from_models(VARIANTS), steps=3)
    >>> cd.names
    ['baseline', 'denser', 'densest']
    >>> bool((cd.improvement >= 0).all())     # backtracking never regresses
    True
    >>> cd.best_model().peak_flops > 0
    True
    """
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    if beta is None:
        beta_np = K.get_backend("numpy").default_beta(
            pb.arrays(), mb.select(beta_ref).arrays())
    else:
        beta_np = np.broadcast_to(
            np.asarray(beta, dtype=np.float64), (len(pb),))

    seed_rates = np.stack(
        [np.asarray(getattr(mb, f), dtype=np.float64) for f in OPT_FIELDS],
        axis=1)                                            # (V, 4)
    theta0 = np.log(seed_rates)
    lo, hi = theta0 - np.log(span), theta0 + np.log(span)

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

        def per_variant(theta):
            m = _machine_arrays_from_theta(jnp, theta, fixed)
            return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                    eps, cost_model, w_area, w_power)

        obj_fn = jax.jit(per_variant)
        grad_fn = jax.jit(jax.grad(lambda th: jnp.sum(per_variant(th))))

        theta = backend.asarray(theta0)
        f_cur = obj_fn(theta)
        lr_v = jnp.full((theta.shape[0],), float(lr))
        history = [backend.to_numpy(f_cur)]

        for _ in range(steps):
            g = grad_fn(theta)
            cand = jnp.clip(theta - lr_v[:, None] * g, lo_j, hi_j)
            f_new = obj_fn(cand)
            ok = f_new < f_cur
            theta = jnp.where(ok[:, None], cand, theta)
            f_cur = jnp.where(ok, f_new, f_cur)
            lr_v = jnp.where(ok, lr_v * 1.2, lr_v * 0.5)
            history.append(backend.to_numpy(f_cur))

        theta_np = backend.to_numpy(theta)
        f_final = backend.to_numpy(f_cur)

    final_rates = np.exp(theta_np)
    f_seed = history[0]

    def params_of(rates_row, i) -> Dict[str, float]:
        d = {f: float(rates_row[j]) for j, f in enumerate(OPT_FIELDS)}
        d["ici_links"] = float(fixed_np.ici_links[i])
        d["scale_compute"] = float(fixed_np.scale_compute[i])
        d["scale_memory"] = float(fixed_np.scale_memory[i])
        d["scale_interconnect"] = float(fixed_np.scale_interconnect[i])
        return d

    return CodesignResult(
        names=list(mb.names),
        objective_seed=np.asarray(f_seed),
        objective_final=np.asarray(f_final),
        seed_params=[params_of(seed_rates[i], i) for i in range(len(mb))],
        final_params=[params_of(final_rates[i], i) for i in range(len(mb))],
        trajectory=np.stack(history, axis=0),
        steps=steps,
        w_area=w_area,
        w_power=w_power,
    )
