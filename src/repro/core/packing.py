"""Multi-tenant packing: A apps across M machine instances (ROADMAP item).

The task-partitioning-and-floorplanning scenario from PAPERS.md asked the
natural question after single-machine co-design: given a FLEET of ``M``
machine instances and ``A`` applications, which apps should live on which
machine, and what should each machine look like, under per-subsystem
envelopes and a TOTAL silicon budget shared by the whole fleet?

``pack_codesign`` answers by alternation, reusing the group-axis
machinery of ``joint_codesign`` with the roles transposed -- there, each
app GROUP picks one sharding variant per machine; here, each APP picks
one machine instance:

  * assignment step -- the ``(A, M)`` aggregate-congruence matrix under
    the current fleet hardens to a one-hot argmin per app (or relaxes to
    an annealed softmax in ``mode="softmax"``);
  * descent step -- all ``M`` machines descend JOINTLY as one flattened
    ``(1, M*D)`` log-rate vector through the shared
    ``backtracking_descent``, so the fleet-total budget couples them
    while the assignment weights decouple the fit terms.

The retraction composes the per-machine operators of
``repro.core.constrained`` (span-clip box ∩ per-subsystem envelope, per
instance) with a FLEET budget projection: one scalar downward log-shift
applied to every machine, bisected so the summed area/power meets the
total budget -- monotone in the shift, so the bisection is exact to f64
resolution, and rate decreases preserve envelope feasibility.

A ``budgets`` schedule traces the fleet-level frontier J*(total budget)
by warm-started continuation exactly like ``frontier_codesign`` (budget
enters the retraction as a traced scalar; one compile serves the whole
schedule; monotone propagation carries tighter-budget incumbents to
looser budgets).  ``PackingResult`` implements the uniform
``markdown(top_k)`` / ``to_json(top_k)`` protocol, so packing requests
serve through ``repro.serving`` unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.core import kernels_xp as K
from repro.core.codesign import (
    OPT_FIELDS,
    _as_batches,
    _objective_terms,
    backtracking_descent,
    machine_arrays_from_theta,
    params_of_theta,
    resolve_beta,
    theta_box,
)
from repro.core.constrained import (
    FEASIBLE_RTOL,
    PROJECT_ITERS,
    _iterate,
    budget_feasible,
    project_to_budgets,
    validate_area_envelope,
)
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.sweep import MachineBatch

#: Packing assignment modes (mirrors ``joint_codesign``).
PACK_MODES = ("alternate", "softmax")

_PACK_DEFAULTS = dict(
    mode="alternate", steps=60, lr=0.1, span=16.0, beta=None,
    timing_model="serial", cost_model=DEFAULT_COST_MODEL,
    w_area=0.1, w_power=0.05, area_budget=None, power_budget=None,
    area_envelope=None, budgets=None, num_machines=4,
)


# --------------------------------------------------------------------------- #
# Assignment weights and the fleet objective
# --------------------------------------------------------------------------- #


def _pack_weights(agg: np.ndarray) -> np.ndarray:
    """``(A, M)`` one-hot-per-app weights: app ``a`` puts ``1/A`` on its
    argmin machine, so summing ``w * agg`` over both axes is the mean
    assigned aggregate (the transpose of ``joint``'s ``_hard_weights``)."""
    a, _ = agg.shape
    w = np.zeros_like(agg)
    w[np.arange(a), np.argmin(agg, axis=1)] = 1.0 / a
    return w


def _soft_weights(agg: np.ndarray, temp: float) -> np.ndarray:
    """Annealed-softmax assignment: rows sum to ``1/A``; hardens to
    ``_pack_weights`` as ``temp -> 0``."""
    a, _ = agg.shape
    z = -(agg - agg.min(axis=1, keepdims=True)) / max(temp, 1e-9)
    e = np.exp(z)
    return e / e.sum(axis=1, keepdims=True) / a


def fleet_objective(
    profiles,
    machines,
    *,
    beta=None,
    beta_ref: int = 0,
    timing_model: str = "serial",
    eps: float = K.IDEAL_EPS,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    w_area: float = 0.1,
    w_power: float = 0.05,
) -> float:
    """Best-assignment fleet J for ANY fleet (NumPy reference, scalar).

    Every app is assigned to its argmin machine; silicon terms sum over
    the whole fleet -- the exact objective ``pack_codesign`` descends, so
    this is the yardstick for comparing a packed fleet against, e.g., M
    copies of the best single-machine design (the acceptance pin in
    tests/test_packing.py).
    """
    pb, mb = _as_batches(profiles, machines)
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    p, m = pb.arrays(), mb.arrays()
    out = K.congruence_kernel(np, p, m, beta_np, timing_model, eps,
                              clamp=False)
    agg = np.asarray(out.aggregate)
    fit = float(agg.min(axis=1).mean())
    return (fit + w_area * float(np.sum(cost_model.area(m)))
            + w_power * float(np.sum(cost_model.power(m))))


# --------------------------------------------------------------------------- #
# Fleet-total budget projection (one scalar shift across all machines)
# --------------------------------------------------------------------------- #


def _fleet_shift(xp, th, lo, fixed, cost_model: CostModel, area_budget,
                 power_budget, iters: int = PROJECT_ITERS):
    """Retract an ``(M, D)`` fleet onto the TOTAL-budget sublevel set.

    One scalar downward log-shift ``t`` (a uniform multiplicative rescale
    of every rate on every machine), floored at the box's lower edge,
    bisected to the smallest ``t >= 0`` with ``sum(area) <= area_budget``
    (and ``sum(power) <= power_budget`` when set).  Every summed quantity
    is strictly increasing in every rate, so feasibility is monotone in
    ``t`` and the bisection is exact to f64 resolution; shifting DOWN
    also preserves any per-machine envelope feasibility established
    before the call.  ``area_budget`` may be a traced scalar -- the
    frontier continuation compiles this once for its whole schedule.
    """

    def at(t):
        return xp.maximum(th - t, lo)

    def ok(t):
        m = machine_arrays_from_theta(xp, at(t), fixed)
        good = xp.asarray(True)
        if area_budget is not None:
            good = good & (xp.sum(cost_model.area(m)) <= area_budget)
        if power_budget is not None:
            good = good & (xp.sum(cost_model.power(m)) <= power_budget)
        return good

    zero = xp.zeros(())
    t_floor = xp.max(th - lo)
    ok0 = ok(zero)

    def bisect_step(_, bracket):
        t_lo, t_hi = bracket
        mid = 0.5 * (t_lo + t_hi)
        okm = ok(mid)
        return (xp.where(okm, t_lo, mid), xp.where(okm, mid, t_hi))

    _, t_hi = _iterate(xp, bisect_step, (zero, t_floor), iters)
    return at(xp.where(ok0, zero, t_hi))


def _fleet_feasible(m: K.MachineArrays, cost_model: CostModel,
                    area_budget: Optional[float],
                    power_budget: Optional[float],
                    area_envelope: Optional[Mapping[str, float]],
                    rtol: float = FEASIBLE_RTOL) -> bool:
    """Fleet-total budgets + every machine's envelope, to relative rtol."""
    ok = True
    if area_budget is not None:
        ok &= float(np.sum(cost_model.area(m))) <= area_budget * (1.0 + rtol)
    if power_budget is not None:
        ok &= float(np.sum(cost_model.power(m))) <= power_budget * (1.0 + rtol)
    if area_envelope:
        ok &= bool(np.all(budget_feasible(
            np, m, cost_model, None, None, rtol=rtol,
            area_envelope=area_envelope)))
    return bool(ok)


# --------------------------------------------------------------------------- #
# Result
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class PackingResult:
    """Outcome of one multi-tenant packing run.

    ``assignment[a]`` is the machine index app ``a`` landed on;
    ``trajectory`` is the accepted-objective history (monotone
    non-increasing in ``mode="alternate"`` -- descent steps only accept
    improvements and argmin re-assignment only lowers the fit term).
    When a ``budgets`` schedule was traced, the ``frontier_*`` arrays
    hold J*(total budget) ascending by budget and the main fields
    describe the TIGHTEST budget's fleet.

    Implements the uniform result protocol (``markdown(top_k)`` /
    ``to_json(top_k)``), so the serving front door renders it unchanged.
    """

    app_names: List[str]
    machine_names: List[str]
    assignment: np.ndarray            # (A,) machine index per app
    machines: MachineBatch            # the final fleet (M rows)
    seed_params: List[Dict[str, float]]
    final_params: List[Dict[str, float]]
    objective_seed: float
    objective_final: float
    trajectory: np.ndarray            # accepted fleet-J history
    per_app_aggregate: np.ndarray     # (A,) aggregate at the assigned machine
    area_total: float
    power_total: float
    feasible: Optional[bool]          # None when unconstrained
    mode: str = "alternate"
    steps: int = 0
    rounds: int = 0
    w_area: float = 0.1
    w_power: float = 0.05
    area_budget: Optional[float] = None      # fleet TOTAL
    power_budget: Optional[float] = None     # fleet TOTAL
    area_envelope: Optional[Dict[str, float]] = None
    budgets: Optional[np.ndarray] = None          # frontier schedule (asc)
    frontier_objective: Optional[np.ndarray] = None
    frontier_area: Optional[np.ndarray] = None
    frontier_feasible: Optional[np.ndarray] = None

    # ------------------------------ lookups --------------------------- #

    @property
    def improvement(self) -> float:
        return self.objective_seed - self.objective_final

    def apps_on(self, machine: int) -> List[str]:
        """App names assigned to machine index ``machine``."""
        return [a for a, mi in zip(self.app_names, self.assignment)
                if int(mi) == machine]

    # ------------------------------ reports --------------------------- #

    def markdown(self, top_k: Optional[int] = None) -> str:
        """Fleet table + assignment summary (``top_k`` caps listed app
        names per machine and frontier rows; None means the default 10,
        per the uniform result protocol)."""
        top_k = 10 if top_k is None else top_k
        m = self.machines
        lines = [
            f"packing: {len(self.app_names)} apps across "
            f"{len(m)} machines (pack-{self.mode}, {self.steps} steps, "
            f"{self.rounds} rounds)",
            f"objective: {self.objective_seed:.4f} -> "
            f"{self.objective_final:.4f} "
            f"(improvement {self.improvement:.4f})",
            f"fleet: area={self.area_total:.3f}"
            + (f" (budget {self.area_budget:.3f})"
               if self.area_budget is not None else "")
            + f" power={self.power_total:.3f}"
            + (f" (budget {self.power_budget:.3f})"
               if self.power_budget is not None else "")
            + ("" if self.feasible is None
               else f" feasible={bool(self.feasible)}"),
            "",
            "| machine | apps | mean agg | area | peak_flops | hbm_bw "
            "| ici_bw x links | inter_pod_bw |",
            "|---" * 8 + "|",
        ]
        for i in range(len(m)):
            rows = np.nonzero(self.assignment == i)[0]
            mean_agg = (float(self.per_app_aggregate[rows].mean())
                        if len(rows) else float("nan"))
            area_i = float(DEFAULT_COST_MODEL.area(m.take([i]))[0])
            lines.append(
                f"| {m.names[i]} | {len(rows)} | {mean_agg:.4f} "
                f"| {area_i:.3f} | {m.peak_flops[i]:.3e} "
                f"| {m.hbm_bw[i]:.3e} "
                f"| {m.ici_bw[i]:.3e} x {int(m.ici_links[i])} "
                f"| {m.inter_pod_bw[i]:.3e} |")
        lines.append("")
        for i in range(len(m)):
            apps = self.apps_on(i)
            shown = ", ".join(apps[:top_k])
            more = f" (+{len(apps) - top_k} more)" if len(apps) > top_k else ""
            lines.append(f"- {m.names[i]}: {shown or '(idle)'}{more}")
        if self.budgets is not None:
            lines += ["", f"fleet frontier J*(total budget) "
                          f"({len(self.budgets)} budgets, ascending):", ""]
            for j, b in enumerate(self.budgets[:top_k]):
                feas = bool(self.frontier_feasible[j])
                lines.append(
                    f"- budget {float(b):.3f}: "
                    f"J*={float(self.frontier_objective[j]):.4f} "
                    f"area={float(self.frontier_area[j]):.3f} "
                    f"{'feasible' if feas else 'INFEASIBLE'}")
        return "\n".join(lines)

    def to_json(self, top_k: Optional[int] = None) -> dict:
        top_k = 10 if top_k is None else top_k
        out = {
            "num_apps": len(self.app_names),
            "num_machines": len(self.machines),
            "mode": f"pack-{self.mode}",
            "steps": self.steps,
            "rounds": self.rounds,
            "objective_seed": self.objective_seed,
            "objective_final": self.objective_final,
            "improvement": self.improvement,
            "area_total": self.area_total,
            "power_total": self.power_total,
            "feasible": (None if self.feasible is None
                         else bool(self.feasible)),
            "area_budget": self.area_budget,
            "power_budget": self.power_budget,
            "area_envelope": (dict(self.area_envelope)
                              if self.area_envelope else None),
            "assignment": {app: self.machines.names[int(mi)]
                           for app, mi in zip(self.app_names,
                                              self.assignment)},
            "machines": [
                {"machine": self.machines.names[i],
                 "num_apps": int(np.sum(self.assignment == i)),
                 "apps": self.apps_on(i)[:top_k],
                 "params": self.final_params[i]}
                for i in range(len(self.machines))],
            "trajectory": [float(v) for v in self.trajectory],
        }
        if self.budgets is not None:
            out["frontier"] = [
                {"budget": float(b),
                 "objective": float(self.frontier_objective[j]),
                 "area_total": float(self.frontier_area[j]),
                 "feasible": bool(self.frontier_feasible[j])}
                for j, b in enumerate(self.budgets)]
        return out


# --------------------------------------------------------------------------- #
# The packing descent
# --------------------------------------------------------------------------- #


def pack_codesign(
    profiles,
    machines,
    *,
    num_machines: Optional[int] = None,
    mode: Optional[str] = None,
    rounds: int = 4,
    steps: Optional[int] = None,
    lr: Optional[float] = None,
    span: Optional[float] = None,
    beta=None,
    beta_ref: int = 0,
    timing_model: Optional[str] = None,
    eps: float = K.IDEAL_EPS,
    cost_model: Optional[CostModel] = None,
    w_area: Optional[float] = None,
    w_power: Optional[float] = None,
    area_budget: Optional[float] = None,
    power_budget: Optional[float] = None,
    area_envelope: Optional[Mapping[str, float]] = None,
    budgets: Optional[Sequence[float]] = None,
    temp0: float = 1.0,
    temp_min: float = 0.05,
    spec=None,
) -> PackingResult:
    """Assign ``A`` apps across ``num_machines`` instances by alternation.

    ``profiles`` accepts everything suite strings are accepted as
    elsewhere (a ``gen:<count>`` generated suite, a zoo suite, a profile
    list or a ``ProfileBatch``).  ``machines`` seeds the fleet: its rows
    are cycled up to ``num_machines`` instances, each descending its own
    log-rates.  ``area_budget`` / ``power_budget`` bound the fleet TOTAL
    (not each instance); ``area_envelope`` caps each instance
    per-subsystem, exactly as in ``constrained_codesign``.

    ``mode="alternate"`` hardens the assignment to each app's argmin
    machine between descent rounds (the round boundary is monotone:
    re-assignment can only lower the objective).  ``mode="softmax"``
    anneals a soft assignment from ``temp0`` down to ``temp_min`` and
    hardens at the end; an incumbent under the HARD assignment is tracked
    throughout, so the reported result never regresses past the seed.

    A ``budgets`` schedule traces J*(total budget) by warm-started
    continuation (ascending, validated like ``frontier_codesign``); the
    result's main fields then describe the tightest budget's fleet.

    >>> from repro.core import VARIANTS, pack_codesign
    >>> res = pack_codesign("gen:6", VARIANTS, num_machines=2,
    ...                     rounds=2, steps=4)
    >>> len(res.machines), len(res.assignment)
    (2, 6)
    >>> bool(res.objective_final <= res.objective_seed + 1e-12)
    True
    """
    from repro.core.frontier import _validate_budget_schedule
    from repro.core.spec import resolve_spec

    r = resolve_spec(spec, _PACK_DEFAULTS, dict(
        mode=mode, steps=steps, lr=lr, span=span, beta=beta,
        timing_model=timing_model, cost_model=cost_model, w_area=w_area,
        w_power=w_power, area_budget=area_budget, power_budget=power_budget,
        area_envelope=area_envelope, budgets=budgets,
        num_machines=num_machines))
    mode, steps, lr, span, beta = (r["mode"], r["steps"], r["lr"], r["span"],
                                   r["beta"])
    timing_model, cost_model = r["timing_model"], r["cost_model"]
    w_area, w_power = r["w_area"], r["w_power"]
    area_budget, power_budget = r["area_budget"], r["power_budget"]
    envelope = validate_area_envelope(r["area_envelope"])
    budgets, num_machines = r["budgets"], int(r["num_machines"])

    if mode not in PACK_MODES:
        raise ValueError(f"unknown packing mode {mode!r}; have {PACK_MODES}")
    if num_machines < 1:
        raise ValueError(f"num_machines must be >= 1, got {num_machines}")
    for name, b in (("area_budget", area_budget),
                    ("power_budget", power_budget)):
        if b is not None and not b > 0.0:
            raise ValueError(f"{name} must be positive, got {b!r}")
    schedule = (None if budgets is None
                else [float(b) for b in _validate_budget_schedule(budgets)])
    if schedule is not None and area_budget is not None:
        raise ValueError("pass either area_budget (one fleet budget) or "
                         "budgets (a frontier schedule), not both")

    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    pb, seed_mb = _as_batches(profiles, machines)
    if len(seed_mb) == 0:
        raise ValueError("pack_codesign needs at least one seed machine")
    idx = np.arange(num_machines) % len(seed_mb)
    fleet_mb = seed_mb.take(idx)
    fleet_mb = MachineBatch(
        names=[f"pack{i}-{n}" for i, n in enumerate(fleet_mb.names)],
        **{f: getattr(fleet_mb, f) for f in
           ("peak_flops", "hbm_bw", "ici_bw", "ici_links", "inter_pod_bw",
            "scale_compute", "scale_memory", "scale_interconnect")})
    fixed_np = fleet_mb.arrays()
    beta_np = resolve_beta(pb, seed_mb, beta, beta_ref)
    theta0, lo, hi = theta_box(fleet_mb, span)
    n_rates = theta0.shape[1]
    n_apps, n_mach = len(pb), num_machines
    # The fleet-total budget couples every machine, so the whole fleet
    # descends as ONE (1, M*D) row: scalar objective, global acceptance.
    theta0_flat = theta0.reshape(1, -1)
    swept_budget = schedule is not None
    constrained = area_budget is not None or power_budget is not None

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

        def retract_flat(th_flat, *budget_arg):
            th = th_flat.reshape(n_mach, n_rates)
            # Per-machine box ∩ envelope first (reduces to a clip with no
            # envelope), then the fleet-total shift -- which only lowers
            # rates, preserving the per-machine feasibility just won.
            th, _ = project_to_budgets(
                jnp, th, lo_j, hi_j, fixed, cost_model, None, None,
                area_envelope=envelope)
            if budget_arg:
                th = _fleet_shift(jnp, th, lo_j, fixed, cost_model,
                                  budget_arg[0], power_budget)
            elif constrained:
                th = _fleet_shift(jnp, th, lo_j, fixed, cost_model,
                                  area_budget, power_budget)
            return th.reshape(1, -1)

        def objective_with(th_flat, weights):
            m = machine_arrays_from_theta(
                jnp, th_flat.reshape(n_mach, n_rates), fixed)
            # Summing the per-machine terms folds the assignment-weighted
            # fit (rows of ``weights`` sum to 1/A) and the fleet silicon
            # into one scalar J, shape (1,) for the shared descent.
            terms = _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                     eps, cost_model, w_area, w_power,
                                     app_weights=weights)
            return jnp.sum(terms)[None]

        def aggregate_np(th_flat):
            m = machine_arrays_from_theta(
                jnp, th_flat.reshape(n_mach, n_rates), fixed)
            out = K.congruence_kernel(jnp, p_arrays, m, beta_j, timing_model,
                                      eps, clamp=False)
            return np.asarray(out.aggregate)

        cache: dict = {}
        steps_round = max(1, steps // max(rounds + 1, 1))

        def solve(theta_start, w_start, lr_start, rargs):
            """One full alternation (rounds + polish) at a fixed budget.

            Returns ``(theta, w_hard, f_final, history, lr)`` with the
            incumbent guarantee: ``f_final`` never exceeds the seed J.
            """
            theta = retract_flat(theta_start, *rargs)
            w_hard = (_pack_weights(aggregate_np(theta)) if w_start is None
                      else w_start)
            f_seed = np.asarray(objective_with(theta,
                                               backend.asarray(w_hard)))
            history: List[np.ndarray] = [f_seed]
            best_theta, best_f = theta, jnp.asarray(f_seed)
            lr_v = lr_start
            temps = np.geomspace(temp0, max(temp_min, 1e-6), max(rounds, 1))
            for ri in range(rounds):
                w_round = (w_hard if mode == "alternate"
                           else _soft_weights(aggregate_np(theta),
                                              float(temps[ri])))
                theta, _, hist, _, lr_v = backtracking_descent(
                    jax, jnp, theta, objective_with, steps_round, lr_v,
                    retract=retract_flat,
                    obj_args=(backend.asarray(w_round),),
                    retract_args=rargs, cache=cache)
                if mode == "alternate":
                    history.extend(hist[1:])
                w_hard = _pack_weights(aggregate_np(theta))
                f_bound = np.asarray(objective_with(
                    theta, backend.asarray(w_hard)))
                history.append(f_bound)
                better = jnp.asarray(f_bound) < best_f
                best_theta = jnp.where(better[:, None], theta, best_theta)
                best_f = jnp.minimum(jnp.asarray(f_bound), best_f)
            # Polish from the incumbent under its hard assignment.
            theta = best_theta
            w_hard = _pack_weights(aggregate_np(theta))
            theta, _, hist, _, lr_v = backtracking_descent(
                jax, jnp, theta, objective_with, steps_round, lr_v,
                retract=retract_flat,
                obj_args=(backend.asarray(w_hard),),
                retract_args=rargs, cache=cache)
            history.extend(hist[1:])
            w_hard = _pack_weights(aggregate_np(theta))
            f_final = np.asarray(objective_with(theta,
                                                backend.asarray(w_hard)))
            history.append(f_final)
            return theta, w_hard, f_final, history, lr_v

        if schedule is None:
            rargs = ((backend.asarray(float(area_budget)),)
                     if area_budget is not None else ())
            theta, w_hard, f_final, history, _ = solve(
                backend.asarray(theta0_flat), None, lr, rargs)
            theta_np = backend.to_numpy(theta)
            obj_seed = float(history[0][0])
            obj_final = float(f_final[0])
            frontier = None
        else:
            # Loosest -> tightest continuation: the budget is a traced
            # scalar, so every schedule point reuses one compiled descent.
            solved: Dict[float, dict] = {}
            theta_w, w_w, lr_w = backend.asarray(theta0_flat), None, lr
            obj_seed = None
            for b in sorted(schedule, reverse=True):
                rargs = (backend.asarray(float(b)),)
                theta_w, w_w, f_b, hist_b, lr_w = solve(
                    theta_w, w_w, lr_w, rargs)
                if obj_seed is None:
                    obj_seed = float(hist_b[0][0])
                th_b = backend.to_numpy(theta_w)
                m_b = machine_arrays_from_theta(
                    np, th_b.reshape(n_mach, n_rates), fixed_np)
                solved[b] = dict(
                    theta=th_b, w=w_w, obj=float(f_b[0]), history=hist_b,
                    area=float(np.sum(cost_model.area(m_b))),
                    feasible=_fleet_feasible(m_b, cost_model, b,
                                             power_budget, envelope))
            # Monotone propagation tightest -> loosest: a fleet feasible
            # at a tighter total budget is feasible at every looser one,
            # so J*(budget) is non-increasing as the budget loosens.
            best = None
            for b in sorted(schedule):
                if (best is not None and best["feasible"]
                        and best["obj"] < solved[b]["obj"]):
                    solved[b] = dict(best, feasible=True)
                if solved[b]["feasible"] and (best is None
                                              or not best["feasible"]
                                              or solved[b]["obj"]
                                              <= best["obj"]):
                    best = solved[b]
            tightest = min(schedule)
            theta_np = solved[tightest]["theta"]
            obj_final = solved[tightest]["obj"]
            history = solved[tightest]["history"]
            frontier = dict(
                budgets=np.asarray(sorted(schedule)),
                objective=np.asarray([solved[b]["obj"]
                                      for b in sorted(schedule)]),
                area=np.asarray([solved[b]["area"]
                                 for b in sorted(schedule)]),
                feasible=np.asarray([solved[b]["feasible"]
                                     for b in sorted(schedule)]))
            area_budget = tightest

    final_m = machine_arrays_from_theta(np, theta_np.reshape(n_mach, n_rates),
                                        fixed_np)
    agg_final = _final_aggregate(pb, final_m, beta_np, timing_model, eps)
    assignment = np.argmin(agg_final, axis=1)
    per_app = agg_final[np.arange(n_apps), assignment]
    area_total = float(np.sum(cost_model.area(final_m)))
    power_total = float(np.sum(cost_model.power(final_m)))
    feasible = (_fleet_feasible(final_m, cost_model, area_budget,
                                power_budget, envelope)
                if (constrained or swept_budget or envelope) else None)
    theta_rows = theta_np.reshape(n_mach, n_rates)
    final_machines = MachineBatch(
        names=list(fleet_mb.names),
        **{f: np.array([params_of_theta(theta_rows[i], fixed_np, i)[f]
                        for i in range(n_mach)])
           for f in OPT_FIELDS},
        ici_links=np.asarray(fixed_np.ici_links, dtype=np.float64),
        scale_compute=np.asarray(fixed_np.scale_compute, dtype=np.float64),
        scale_memory=np.asarray(fixed_np.scale_memory, dtype=np.float64),
        scale_interconnect=np.asarray(fixed_np.scale_interconnect,
                                      dtype=np.float64))

    res = PackingResult(
        app_names=list(pb.names),
        machine_names=list(fleet_mb.names),
        assignment=assignment,
        machines=final_machines,
        seed_params=[params_of_theta(theta0[i], fixed_np, i)
                     for i in range(n_mach)],
        final_params=[params_of_theta(theta_rows[i], fixed_np, i)
                      for i in range(n_mach)],
        objective_seed=obj_seed,
        objective_final=obj_final,
        trajectory=np.concatenate([np.atleast_1d(h) for h in history]),
        per_app_aggregate=per_app,
        area_total=area_total,
        power_total=power_total,
        feasible=feasible,
        mode=mode,
        steps=steps,
        rounds=rounds,
        w_area=w_area,
        w_power=w_power,
        area_budget=(float(area_budget) if area_budget is not None else None),
        power_budget=(float(power_budget)
                      if power_budget is not None else None),
        area_envelope=envelope,
    )
    if frontier is not None:
        res.budgets = frontier["budgets"]
        res.frontier_objective = frontier["objective"]
        res.frontier_area = frontier["area"]
        res.frontier_feasible = frontier["feasible"]
    return res


def _final_aggregate(pb, m: K.MachineArrays, beta_np, timing_model: str,
                     eps: float) -> np.ndarray:
    """(A, M) aggregate matrix at the final fleet (NumPy, reporting path)."""
    out = K.congruence_kernel(np, pb.arrays(), m, beta_np, timing_model, eps,
                              clamp=False)
    return np.asarray(out.aggregate)
