"""One request object for every co-design entry point.

PRs 1-5 grew five entry points (``run_sweep``, ``constrained_codesign``,
``joint_codesign``, ``frontier_codesign`` and the DSE ``evaluate``) whose
keyword surfaces drifted apart; a serving front door cannot forward five
different signatures.  ``CodesignSpec`` is the unified request: one frozen
dataclass carrying budgets, envelopes, the frontier schedule, descent
knobs and the kernel backend, accepted by every co-design entry point via
``spec=`` and by ``repro.serving.codesign_service`` as the request body.

Resolution order is fixed and explicit everywhere: an explicitly-passed
keyword wins, then the spec's field, then the entry point's historical
default -- so ``constrained_codesign(..., spec=s, steps=5)`` runs 5 steps
no matter what ``s.steps`` says, and legacy keyword-only call sites are
byte-identical to their pre-spec behaviour (pinned in
tests/test_constrained.py).

Validation is the ONE shared path: ``CodesignSpec.validate()`` delegates
to the same ``validate_area_envelope`` / ``_validate_budget_schedule`` /
``validate_backend_name`` checks the entry points themselves run, so a
spec that validates cannot fail parameter checks downstream, and CLIs
(``launch/hillclimb.py``, ``launch/serve_codesign.py``) reject bad
requests at parse time without re-implementing the rules.

>>> spec = CodesignSpec(area_budget=1.0, steps=5)
>>> spec.validate().area_budget
1.0
>>> CodesignSpec.from_json(spec.to_json()) == spec
True
>>> CodesignSpec(projection="bogus").validate()
Traceback (most recent call last):
    ...
ValueError: unknown projection 'bogus'; have ('shift', 'euclidean')
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from repro.core.costmodel import CostModel
from repro.core.kernels_xp import validate_backend_name

#: Constraint modes ``constrained_codesign`` accepts.
CONSTRAINED_MODES = ("projected", "lagrangian")
#: Selection modes ``joint_codesign`` accepts.
JOINT_MODES = ("alternate", "softmax")
#: Budget-projection retractions.
PROJECTIONS = ("shift", "euclidean")
#: Population generators ``run_sweep``/``shard_sweep`` accept.
SWEEP_MODES = ("random", "grid")


@dataclasses.dataclass(frozen=True)
class CodesignSpec:
    """Unified co-design request.

    Every field is optional; ``None`` means "use the entry point's
    default".  Fields irrelevant to an entry point are ignored there
    (``budgets`` only drives ``frontier_codesign``; ``n``/``sweep_mode``/
    ``seed`` only drive sweep requests), so one spec can describe a whole
    exploration session and be handed to each stage unchanged.
    """

    # ---- constraint set -------------------------------------------------
    area_budget: Optional[float] = None
    power_budget: Optional[float] = None
    area_envelope: Optional[Mapping[str, float]] = None
    budgets: Optional[Sequence[float]] = None   # frontier schedule
    # ---- descent knobs --------------------------------------------------
    mode: Optional[str] = None                  # constrained OR joint mode
    projection: Optional[str] = None
    steps: Optional[int] = None
    refine_steps: Optional[int] = None
    lr: Optional[float] = None
    span: Optional[float] = None
    warm_start: Optional[bool] = None
    optimize_links: Optional[bool] = None
    w_area: Optional[float] = None
    w_power: Optional[float] = None
    # ---- scoring --------------------------------------------------------
    beta: Optional[float] = None
    timing_model: Optional[str] = None
    cost_model: Optional[CostModel] = None
    backend: Optional[str] = None
    clamp: Optional[bool] = None
    # ---- sweep population ----------------------------------------------
    n: Optional[int] = None
    sweep_mode: Optional[str] = None
    seed: Optional[int] = None
    # ---- multi-tenant packing ------------------------------------------
    num_machines: Optional[int] = None          # pack_codesign fleet size
    # ---- bilevel budget descent (implicit.py) ---------------------------
    total_budget: Optional[float] = None        # split across area + power
    split0: Optional[float] = None              # initial area share, (0, 1)
    outer_steps: Optional[int] = None           # outer descent iterations
    outer_lr: Optional[float] = None            # outer step size on the split
    # ---- workload suite -------------------------------------------------
    suite: Optional[str] = None      # zoo[-smoke][:scenario] | gen:<count>

    # ------------------------------------------------------------------ #

    def validate(self) -> "CodesignSpec":
        """Run the shared validation path; returns a normalized copy.

        Delegates to the same checks the entry points run --
        ``validate_area_envelope`` (constrained), the budget-schedule
        validator (frontier) and ``validate_backend_name`` (kernels) --
        so validating here IS validating everywhere.
        """
        from repro.core.constrained import validate_area_envelope
        from repro.core.frontier import _validate_budget_schedule
        from repro.core.model_zoo import validate_suite_name

        validate_suite_name(self.suite)
        envelope = validate_area_envelope(self.area_envelope)
        budgets: Optional[Tuple[float, ...]] = None
        if self.budgets is not None:
            budgets = tuple(_validate_budget_schedule(self.budgets))
        validate_backend_name(self.backend)
        for name, value in (("area_budget", self.area_budget),
                            ("power_budget", self.power_budget)):
            if value is not None and not value > 0.0:
                raise ValueError(f"{name} must be positive, got {value!r}")
        if (self.mode is not None
                and self.mode not in CONSTRAINED_MODES + JOINT_MODES):
            raise ValueError(
                f"unknown mode {self.mode!r}; have "
                f"{CONSTRAINED_MODES + JOINT_MODES}")
        if self.projection is not None and self.projection not in PROJECTIONS:
            raise ValueError(f"unknown projection {self.projection!r}; "
                             f"have {PROJECTIONS}")
        if self.sweep_mode is not None and self.sweep_mode not in SWEEP_MODES:
            raise ValueError(f"unknown sweep_mode {self.sweep_mode!r}; "
                             f"have {SWEEP_MODES}")
        for name in ("steps", "refine_steps", "n", "num_machines",
                     "outer_steps"):
            value = getattr(self, name)
            if value is not None and not int(value) > 0:
                raise ValueError(f"{name} must be positive, got {value!r}")
        for name in ("total_budget", "outer_lr"):
            value = getattr(self, name)
            if value is not None and not value > 0.0:
                raise ValueError(f"{name} must be positive, got {value!r}")
        if self.split0 is not None and not 0.0 < self.split0 < 1.0:
            raise ValueError("split0 must lie strictly inside (0, 1), "
                             f"got {self.split0!r}")
        return dataclasses.replace(self, area_envelope=envelope,
                                   budgets=budgets)

    # ------------------------------------------------------------------ #

    def to_json(self) -> dict:
        """Plain-JSON form (``None`` fields omitted; the default cost
        model is omitted too -- a custom one serializes structurally)."""
        out: Dict[str, Any] = {}
        for f in dataclasses.fields(self):
            value = getattr(self, f.name)
            if value is None:
                continue
            if f.name == "cost_model":
                value = {
                    "reference": value.reference.to_json(),
                    "area_weights": dict(value.area_weights),
                    "power_weights": dict(value.power_weights),
                    "power_exponents": dict(value.power_exponents),
                    "static_power": value.static_power,
                }
            elif f.name == "area_envelope":
                value = dict(value)
            elif f.name == "budgets":
                value = [float(b) for b in value]
            out[f.name] = value
        return out

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "CodesignSpec":
        from repro.core.machine import MachineModel

        kw = dict(d)
        cm = kw.get("cost_model")
        if isinstance(cm, Mapping):
            kw["cost_model"] = CostModel(
                reference=MachineModel.from_json(cm["reference"]),
                area_weights=dict(cm["area_weights"]),
                power_weights=dict(cm["power_weights"]),
                power_exponents=dict(cm["power_exponents"]),
                static_power=float(cm["static_power"]),
            )
        if kw.get("budgets") is not None:
            kw["budgets"] = tuple(float(b) for b in kw["budgets"])
        known = {f.name for f in dataclasses.fields(CodesignSpec)}
        unknown = set(kw) - known
        if unknown:
            raise ValueError(f"unknown CodesignSpec fields {sorted(unknown)}")
        return CodesignSpec(**kw)

    def __eq__(self, other) -> bool:
        if not isinstance(other, CodesignSpec):
            return NotImplemented
        norm = lambda s: tuple(
            (f.name, _normalize(getattr(s, f.name)))
            for f in dataclasses.fields(s))
        return norm(self) == norm(other)


def _normalize(value):
    if isinstance(value, Mapping):
        return tuple(sorted(value.items()))
    if isinstance(value, (list, tuple)):
        return tuple(value)
    return value


def resolve_spec(spec: Optional[CodesignSpec], defaults: Mapping[str, Any],
                 explicit: Mapping[str, Any]) -> Dict[str, Any]:
    """Final parameter values for one entry point.

    For each name in ``defaults``: an explicitly-passed (non-None) keyword
    wins, then the spec's field, then the default.  ``sweep_mode`` on the
    spec feeds a plain ``mode`` parameter on sweep entry points via the
    name itself -- callers pass the mapping they need.
    """
    out: Dict[str, Any] = {}
    for name, default in defaults.items():
        value = explicit.get(name)
        if value is None and spec is not None:
            value = getattr(spec, name, None)
        out[name] = default if value is None else value
    return out
