"""Congruence profiling core -- the paper's contribution, adapted to TPU pods.

Public API:
  MachineModel / Subsystem / VARIANTS      -- hardware models + idealization
  WorkloadProfile / profile_from_compiled  -- compile-once cost extraction
  subsystem_times / step_time              -- lightweight timing analysis
  congruence_score / profile_congruence    -- Eq. 1 + ICS/HRCS/LBCS reports
  roofline.analyze                         -- three-term roofline reports
  dse.evaluate                             -- Table I-style variant sweeps
  sweep.ParamSpace / batched_congruence    -- vectorized population sweeps
  sweep.run_sweep / shard_sweep            -- one-call + mesh-sharded sweeps
  kernels_xp.get_backend                   -- numpy/jax/pallas kernel backends
  costmodel.CostModel                      -- area + power silicon proxies
  codesign.grad_codesign                   -- jax.grad machine co-design
  constrained.constrained_codesign         -- budgeted descent (area/power
                                              budgets + per-subsystem
                                              area envelopes)
  constrained.joint_codesign               -- joint machine+sharding descent
  frontier.frontier_codesign               -- J*(budget) feasibility frontier
                                              by warm-started continuation
  implicit.implicit_sensitivities          -- KKT shadow prices and
                                              dJ*/d(budget) at an optimum
                                              via the implicit function
                                              theorem (plus sensitivities_of
                                              for CodesignResults)
  implicit.bilevel_codesign                -- outer budget-split descent
                                              through the inner optimum
                                              (implicit custom-VJP gradient)
  genload.AppSpace                         -- generated-workload stress
                                              populations ("gen:<n>" suites,
                                              index-addressed sampling)
  packing.pack_codesign                    -- multi-tenant packing: A apps
                                              across M machine instances
                                              under fleet budgets
  spec.CodesignSpec                        -- one validated request object
                                              accepted by every co-design
                                              entry point and the serving
                                              front door
  model_zoo.profiles_from_configs          -- registry configs x scenarios
                                              -> measured WorkloadProfile
                                              suites ("zoo"/"zoo-smoke"),
                                              cached as JSON artifacts
  model_zoo.calibration_report             -- Eq.1 kernels vs roofline
                                              step-time cross-check

See docs/architecture.md for the layer map and docs/backends.md for the
backend-authoring contract.
"""

from repro.core.codesign import CodesignResult, grad_codesign, scalarized_objective
from repro.core.constrained import (
    constrained_codesign,
    joint_codesign,
    project_to_budgets,
    validate_area_envelope,
)
from repro.core.frontier import FrontierResult, frontier_codesign
from repro.core.implicit import (
    BilevelResult,
    SensitivityReport,
    bilevel_codesign,
    implicit_jstar_fn,
    implicit_sensitivities,
    sensitivities_of,
    unrolled_jstar_fn,
)
from repro.core.genload import (
    APP_PARAMS,
    AppSpace,
    is_gen_suite,
    parse_gen_suite,
    resolve_gen_suite,
)
from repro.core.packing import PackingResult, fleet_objective, pack_codesign
from repro.core.congruence import (
    CongruenceReport,
    SCORE_NAMES,
    congruence_score,
    default_beta,
    profile_congruence,
)
from repro.core.costmodel import DEFAULT_COST_MODEL, CostModel
from repro.core.costs import (
    COLLECTIVE_KINDS,
    HloStats,
    WorkloadProfile,
    parse_hlo_stats,
    profile_from_compiled,
)
from repro.core.dse import DseCell, DseTable, LazyDseTable, evaluate
from repro.core.kernels_xp import (
    Backend,
    available_backends,
    get_backend,
    register_backend,
    validate_backend_name,
)
from repro.core.model_zoo import (
    CalibrationReport,
    ZooCell,
    calibration_report,
    profiles_from_configs,
    resolve_suite,
    validate_suite_name,
    zoo_cells,
)
from repro.core.spec import CodesignSpec, resolve_spec
from repro.core.machine import (
    ALL_SUBSYSTEMS,
    IDEAL_EPS,
    MachineModel,
    Subsystem,
    TPU_DENSER,
    TPU_DENSEST,
    TPU_V5E,
    VARIANTS,
    VARIANTS_BY_NAME,
    get_variant,
)
from repro.core.roofline import RooflineReport, analyze, markdown_table, model_flops_for
from repro.core.sweep import (
    Dim,
    MachineBatch,
    ParamSpace,
    PopulationStream,
    ProfileBatch,
    ShardedSweepResult,
    SweepResult,
    batched_congruence,
    batched_step_time,
    load_population,
    run_sweep,
    save_population,
    shard_sweep,
)
from repro.core.timing import TimingBreakdown, step_time, subsystem_times
