"""Machine models for congruence profiling.

The paper idealizes one FPGA subsystem at a time (near-zero delay) and re-runs
only the timing analysis.  Our machine model is the TPU analogue of the VPR
architecture description: a small set of hardware constants per subsystem.
``MachineModel.idealized(subsystem)`` returns a copy with that subsystem's
delay scaled to near zero (``IDEAL_EPS``), mirroring the paper's 0.2 ns
"optimistic ideal delay" rather than an exact zero.

Subsystem mapping (see DESIGN.md §2):
  INTERCONNECT -> ICI collective network        (paper: routing fabric, ICS)
  MEMORY       -> HBM bandwidth                 (paper: H-blocks/BRAM, HRCS)
  COMPUTE      -> MXU/VPU FLOPs                 (paper: general logic, LBCS)
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Mapping

# Paper §II: "We set these modified delays near-zero to emulate the Roofline
# ideal for each subsystem" -- the paper uses 0.2ns instead of exactly zero;
# we scale subsystem time by IDEAL_EPS.
IDEAL_EPS = 1e-3


class Subsystem(str, enum.Enum):
    """The three profiled subsystems (paper: interconnect / H-blocks / logic)."""

    COMPUTE = "compute"            # LBCS analogue (MXU/VPU)
    MEMORY = "memory"              # HRCS analogue (HBM)
    INTERCONNECT = "interconnect"  # ICS analogue (ICI)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


ALL_SUBSYSTEMS = (Subsystem.COMPUTE, Subsystem.MEMORY, Subsystem.INTERCONNECT)


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Closed-form hardware model of one chip inside a pod.

    All rates are *per chip*; roofline terms divide per-device work by these
    rates, which is algebraically identical to global-work / (chips * rate).
    """

    name: str
    peak_flops: float          # bf16 FLOP/s per chip (MXU+VPU)
    hbm_bw: float              # HBM bytes/s per chip
    ici_bw: float              # ICI bytes/s per link per chip
    ici_links: int = 1         # effective links engaged per collective step
    inter_pod_bw: float = 25.0e9   # bytes/s per chip across the pod axis (DCN-like)
    mxu_fraction: float = 1.0  # fraction of peak available to non-matmul ops
    # Per-subsystem delay scale factors; 1.0 = nominal, IDEAL_EPS = idealized.
    scale: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {s.value: 1.0 for s in ALL_SUBSYSTEMS}
    )

    # ------------------------------------------------------------------ #

    def scale_for(self, subsystem: Subsystem) -> float:
        return float(self.scale.get(subsystem.value, 1.0))

    def idealized(self, subsystem: Subsystem, eps: float = IDEAL_EPS) -> "MachineModel":
        """Return a copy with ``subsystem``'s delay scaled to near-zero.

        This is the paper's core move: modify the architecture description so
        one subsystem runs at its Roofline ideal, leaving the mapping (for us:
        the compiled HLO and its extracted costs) untouched.
        """
        new_scale: Dict[str, float] = dict(self.scale)
        new_scale[subsystem.value] = eps
        return dataclasses.replace(
            self, name=f"{self.name}+ideal-{subsystem.value}", scale=new_scale
        )

    def with_scales(self, **scales: float) -> "MachineModel":
        new_scale: Dict[str, float] = dict(self.scale)
        for key, value in scales.items():
            Subsystem(key)  # validate
            new_scale[key] = float(value)
        return dataclasses.replace(self, scale=new_scale)

    def with_rates(self, name: str = None, **rates: float) -> "MachineModel":
        """Copy with replaced provisioned rates (the co-design knobs).

        Valid keys: ``peak_flops``, ``hbm_bw``, ``ici_bw``, ``ici_links``,
        ``inter_pod_bw``.  ``ici_links`` is rounded to an int; delay
        ``scale`` factors are preserved (use ``with_scales`` for those).
        """
        allowed = ("peak_flops", "hbm_bw", "ici_bw", "ici_links",
                   "inter_pod_bw")
        for key in rates:
            if key not in allowed:
                raise KeyError(f"unknown rate {key!r}; have {allowed}")
        if "ici_links" in rates:
            rates["ici_links"] = int(round(rates["ici_links"]))
        if name is not None:
            rates["name"] = name
        return dataclasses.replace(self, **rates)

    @property
    def ici_bw_total(self) -> float:
        return self.ici_bw * self.ici_links

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["scale"] = dict(self.scale)
        return d

    @staticmethod
    def from_json(d: dict) -> "MachineModel":
        return MachineModel(**d)


# --------------------------------------------------------------------------- #
# Hardware variants -- the paper's baseline / denser / densest sweep (Table I).
# Baseline constants are the assignment's TPU v5e numbers:
#   197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.
# "denser"/"densest" increase the specialized-resource density the same way
# the paper raises DSP/BRAM ratios (DESIGN.md §4).
# --------------------------------------------------------------------------- #

TPU_V5E = MachineModel(
    name="baseline",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=1,
)

TPU_DENSER = MachineModel(
    name="denser",
    peak_flops=394e12,       # 2x compute density
    hbm_bw=1228e9,           # 1.5x HBM
    ici_bw=50e9,
    ici_links=1,
)

TPU_DENSEST = MachineModel(
    name="densest",
    peak_flops=459e12,       # v5p-like
    hbm_bw=2765e9,
    ici_bw=100e9,
    ici_links=1,
)

VARIANTS = (TPU_V5E, TPU_DENSER, TPU_DENSEST)
VARIANTS_BY_NAME = {m.name: m for m in VARIANTS}


def get_variant(name: str) -> MachineModel:
    try:
        return VARIANTS_BY_NAME[name]
    except KeyError as exc:  # pragma: no cover - defensive
        raise KeyError(
            f"unknown machine variant {name!r}; have {sorted(VARIANTS_BY_NAME)}"
        ) from exc
