"""Constrained + joint gradient co-design under real silicon budgets.

``grad_codesign`` answers "in which direction should the machine move?";
unconstrained, it happily inflates every subsystem until the span clip
stops it.  This module turns the reproduction into a usable co-design tool
by keeping descent inside an area (and optionally power) budget -- the
paper's early-design-exploration pitch under the resource budgets that
heterogeneous-FPGA exploration treats as first-class:

  * **Projected gradient** (``mode="projected"``) -- every candidate step
    is retracted onto ``{CostModel.area(m) <= budget}`` before the
    backtracking acceptance test, so every accepted iterate is feasible.
    The projection works in the SAME log-rate space the descent runs in: a
    uniform log-shift ``theta -> max(theta - t, lo)`` (a multiplicative
    rescale of every rate, floored at the span clip's lower box edge) with
    ``t`` solved by bisection so the active budget binds exactly.  Because
    the operator clips internally and is idempotent, it commutes with the
    span clip -- the order-of-operations regression pinned in
    tests/test_constrained.py.
  * **Augmented Lagrangian** (``mode="lagrangian"``) -- descent on
    ``J + (1/2mu) * (relu(lam + mu*(area - budget))^2 - lam^2)`` with dual
    updates between inner descents; iterates may leave the feasible region
    but the recorded violation trace is monotonically damped (an outer
    iterate is only accepted when it does not increase the violation), and
    a final safety projection makes the returned machines feasible to
    1e-9.
  * **Joint (machine, sharding-variant) descent** (``joint_codesign``) --
    each application contributes a GROUP of sharding variants; descent
    optimizes machine log-rates jointly with the per-(app, variant) choice,
    either by alternation (harden the argmin selection, descend, repeat) or
    simultaneously through a temperature-annealed softmax relaxation over
    the group axis.  Both finish with a hard selection.
  * **Integer relaxation for** ``ici_links`` (``optimize_links=True``) --
    a continuous ``log(ici_links)`` column joins theta (floored at one
    link); after descent each variant is rounded BOTH ways, each rounding
    is repaired by re-projecting the rate columns onto the budget with the
    links column held fixed, and the feasible argmin wins -- so
    rounding-with-repair never returns an infeasible link count.
  * **Per-subsystem area envelopes** (``area_envelope={"peak_flops": b1,
    "hbm_bw": b2, ...}``) -- one extra constraint per entry, bounding
    ``CostModel.subsystem_area(m, field) <= b`` (the subsystem's
    provisioned throughput relative to the reference chip).  Envelopes
    compose with the scalar budgets: the Lagrangian mode carries one
    multiplier PER constraint, and both projections honour them (the
    uniform shift through the monotone feasibility test; the Euclidean
    projection by tightening the box, since each envelope caps one
    log-rate column).  A single-key envelope budgets exactly what a
    scalar ``area_budget`` under the single-key ``CostModel`` restriction
    budgets -- pinned in tests/test_frontier.py.
  * **True Euclidean projection** (``projection="euclidean"``) -- the
    uniform log-shift retracts every rate by the same factor; the
    per-coordinate weighted Euclidean projection instead solves
    ``min ||theta' - theta||^2 s.t. budget(exp(theta')) <= B`` inside the
    span box, via Newton on each coordinate's KKT stationarity nested in
    a bisection on the constraint multiplier.  Floor-aware, idempotent,
    and it commutes with the span clip exactly like the uniform shift --
    both operator laws are pinned in tests/test_constrained.py.

All modes reuse the one descent loop and the one traceable objective in
``repro.core.codesign`` -- the same ``kernels_xp`` math every sweep scores
with -- and return the same ``CodesignResult`` (with the feasibility
report populated).  ``docs/codesign.md`` is the worked guide;
``repro.core.frontier`` traces whole budget *sweeps* over this module by
warm-started continuation (``docs/frontier.md``).
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core import kernels_xp as K
from repro.core.codesign import (
    OPT_FIELDS,
    CodesignResult,
    _as_batches,
    _objective_terms,
    backtracking_descent,
    machine_arrays_from_theta,
    params_of_theta,
    resolve_beta,
    theta_box,
)
from repro.core.costmodel import DEFAULT_COST_MODEL, RATE_FIELDS, CostModel

#: Relative slack the feasibility report allows: ``area <= budget*(1+TOL)``.
FEASIBLE_RTOL = 1e-9

#: Bisection iterations for the budget projection.  Each halves the shift
#: interval; 64 puts the boundary within f64 resolution of the exact root.
PROJECT_ITERS = 64

#: Inner Newton iterations for the Euclidean projection's per-coordinate
#: KKT stationarity solve (quadratically convergent from the seed point).
NEWTON_ITERS = 30

#: Multiplier-bracketing growth steps for the Euclidean projection:
#: 1e-6 * 8**25 > 1e16 covers every representable active constraint.
BRACKET_ITERS = 25


# --------------------------------------------------------------------------- #
# Constraint-set helpers (scalar budgets + per-subsystem envelopes)
# --------------------------------------------------------------------------- #


def validate_area_envelope(
        envelope: Optional[Mapping[str, float]]) -> Optional[Dict[str, float]]:
    """Normalize an ``area_envelope`` mapping (None/empty -> None).

    Keys must name cost-model rate fields, values must be positive; the
    returned dict is a plain copy so callers can stash it in results.

    >>> validate_area_envelope({"peak_flops": 1.5})
    {'peak_flops': 1.5}
    >>> validate_area_envelope({}) is None
    True
    >>> validate_area_envelope({"mxu_count": 1.0})
    Traceback (most recent call last):
        ...
    ValueError: unknown area_envelope field 'mxu_count'; have ('peak_flops', 'hbm_bw', 'ici_bw_total', 'inter_pod_bw')
    """
    if not envelope:
        return None
    out: Dict[str, float] = {}
    for field, b in envelope.items():
        if field not in RATE_FIELDS:
            raise ValueError(f"unknown area_envelope field {field!r}; "
                             f"have {RATE_FIELDS}")
        b = float(b)
        if not b > 0.0:
            raise ValueError(
                f"area_envelope[{field!r}] must be positive, got {b!r}")
        out[field] = b
    return out


def budget_feasible(xp, m: K.MachineArrays, cost_model: CostModel,
                    area_budget: Optional[float],
                    power_budget: Optional[float], rtol: float = FEASIBLE_RTOL,
                    area_envelope: Optional[Mapping[str, float]] = None):
    """Per-variant bool: every active constraint satisfied to relative
    ``rtol`` (scalar area/power budgets plus per-subsystem envelopes)."""
    ok = xp.ones_like(m.peak_flops, dtype=bool)
    if area_budget is not None:
        ok = ok & (cost_model.area(m) <= area_budget * (1.0 + rtol))
    if power_budget is not None:
        ok = ok & (cost_model.power(m) <= power_budget * (1.0 + rtol))
    if area_envelope:
        for field in sorted(area_envelope):
            ok = ok & (cost_model.subsystem_area(m, field)
                       <= area_envelope[field] * (1.0 + rtol))
    return ok


def budget_violations_vector(xp, m: K.MachineArrays, cost_model: CostModel,
                             area_budget: Optional[float],
                             power_budget: Optional[float],
                             area_envelope: Optional[Mapping[str, float]]
                             = None):
    """``(V, C)`` relative violation per active constraint, relu'd.

    Constraint order is static per configuration: scalar area, scalar
    power, then envelope fields sorted by name -- the augmented-Lagrangian
    mode keys one multiplier per column.
    """
    cols = []
    if area_budget is not None:
        cols.append(cost_model.area(m) / area_budget - 1.0)
    if power_budget is not None:
        cols.append(cost_model.power(m) / power_budget - 1.0)
    if area_envelope:
        for field in sorted(area_envelope):
            cols.append(cost_model.subsystem_area(m, field)
                        / area_envelope[field] - 1.0)
    if not cols:
        return xp.zeros_like(m.peak_flops)[:, None]
    return xp.maximum(xp.stack(cols, axis=1), 0.0)


def constraint_labels(area_budget, power_budget,
                      area_envelope: Optional[Mapping[str, float]] = None
                      ) -> List[str]:
    """Constraint-column names in ``budget_violations_vector`` order
    (scalar area, scalar power, then envelope fields sorted by name) --
    the shared key between the augmented-Lagrangian multipliers and the
    implicit shadow prices in ``repro.core.implicit``.

    >>> constraint_labels(1.0, None, {"hbm_bw": 0.5, "peak_flops": 2.0})
    ['area', 'hbm_bw', 'peak_flops']
    """
    labels = []
    if area_budget is not None:
        labels.append("area")
    if power_budget is not None:
        labels.append("power")
    if area_envelope:
        labels.extend(sorted(area_envelope))
    return labels


def budget_violation(xp, m: K.MachineArrays, cost_model: CostModel,
                     area_budget: Optional[float],
                     power_budget: Optional[float],
                     area_envelope: Optional[Mapping[str, float]] = None):
    """Worst relative constraint violation per variant (0 = feasible)."""
    return xp.max(budget_violations_vector(
        xp, m, cost_model, area_budget, power_budget, area_envelope), axis=1)


def _iterate(xp, body, init, iters: int):
    """Run ``body(i, state) -> state`` ``iters`` times -- rolled under a
    JAX trace (one loop body in the jaxpr, an order of magnitude off the
    projected-mode compile time), a plain Python loop eagerly."""
    if xp.__name__ == "jax.numpy":
        from jax import lax
        return lax.fori_loop(0, iters, body, init)
    state = init
    for i in range(iters):
        state = body(i, state)
    return state


def project_to_budgets(
    xp,
    theta,
    lo,
    hi,
    fixed: K.MachineArrays,
    cost_model: CostModel,
    area_budget: Optional[float],
    power_budget: Optional[float] = None,
    mask=None,
    iters: int = PROJECT_ITERS,
    area_envelope: Optional[Mapping[str, float]] = None,
    method: str = "shift",
):
    """Retract ``theta`` onto (span-clip box) ∩ (constraint set), per variant.

    The constraint set intersects the scalar ``area_budget``/
    ``power_budget`` sublevel sets with one per-subsystem cap per
    ``area_envelope`` entry.  Two retraction operators are available:

      * ``method="shift"`` (default) -- ``theta -> max(clip(theta) - t*,
        lo)``: a uniform downward log-shift of the (masked) columns, i.e.
        a multiplicative rescale of the corresponding rates, floored at
        the box's lower edge, with the smallest ``t* >= 0`` that satisfies
        every active constraint, found by bisection (every constraint
        quantity is strictly increasing in every rate, so feasibility is
        monotone in ``t``).
      * ``method="euclidean"`` -- the true per-coordinate weighted
        Euclidean projection in log-rate space (see
        ``_project_euclidean``): the closest feasible point rather than a
        uniform rescale, so a budget binding on one subsystem no longer
        drags the others down with it.

    Properties shared by both operators (pinned in
    tests/test_constrained.py):
      * the result is always inside the clip box;
      * when a feasible point exists under the floor, the result satisfies
        every constraint (to f64 bisection resolution, well within
        ``FEASIBLE_RTOL``);
      * idempotent, and absorbs the span clip on either side -- i.e. the
        clip and the projection commute through this combined operator.

    ``mask`` (shape ``(D,)`` bool) restricts the shift to a column subset
    (the rounding repair shifts rates while holding the rounded
    ``ici_links`` column fixed).  Returns ``(theta_projected, feasible)``;
    ``feasible`` is False only when even the floor violates a constraint
    (the floor point is still returned as the best effort).
    """
    th = xp.clip(theta, lo, hi)
    if area_budget is None and power_budget is None and not area_envelope:
        return th, xp.ones_like(th[:, 0], dtype=bool)
    if method == "euclidean":
        return _project_euclidean(xp, th, lo, hi, fixed, cost_model,
                                  area_budget, power_budget, area_envelope,
                                  mask, iters)
    if method != "shift":
        raise ValueError(f"unknown projection method {method!r}; "
                         "have ('shift', 'euclidean')")
    if mask is None:
        shift_mask = xp.ones_like(th[0])
    else:
        shift_mask = xp.asarray(mask).astype(th.dtype)

    def at_shift(t):
        return xp.where(shift_mask[None, :] > 0,
                        xp.maximum(th - t[:, None], lo), th)

    def feasible_at(t):
        m = machine_arrays_from_theta(xp, at_shift(t), fixed)
        # Feasibility at rtol=0: the bisection lands strictly inside the
        # budget, leaving the report's FEASIBLE_RTOL as pure slack.
        return budget_feasible(xp, m, cost_model, area_budget, power_budget,
                               rtol=0.0, area_envelope=area_envelope)

    zero = xp.zeros_like(th[:, 0])
    ok0 = feasible_at(zero)
    # Largest useful shift: every masked column at its floor.
    t_floor = xp.max(xp.where(shift_mask[None, :] > 0, th - lo,
                              xp.zeros_like(th)), axis=1)
    ok_floor = feasible_at(t_floor)

    def bisect_step(_, bracket):
        t_lo, t_hi = bracket
        mid = 0.5 * (t_lo + t_hi)
        okm = feasible_at(mid)
        return (xp.where(okm, t_lo, mid), xp.where(okm, mid, t_hi))

    t_lo, t_hi = _iterate(xp, bisect_step, (zero, t_floor), iters)
    # Return the feasible endpoint of the bracket; untouched where already
    # feasible (exact idempotence), floor where nothing is feasible.
    t_star = xp.where(ok0, zero, t_hi)
    return at_shift(t_star), ok0 | ok_floor


# --------------------------------------------------------------------------- #
# The Euclidean projection (per-coordinate KKT solve, log-rate space)
# --------------------------------------------------------------------------- #


def _area_posynomial(xp, cost_model: CostModel, fixed: K.MachineArrays):
    """``CostModel.area`` over 4-column theta as ``(coeff, expo, offset)``:
    ``area = sum_j coeff[:, j] * exp(expo[j] * theta[:, j])``.

    ``ici_links`` is fixed here (the Euclidean path rejects the links
    relaxation), so it folds into the ``ici_bw`` column's coefficient.
    """
    ref, w = cost_model.reference, cost_model.area_weights
    tw = sum(w.get(f, 0.0) for f in RATE_FIELDS)
    ones = xp.ones_like(fixed.ici_links)
    coeff = xp.stack([
        w.get("peak_flops", 0.0) / tw / ref.peak_flops * ones,
        w.get("hbm_bw", 0.0) / tw / ref.hbm_bw * ones,
        w.get("ici_bw_total", 0.0) / tw / ref.ici_bw_total * fixed.ici_links,
        w.get("inter_pod_bw", 0.0) / tw / ref.inter_pod_bw * ones,
    ], axis=1)
    return coeff, xp.asarray([1.0, 1.0, 1.0, 1.0]), 0.0


def _power_posynomial(xp, cost_model: CostModel, fixed: K.MachineArrays):
    """``CostModel.power`` over 4-column theta, same ``(coeff, expo,
    offset)`` shape; exponents carry the DVFS superlinearity and the
    static term becomes a constant offset against the budget."""
    ref, w = cost_model.reference, cost_model.power_weights
    e = {f: cost_model.power_exponents.get(f, 1.0) for f in RATE_FIELDS}
    tw = sum(w.get(f, 0.0) for f in RATE_FIELDS)
    ones = xp.ones_like(fixed.ici_links)
    coeff = xp.stack([
        w.get("peak_flops", 0.0) / tw
        / ref.peak_flops ** e["peak_flops"] * ones,
        w.get("hbm_bw", 0.0) / tw / ref.hbm_bw ** e["hbm_bw"] * ones,
        w.get("ici_bw_total", 0.0) / tw
        * (fixed.ici_links / ref.ici_bw_total) ** e["ici_bw_total"],
        w.get("inter_pod_bw", 0.0) / tw
        / ref.inter_pod_bw ** e["inter_pod_bw"] * ones,
    ], axis=1)
    expo = xp.asarray([e["peak_flops"], e["hbm_bw"], e["ici_bw_total"],
                       e["inter_pod_bw"]])
    return coeff, expo, cost_model.static_power


def _project_posynomial(xp, th, lo, hi, coeff, expo, budget, iters):
    """Exact Euclidean projection of each theta row onto
    ``{t in [lo, hi] : sum_j coeff_j * exp(expo_j * t_j) <= budget}``.

    KKT with multiplier ``nu >= 0``: each coordinate solves the
    stationarity ``t - x + nu * coeff * expo * exp(expo * t) = 0``
    (convex, solved by Newton from ``t0 = x`` where the residual is
    positive, so iterates descend monotonically onto the root), clipped
    to the box -- the clipped solve IS the box-constrained coordinate
    minimizer because objective and constraint are separable.  The
    constraint value is strictly decreasing in ``nu``, so the active
    multiplier is bracketed by geometric growth and pinned by bisection.
    Zero-coefficient columns (cost-model weight 0, masked columns) have
    zero stationarity correction and pass through untouched.
    """
    def g_of(t):
        return xp.sum(coeff * xp.exp(expo[None, :] * t), axis=1)

    def t_of(nu):
        k = nu[:, None] * coeff * expo[None, :]

        def newton(_, t):
            ex = xp.exp(expo[None, :] * t)
            return t - (t - th + k * ex) / (1.0 + k * expo[None, :] * ex)

        return xp.clip(_iterate(xp, newton, th, NEWTON_ITERS), lo, hi)

    ok0 = g_of(th) <= budget

    def grow(_, nu):
        return xp.where(g_of(t_of(nu)) <= budget, nu, nu * 8.0)

    nu_hi = _iterate(xp, grow, 1e-6 * xp.ones_like(th[:, 0]), BRACKET_ITERS)

    def bisect(_, bracket):
        nu_lo, nu_up = bracket
        mid = 0.5 * (nu_lo + nu_up)
        okm = g_of(t_of(mid)) <= budget
        return (xp.where(okm, nu_lo, mid), xp.where(okm, mid, nu_up))

    _, nu_star = _iterate(
        xp, bisect, (xp.zeros_like(nu_hi), nu_hi), iters)
    # Feasible bracket endpoint; bit-exact pass-through when already
    # feasible (idempotence).
    return xp.where(ok0[:, None], th, t_of(nu_star))


def _project_euclidean(xp, th, lo, hi, fixed, cost_model, area_budget,
                       power_budget, area_envelope, mask, iters):
    """Euclidean retraction onto box ∩ envelopes ∩ scalar budgets.

    Envelope caps are exact per-coordinate upper bounds in log space, so
    they tighten the box; each scalar budget then projects exactly via
    ``_project_posynomial``.  With BOTH scalar budgets active the two
    exact projections alternate (projections-onto-convex-sets); a final
    uniform-shift pass guarantees the feasibility contract wherever the
    alternation has not yet converged to 1e-9.
    """
    if th.shape[1] != len(OPT_FIELDS) or mask is not None:
        raise ValueError(
            "projection='euclidean' supports the 4 rate columns with no "
            "column mask; use the default 'shift' projection with the "
            "ici_links relaxation / rounding repair")
    hi_eff = hi
    if area_envelope:
        ref = cost_model.reference
        caps = {
            "peak_flops": lambda b: xp.log(b * ref.peak_flops)
            + xp.zeros_like(th[:, 0]),
            "hbm_bw": lambda b: xp.log(b * ref.hbm_bw)
            + xp.zeros_like(th[:, 0]),
            "ici_bw_total": lambda b: xp.log(
                b * ref.ici_bw_total / fixed.ici_links),
            "inter_pod_bw": lambda b: xp.log(b * ref.inter_pod_bw)
            + xp.zeros_like(th[:, 0]),
        }
        col = {f: j for j, f in
               enumerate(("peak_flops", "hbm_bw", "ici_bw_total",
                          "inter_pod_bw"))}
        cap_mat = xp.full_like(th, xp.inf)
        for field in sorted(area_envelope):
            j = col[field]
            cap_col = caps[field](area_envelope[field])
            cap_mat = _set_column(xp, cap_mat, j,
                                  xp.minimum(cap_mat[:, j], cap_col))
        # A cap below the box floor leaves no feasible point; pin the
        # column at the floor and let the feasibility flag report it.
        hi_eff = xp.maximum(xp.minimum(hi, cap_mat), lo)
    out = xp.clip(th, lo, hi_eff)

    constraints = []
    if area_budget is not None:
        coeff, expo, off = _area_posynomial(xp, cost_model, fixed)
        constraints.append((coeff, expo, area_budget - off))
    if power_budget is not None:
        coeff, expo, off = _power_posynomial(xp, cost_model, fixed)
        constraints.append((coeff, expo, power_budget - off))

    cycles = 1 if len(constraints) <= 1 else 6
    for _ in range(cycles):
        for coeff, expo, b in constraints:
            out = _project_posynomial(xp, out, lo, hi_eff, coeff, expo, b,
                                      iters)

    def feasible(t):
        m = machine_arrays_from_theta(xp, t, fixed)
        return budget_feasible(xp, m, cost_model, area_budget, power_budget,
                               rtol=0.0, area_envelope=area_envelope)

    ok = feasible(out)
    if len(constraints) > 1:
        # POCS converges to the intersection only in the limit; the shift
        # operator is the guaranteed-feasible fallback for the (rare)
        # variants still outside after the alternation cycles.
        fallback, _ = project_to_budgets(
            xp, out, lo, hi_eff, fixed, cost_model, area_budget,
            power_budget, iters=iters, area_envelope=area_envelope,
            method="shift")
        out = xp.where(ok[:, None], out, fallback)
        ok = feasible(out)
    ok_floor = feasible(xp.clip(lo, lo, hi_eff))
    return out, ok | ok_floor


def _set_column(xp, a, j: int, col):
    """Functional column assignment (works for NumPy and traced JAX)."""
    if xp.__name__ == "jax.numpy":
        return a.at[:, j].set(col)
    a = a.copy()
    a[:, j] = col
    return a


# --------------------------------------------------------------------------- #
# Constrained descent: projected gradient + augmented Lagrangian
# --------------------------------------------------------------------------- #


def _validate_budgets(area_budget, power_budget, area_envelope=None):
    if (area_budget is None and power_budget is None
            and not area_envelope):
        raise ValueError(
            "constrained_codesign needs area_budget, power_budget and/or "
            "area_envelope (use grad_codesign for unconstrained descent)")
    for name, b in (("area_budget", area_budget),
                    ("power_budget", power_budget)):
        if b is not None and not b > 0.0:
            raise ValueError(f"{name} must be positive, got {b!r}")
    return validate_area_envelope(area_envelope)


def _finalize(mb, fixed_np, theta0, theta_np, history, steps, w_area, w_power,
              cost_model, mode, suffix, area_budget, power_budget,
              violation_trace, feasible, objective_final,
              selection_names=None, area_envelope=None, multipliers=None,
              constraint_names=None) -> CodesignResult:
    final_m = machine_arrays_from_theta(np, theta_np, fixed_np)
    return CodesignResult(
        names=list(mb.names),
        objective_seed=np.asarray(history[0]),
        objective_final=np.asarray(objective_final),
        seed_params=[params_of_theta(theta0[i], fixed_np, i)
                     for i in range(len(mb))],
        final_params=[params_of_theta(theta_np[i], fixed_np, i)
                      for i in range(len(mb))],
        trajectory=np.stack(history, axis=0),
        steps=steps,
        w_area=w_area,
        w_power=w_power,
        mode=mode,
        suffix=suffix,
        area_budget=area_budget,
        power_budget=power_budget,
        area_envelope=area_envelope,
        area_final=np.asarray(cost_model.area(final_m)),
        power_final=np.asarray(cost_model.power(final_m)),
        feasible=np.asarray(feasible, dtype=bool),
        violation_trace=(np.stack(violation_trace, axis=0)
                         if violation_trace is not None else None),
        selection_names=selection_names,
        multipliers=multipliers,
        constraint_names=constraint_names,
    )


def _round_links_with_repair(theta_np, lo, hi, fixed_np, cost_model,
                             area_budget, power_budget, obj_np,
                             area_envelope=None):
    """Round the continuous ``log(ici_links)`` column both ways, re-project
    the rate columns onto the budget for each rounding, keep the feasible
    argmin (NumPy post-pass; returns the repaired theta and feasibility)."""
    links_col = len(OPT_FIELDS)
    rate_mask = np.array([True] * len(OPT_FIELDS) + [False])
    links_cont = np.exp(theta_np[:, links_col])
    # The span box bounds the CONTINUOUS relaxation; a rounded count must
    # land on an integer inside it, so clamp to the integer sub-range
    # [ceil(lo), floor(hi)] (floored at one link) -- clipping an integer
    # to a fractional box edge would smuggle a non-integer count into the
    # returned models.
    lo_links = np.maximum(np.ceil(np.exp(lo[:, links_col]) - 1e-9), 1.0)
    hi_links = np.maximum(np.floor(np.exp(hi[:, links_col]) + 1e-9),
                          lo_links)
    best_theta = theta_np.copy()
    best_obj = np.full(theta_np.shape[0], np.inf)
    best_feas = np.zeros(theta_np.shape[0], dtype=bool)
    for rounder in (np.floor, np.ceil):
        links = np.clip(rounder(links_cont), lo_links, hi_links)
        cand = theta_np.copy()
        cand[:, links_col] = np.log(links)
        # Repair: rounding up raises area; shift the RATES back under the
        # budget while holding the now-integral links column fixed.
        # The 5-column theta carries the rounded links in its last column,
        # so every constraint (the ici_bw_total envelope included) is
        # re-checked against the INTEGER link count during the repair.
        cand, feas = project_to_budgets(
            np, cand, lo, hi, fixed_np, cost_model, area_budget,
            power_budget, mask=rate_mask, area_envelope=area_envelope)
        # Rounding must not break integrality: the projection's mask keeps
        # the links column fixed, so re-read it as the exact integer.
        obj = obj_np(cand)
        # Feasible candidates always beat infeasible ones; ties on
        # feasibility resolve by objective.
        better = (feas & ~best_feas) | (
            (feas == best_feas) & (obj < best_obj))
        best_theta = np.where(better[:, None], cand, best_theta)
        best_obj = np.where(better, obj, best_obj)
        best_feas = best_feas | feas
    return best_theta, best_feas, best_obj


#: Historical defaults, now resolved through ``repro.core.spec.resolve_spec``
#: so legacy keyword-only calls stay byte-identical while ``spec=`` requests
#: fill unset parameters (explicit kwarg > spec field > this table).
_CONSTRAINED_DEFAULTS = dict(
    area_budget=None, power_budget=None, area_envelope=None,
    mode="projected", projection="shift", steps=100, lr=0.1, span=16.0,
    beta=None, timing_model="serial", cost_model=DEFAULT_COST_MODEL,
    w_area=0.1, w_power=0.05, optimize_links=False,
)


def constrained_codesign(
    profiles,
    machines,
    *,
    area_budget: Optional[float] = None,
    power_budget: Optional[float] = None,
    area_envelope: Optional[Mapping[str, float]] = None,
    mode: Optional[str] = None,
    projection: Optional[str] = None,
    steps: Optional[int] = None,
    lr: Optional[float] = None,
    span: Optional[float] = None,
    beta=None,
    beta_ref: int = 0,
    timing_model: Optional[str] = None,
    eps: float = K.IDEAL_EPS,
    cost_model: Optional[CostModel] = None,
    w_area: Optional[float] = None,
    w_power: Optional[float] = None,
    optimize_links: Optional[bool] = None,
    outer_iters: int = 6,
    mu0: float = 10.0,
    mu_growth: float = 4.0,
    spec=None,
) -> CodesignResult:
    """Budgeted ``grad_codesign``: descend J subject to silicon budgets.

    The constraint set is any mix of a scalar ``area_budget``, a scalar
    ``power_budget`` and per-subsystem ``area_envelope`` caps
    (``{"peak_flops": b1, "hbm_bw": b2, ...}``, each bounding
    ``CostModel.subsystem_area``).  ``mode="projected"`` retracts every
    candidate onto the constraint set (see ``project_to_budgets``;
    ``projection="euclidean"`` swaps the uniform log-shift for the true
    per-coordinate Euclidean projection), so the whole trajectory is
    feasible and the violation trace is identically zero.
    ``mode="lagrangian"`` runs ``outer_iters`` rounds of inner descent on
    the augmented objective -- one multiplier PER constraint -- with
    dual/penalty updates in between (``steps`` is split across the
    rounds); iterates may be infeasible mid-run, but the recorded
    per-round violation trace is monotonically damped and a final
    projection makes the returned machines feasible.  ``optimize_links``
    relaxes ``ici_links`` continuously and finishes with
    rounding-with-repair (shift projection only -- the Euclidean path has
    no links column).

    A ``spec=CodesignSpec(...)`` request fills any parameter left unset;
    an explicitly-passed keyword always wins over the spec's field, and
    keyword-only legacy calls are byte-identical to pre-spec behaviour
    (pinned in tests/test_constrained.py).

    Example (tight budget: the optimum must stay at reference-chip area):

    >>> from repro.core import VARIANTS, WorkloadProfile, constrained_codesign
    >>> from repro.core.costmodel import CostModel
    >>> from repro.core.sweep import MachineBatch
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> cd = constrained_codesign(apps, MachineBatch.from_models(VARIANTS),
    ...                           area_budget=1.0, steps=5)
    >>> cd.mode
    'projected'
    >>> bool((cd.area_final <= 1.0 + 1e-9).all())
    True
    >>> bool(cd.feasible.all())
    True

    A per-subsystem envelope is one more constraint per entry -- here no
    machine may provision more than 80% of the reference HBM bandwidth:

    >>> from repro.core.costmodel import DEFAULT_COST_MODEL
    >>> env = constrained_codesign(apps, MachineBatch.from_models(VARIANTS),
    ...                            area_envelope={"hbm_bw": 0.8}, steps=5,
    ...                            projection="euclidean")
    >>> [bool(DEFAULT_COST_MODEL.subsystem_area(m, "hbm_bw")
    ...       <= 0.8 * (1 + 1e-9)) for m in env.models()]
    [True, True, True]
    >>> env.feasibility_report()["area_envelope"]
    {'hbm_bw': 0.8}
    """
    from repro.core.spec import resolve_spec

    r = resolve_spec(spec, _CONSTRAINED_DEFAULTS, dict(
        area_budget=area_budget, power_budget=power_budget,
        area_envelope=area_envelope, mode=mode, projection=projection,
        steps=steps, lr=lr, span=span, beta=beta, timing_model=timing_model,
        cost_model=cost_model, w_area=w_area, w_power=w_power,
        optimize_links=optimize_links))
    area_budget, power_budget = r["area_budget"], r["power_budget"]
    area_envelope, mode, projection = (r["area_envelope"], r["mode"],
                                       r["projection"])
    steps, lr, span, beta = r["steps"], r["lr"], r["span"], r["beta"]
    timing_model, cost_model = r["timing_model"], r["cost_model"]
    w_area, w_power = r["w_area"], r["w_power"]
    optimize_links = r["optimize_links"]

    area_envelope = _validate_budgets(area_budget, power_budget,
                                      area_envelope)
    if mode not in ("projected", "lagrangian"):
        raise ValueError(f"unknown constraint mode {mode!r}; "
                         "have ('projected', 'lagrangian')")
    if projection not in ("shift", "euclidean"):
        raise ValueError(f"unknown projection {projection!r}; "
                         "have ('shift', 'euclidean')")
    if projection == "euclidean" and optimize_links:
        raise ValueError(
            "projection='euclidean' does not compose with optimize_links "
            "(the links column needs the masked shift repair); use the "
            "default projection='shift'")
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    pb, mb = _as_batches(profiles, machines)
    fixed_np = mb.arrays()
    beta_np = resolve_beta(pb, mb, beta, beta_ref)
    theta0, lo, hi = theta_box(mb, span, optimize_links=optimize_links)
    suffix = {"projected": "+proj", "lagrangian": "+lagr"}[mode]

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)

        def objective(theta):
            m = machine_arrays_from_theta(jnp, theta, fixed)
            return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                    eps, cost_model, w_area, w_power)

        def violation(theta):
            m = machine_arrays_from_theta(jnp, theta, fixed)
            return budget_violation(jnp, m, cost_model, area_budget,
                                    power_budget, area_envelope)

        def violations_vec(theta):
            m = machine_arrays_from_theta(jnp, theta, fixed)
            return budget_violations_vector(jnp, m, cost_model, area_budget,
                                            power_budget, area_envelope)

        def project(theta):
            out, _ = project_to_budgets(jnp, theta, lo_j, hi_j, fixed,
                                        cost_model, area_budget, power_budget,
                                        area_envelope=area_envelope,
                                        method=projection)
            return out

        multipliers = constraint_names = None
        if mode == "projected":
            theta, f_cur, history, vtrace, _ = backtracking_descent(
                jax, jnp, backend.asarray(theta0), objective, steps, lr,
                retract=project, aux_fn=violation)
        else:
            theta, history, vtrace, lam_rel = _lagrangian_descent(
                jax, jnp, backend, theta0, lo_j, hi_j, objective, violation,
                violations_vec, steps, lr, outer_iters, mu0, mu_growth)
            # The dual iterates multiply RELATIVE violations
            # (value / budget - 1); report them as ABSOLUTE shadow prices
            # (lam_abs = lam_rel / budget) so they are directly comparable
            # to the implicit-function-theorem sensitivities
            # d J*/d budget = -lambda from repro.core.implicit.
            labels = constraint_labels(area_budget, power_budget,
                                       area_envelope)
            scale = np.array(
                [area_budget if c == "area" else
                 power_budget if c == "power" else area_envelope[c]
                 for c in labels])
            multipliers = np.asarray(lam_rel) / scale[None, :]
            constraint_names = tuple(labels)
            # Safety net: the dual iterates approach feasibility from
            # outside; project the final design so the returned machines
            # honour the budget to FEASIBLE_RTOL exactly like projected
            # mode does.
            theta = project(theta)
            vtrace.append(np.asarray(violation(theta)))
            history.append(np.asarray(objective(theta)))

        theta_np = backend.to_numpy(theta)
        f_final = np.asarray(history[-1])

    feasible = budget_feasible(
        np, machine_arrays_from_theta(np, theta_np, fixed_np), cost_model,
        area_budget, power_budget, area_envelope=area_envelope)

    if optimize_links:
        def obj_np(th):
            m = machine_arrays_from_theta(np, th, fixed_np)
            with np.errstate(divide="ignore", invalid="ignore"):
                return _objective_terms(np, pb.arrays(), m, beta_np,
                                        timing_model, eps, cost_model,
                                        w_area, w_power)
        theta_np, feasible, f_final = _round_links_with_repair(
            theta_np, lo, hi, fixed_np, cost_model, area_budget,
            power_budget, obj_np, area_envelope=area_envelope)
        history.append(np.asarray(f_final))
        vtrace.append(np.asarray(budget_violation(
            np, machine_arrays_from_theta(np, theta_np, fixed_np),
            cost_model, area_budget, power_budget, area_envelope)))

    return _finalize(mb, fixed_np, theta0, theta_np, history, steps, w_area,
                     w_power, cost_model, mode, suffix, area_budget,
                     power_budget, vtrace, feasible, f_final,
                     area_envelope=area_envelope, multipliers=multipliers,
                     constraint_names=constraint_names)


def _lagrangian_descent(jax, jnp, backend, theta0, lo_j, hi_j, objective,
                        violation, violations_vec, steps, lr, outer_iters,
                        mu0, mu_growth):
    """Augmented-Lagrangian outer loop (inner loops share the one descent).

    One multiplier PER constraint (``violations_vec`` columns: scalar
    area, scalar power, then each envelope field), so a binding HBM
    envelope grows its own dual weight without inflating the pressure on
    an easily-satisfied total-area budget.  The violation trace (the max
    over constraints) is damped BY CONSTRUCTION: an outer iterate is
    accepted per variant only when its worst violation does not exceed the
    best seen so far; rejected variants keep their previous theta and get
    a sharply increased penalty weight for the next round.
    """
    v = theta0.shape[0]
    steps_inner = max(1, steps // max(outer_iters, 1))
    theta = jnp.clip(backend.asarray(theta0), lo_j, hi_j)
    n_constraints = int(violations_vec(theta).shape[1])
    lam = jnp.zeros((v, n_constraints))
    mu = jnp.full((v,), float(mu0))
    lr_v = lr
    v_best = violation(theta)
    history = [np.asarray(objective(theta))]
    vtrace = [np.asarray(v_best)]

    # Multipliers enter as TRACED arguments (not fresh closures), and the
    # jit cache is shared across outer rounds: the congruence graph
    # compiles once for the whole Lagrangian run.
    def augmented(th, lam_c, mu_c):
        g = violations_vec(th)  # (V, C) relative violations, already relu'd
        pen = 0.5 / mu_c * jnp.sum(
            jnp.maximum(lam_c + mu_c[:, None] * g, 0.0) ** 2 - lam_c ** 2,
            axis=1)
        return objective(th) + pen

    jit_cache = {}
    for _ in range(outer_iters):
        cand, _, _, _, lr_v = backtracking_descent(
            jax, jnp, theta, augmented, steps_inner, lr_v,
            retract=lambda th: jnp.clip(th, lo_j, hi_j),
            obj_args=(lam, mu), cache=jit_cache)
        v_new = violation(cand)
        ok = v_new <= v_best + 1e-12
        theta = jnp.where(ok[:, None], cand, theta)
        v_best = jnp.minimum(v_new, v_best)
        lam = jnp.maximum(lam + mu[:, None] * violations_vec(theta), 0.0)
        mu = jnp.where(ok, mu * mu_growth, mu * (mu_growth ** 2))
        history.append(np.asarray(objective(theta)))
        vtrace.append(np.asarray(v_best))
    return theta, history, vtrace, np.asarray(lam)


# --------------------------------------------------------------------------- #
# Joint (machine, sharding-variant) descent
# --------------------------------------------------------------------------- #


def _flatten_groups(profile_groups) -> Tuple[list, np.ndarray, list]:
    """Flatten app groups; returns (flat profiles, group ids, group names)."""
    from repro.core.costs import WorkloadProfile

    groups = list(profile_groups)
    if groups and isinstance(groups[0], WorkloadProfile):
        groups = [[p] for p in groups]  # flat list -> singleton groups
    flat, gids = [], []
    for g, members in enumerate(groups):
        members = list(members)
        if not members:
            raise ValueError(f"profile group {g} is empty")
        flat.extend(members)
        gids.extend([g] * len(members))
    return flat, np.asarray(gids, dtype=np.int64), groups


def _hard_weights(agg: np.ndarray, gids: np.ndarray) -> np.ndarray:
    """(A, V) one-hot-per-group selection weights from an aggregate matrix:
    each (group, variant) pair puts weight 1/G on its argmin member."""
    a, v = agg.shape
    n_groups = int(gids.max()) + 1
    w = np.zeros((a, v))
    for g in range(n_groups):
        rows = np.nonzero(gids == g)[0]
        best = rows[np.argmin(agg[rows, :], axis=0)]          # (V,)
        w[best, np.arange(v)] += 1.0 / n_groups
    return w


_JOINT_DEFAULTS = dict(
    mode="alternate", steps=80, lr=0.1, span=16.0, beta=None,
    timing_model="serial", cost_model=DEFAULT_COST_MODEL,
    w_area=0.1, w_power=0.05, area_budget=None, power_budget=None,
)


def joint_codesign(
    profile_groups,
    machines,
    *,
    mode: Optional[str] = None,
    rounds: int = 4,
    steps: Optional[int] = None,
    lr: Optional[float] = None,
    span: Optional[float] = None,
    beta=None,
    beta_ref: int = 0,
    timing_model: Optional[str] = None,
    eps: float = K.IDEAL_EPS,
    cost_model: Optional[CostModel] = None,
    w_area: Optional[float] = None,
    w_power: Optional[float] = None,
    area_budget: Optional[float] = None,
    power_budget: Optional[float] = None,
    temp0: float = 1.0,
    temp_min: float = 0.05,
    spec=None,
) -> CodesignResult:
    """Joint (machine, sharding-variant) descent through the same kernels.

    ``profile_groups`` is a sequence of groups, each a sequence of
    ``WorkloadProfile`` sharding variants of ONE application (a flat list
    of profiles degrades to singleton groups == machine-only descent).
    The objective is the scalarized J with the mean over apps replaced by
    a per-(group, machine-variant) selection over group members:

      * ``mode="alternate"`` -- harden the selection to the per-group
        argmin under the current machine, descend machine log-rates for
        ``steps/rounds`` steps, re-select, repeat.  Re-selection can only
        lower the objective, so the round boundary is monotone.
      * ``mode="softmax"`` -- relax the selection to a per-group softmax
        with learnable logits, descend (log-rates, logits) SIMULTANEOUSLY,
        annealing the temperature geometrically from ``temp0`` to
        ``temp_min`` across rounds.

    Both modes finish with a hard selection plus one machine-only polish
    round under it, and report the chosen member per (machine variant,
    group) in ``selection_names``.  Budgets (optional) apply through the
    projected retraction, exactly as in ``constrained_codesign``.

    Example (two sharding variants of one app; descent picks per machine):

    >>> from repro.core import VARIANTS, WorkloadProfile, joint_codesign
    >>> from repro.core.sweep import MachineBatch
    >>> base = dict(flops=2e14, hbm_bytes=1.5e11, num_devices=256,
    ...             model_flops=5e16)
    >>> groups = [[WorkloadProfile(name="app0/tp",
    ...                            collective_bytes={"all-reduce": 8e10},
    ...                            **base),
    ...            WorkloadProfile(name="app0/fsdp",
    ...                            collective_bytes={"all-reduce": 1e10},
    ...                            **base)]]
    >>> cd = joint_codesign(groups, MachineBatch.from_models(VARIANTS),
    ...                     rounds=2, steps=6)
    >>> cd.mode
    'joint-alternate'
    >>> [len(sel) for sel in cd.selection_names]   # one pick per group
    [1, 1, 1]
    >>> bool((cd.improvement >= 0).all())
    True
    """
    from repro.core.spec import resolve_spec

    r = resolve_spec(spec, _JOINT_DEFAULTS, dict(
        mode=mode, steps=steps, lr=lr, span=span, beta=beta,
        timing_model=timing_model, cost_model=cost_model, w_area=w_area,
        w_power=w_power, area_budget=area_budget, power_budget=power_budget))
    mode, steps, lr, span, beta = (r["mode"], r["steps"], r["lr"], r["span"],
                                   r["beta"])
    timing_model, cost_model = r["timing_model"], r["cost_model"]
    w_area, w_power = r["w_area"], r["w_power"]
    area_budget, power_budget = r["area_budget"], r["power_budget"]

    if mode not in ("alternate", "softmax"):
        raise ValueError(f"unknown joint mode {mode!r}; "
                         "have ('alternate', 'softmax')")
    if area_budget is not None or power_budget is not None:
        _validate_budgets(area_budget, power_budget)
    backend = K.get_backend("jax")
    jax, jnp = backend._jax, backend._jnp

    flat, gids, groups = _flatten_groups(profile_groups)
    n_groups = len(groups)
    pb, mb = _as_batches(flat, machines)
    fixed_np = mb.arrays()
    # Beta is a per-APPLICATION target: every sharding variant of a group
    # chases the same target (derived from the group's member 0 by default),
    # and an explicit beta has group length, not flattened length.
    first_rows = np.array([int(np.nonzero(gids == g)[0][0])
                           for g in range(n_groups)])
    if beta is None:
        beta_np = resolve_beta(pb, mb, None, beta_ref)[first_rows][gids]
    else:
        beta_np = np.broadcast_to(
            np.asarray(beta, dtype=np.float64), (n_groups,))[gids]
    theta0, lo, hi = theta_box(mb, span)
    n_rates = theta0.shape[1]
    a_total, v = len(pb), len(mb)
    # Per-group one-hot membership matrix for segment softmax: (A, G).
    member = np.zeros((a_total, n_groups))
    member[np.arange(a_total), gids] = 1.0
    constrained = area_budget is not None or power_budget is not None

    with backend._x64():
        p_arrays = backend.profile_arrays(pb.arrays())
        fixed = backend.machine_arrays(fixed_np)
        beta_j = backend.asarray(beta_np)
        lo_j, hi_j = backend.asarray(lo), backend.asarray(hi)
        member_j = backend.asarray(member)

        def retract_theta(th):
            if constrained:
                out, _ = project_to_budgets(
                    jnp, th, lo_j, hi_j, fixed, cost_model, area_budget,
                    power_budget)
                return out
            return jnp.clip(th, lo_j, hi_j)

        def objective_with(th, weights):
            m = machine_arrays_from_theta(jnp, th, fixed)
            return _objective_terms(jnp, p_arrays, m, beta_j, timing_model,
                                    eps, cost_model, w_area, w_power,
                                    app_weights=weights)

        def aggregate_np(th):
            m = machine_arrays_from_theta(jnp, th, fixed)
            out = K.congruence_kernel(jnp, p_arrays, m, beta_j, timing_model,
                                      eps, clamp=False)
            return np.asarray(out.aggregate)

        theta = retract_theta(backend.asarray(theta0))
        w_hard = _hard_weights(aggregate_np(theta), gids)
        obj_seed = np.asarray(objective_with(theta, backend.asarray(w_hard)))
        history: List[np.ndarray] = [obj_seed]
        steps_round = max(1, steps // max(rounds + 1, 1))
        lr_v = lr
        # Best hard-selection iterate so far, per variant: the softmax
        # rounds descend a RELAXED objective, so the hard objective may
        # transiently regress; tracking the incumbent makes the reported
        # result monotone vs the seed by construction.
        best_theta, best_f = theta, jnp.asarray(obj_seed)

        def track_best(theta, f_hard, best_theta, best_f):
            """Keep the incumbent under the (already computed) hard-selection
            objective of this round's boundary."""
            f = jnp.asarray(f_hard)
            better = f < best_f
            return (jnp.where(better[:, None], theta, best_theta),
                    jnp.minimum(f, best_f))

        # Round-varying state (selection weights, softmax temperature)
        # enters as traced arguments with a shared jit cache, so each mode
        # compiles its objective once for the whole run.
        weighted_cache: dict = {}

        if mode == "alternate":
            for _ in range(rounds):
                theta, _, hist, _, lr_v = backtracking_descent(
                    jax, jnp, theta, objective_with,
                    steps_round, lr_v, retract=retract_theta,
                    obj_args=(backend.asarray(w_hard),),
                    cache=weighted_cache)
                history.extend(hist[1:])
                w_hard = _hard_weights(aggregate_np(theta), gids)
                f_bound = np.asarray(
                    objective_with(theta, backend.asarray(w_hard)))
                history.append(f_bound)
                best_theta, best_f = track_best(theta, f_bound,
                                                best_theta, best_f)
        else:
            phi = jnp.zeros((v, a_total))
            temps = np.geomspace(temp0, max(temp_min, 1e-6), max(rounds, 1))

            def retract_params(params):
                return jnp.concatenate(
                    [retract_theta(params[:, :n_rates]), params[:, n_rates:]],
                    axis=1)

            def objective_soft(params, temp):
                th = params[:, :n_rates]
                logits = params[:, n_rates:].T          # (A, V)
                e = jnp.exp(logits / temp)
                denom = member_j @ (member_j.T @ e)     # (A, V) per-group
                weights = e / denom / n_groups
                return objective_with(th, weights)

            soft_cache: dict = {}
            for temp in temps:
                params = jnp.concatenate([theta, phi], axis=1)
                params, _, _, _, lr_v = backtracking_descent(
                    jax, jnp, params, objective_soft, steps_round, lr_v,
                    retract=retract_params,
                    obj_args=(backend.asarray(float(temp)),),
                    cache=soft_cache)
                theta = params[:, :n_rates]
                phi = params[:, n_rates:]
                w_hard = _hard_weights(aggregate_np(theta), gids)
                f_bound = np.asarray(
                    objective_with(theta, backend.asarray(w_hard)))
                history.append(f_bound)
                best_theta, best_f = track_best(theta, f_bound,
                                                best_theta, best_f)

        # Final polish: machine-only descent under the incumbent's hard
        # selection, starting FROM the incumbent (backtracking guarantees
        # it never regresses past it).
        theta = best_theta
        w_hard = _hard_weights(aggregate_np(theta), gids)
        theta, _, hist, _, _ = backtracking_descent(
            jax, jnp, theta, objective_with,
            steps_round, lr_v, retract=retract_theta,
            obj_args=(backend.asarray(w_hard),), cache=weighted_cache)
        history.extend(hist[1:])
        theta_np = backend.to_numpy(theta)
        # Re-select once more at the final machine so the reported
        # objective, the selection and the trajectory tail all agree (the
        # polish may have shifted which member wins; argmin re-selection
        # only ever lowers the objective).
        agg_final = aggregate_np(theta)
        w_hard = _hard_weights(agg_final, gids)
        f_cur = np.asarray(objective_with(theta, backend.asarray(w_hard)))
        history.append(f_cur)

    # Hard per-(variant, group) picks by profile name.
    selection_names = []
    for vi in range(v):
        picks = []
        for g in range(n_groups):
            rows = np.nonzero(gids == g)[0]
            picks.append(pb.names[rows[np.argmin(agg_final[rows, vi])]])
        selection_names.append(picks)

    final_m = machine_arrays_from_theta(np, theta_np, fixed_np)
    feasible = (budget_feasible(np, final_m, cost_model, area_budget,
                                power_budget)
                if constrained else np.ones(v, dtype=bool))
    vtrace = ([np.asarray(budget_violation(np, final_m, cost_model,
                                           area_budget, power_budget))]
              if constrained else None)
    res = _finalize(
        mb, fixed_np, theta0, theta_np, history, steps, w_area, w_power,
        cost_model, f"joint-{mode}", "+joint", area_budget, power_budget,
        vtrace, feasible, np.asarray(f_cur), selection_names=selection_names)
    if not constrained:
        res.feasible = None
        res.area_budget = res.power_budget = None
    return res
