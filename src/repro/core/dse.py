"""Design-space exploration over machine variants (paper §III, Table I).

Given a set of workload profiles (applications) and machine variants
(baseline / denser / densest), compute the aggregate congruence score for
every (application, variant) pair, pick each application's best-fit variant
(lowest aggregate = smallest radar area = best alignment), and report suite
means -- reproducing the structure of the paper's Table I and Fig. 3 on our
TPU workloads.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

from repro.core.congruence import CongruenceReport, profile_congruence
from repro.core.costs import WorkloadProfile
from repro.core.machine import MachineModel, VARIANTS


@dataclasses.dataclass
class DseCell:
    app: str
    variant: str
    report: CongruenceReport

    @property
    def aggregate(self) -> float:
        return self.report.aggregate


@dataclasses.dataclass
class DseTable:
    """Table I analogue: rows = applications, columns = machine variants."""

    cells: List[DseCell]
    suites: Mapping[str, Sequence[str]]  # suite name -> list of app names

    def cell(self, app: str, variant: str) -> DseCell:
        for c in self.cells:
            if c.app == app and c.variant == variant:
                return c
        raise KeyError((app, variant))

    @property
    def apps(self) -> List[str]:
        seen: Dict[str, None] = {}
        for c in self.cells:
            seen.setdefault(c.app, None)
        return list(seen)

    @property
    def variants(self) -> List[str]:
        seen: Dict[str, None] = {}
        for c in self.cells:
            seen.setdefault(c.variant, None)
        return list(seen)

    def best_fit(self, app: str) -> str:
        """Lowest aggregate congruence = best-fit architecture (paper §III-C)."""
        best, best_score = None, float("inf")
        for c in self.cells:
            if c.app == app and c.aggregate < best_score:
                best, best_score = c.variant, c.aggregate
        assert best is not None
        return best

    def suite_mean(self, suite: str, variant: str) -> float:
        apps = set(self.suites[suite])
        vals = [c.aggregate for c in self.cells if c.variant == variant and c.app in apps]
        return sum(vals) / len(vals) if vals else float("nan")

    def suite_best_fit(self, suite: str) -> str:
        return min(self.variants, key=lambda v: self.suite_mean(suite, v))

    def aggregate_mean(self, variant: str) -> float:
        vals = [c.aggregate for c in self.cells if c.variant == variant]
        return sum(vals) / len(vals) if vals else float("nan")

    def overall_best_fit(self) -> str:
        return min(self.variants, key=self.aggregate_mean)

    # ------------------------------------------------------------------ #

    def markdown(self) -> str:
        variants = self.variants
        lines = ["| application | " + " | ".join(variants) + " | best fit |",
                 "|---" * (len(variants) + 2) + "|"]
        for suite, suite_apps in self.suites.items():
            lines.append(f"| **{suite}** |" + " |" * (len(variants) + 1))
            for app in suite_apps:
                row = [f"| {app} "]
                for v in variants:
                    try:
                        row.append(f"| {self.cell(app, v).aggregate:.3f} ")
                    except KeyError:
                        row.append("| - ")
                row.append(f"| {self.best_fit(app)} |")
                lines.append("".join(row))
            means = " ".join(f"| {self.suite_mean(suite, v):.3f}" for v in variants)
            lines.append(
                f"| *{suite} mean* {means} | {self.suite_best_fit(suite)} |"
            )
        means = " ".join(f"| {self.aggregate_mean(v):.3f}" for v in variants)
        lines.append(f"| **aggregate** {means} | {self.overall_best_fit()} |")
        return "\n".join(lines)

    def radar_markdown(self) -> str:
        """Fig. 3 analogue: per-app ICS/HRCS/LBCS triplets per variant."""
        variants = self.variants
        header = "| application |" + "".join(
            f" {v} ICS | {v} HRCS | {v} LBCS |" for v in variants
        )
        lines = [header, "|---" * (1 + 3 * len(variants)) + "|"]
        for app in self.apps:
            row = [f"| {app} "]
            for v in variants:
                try:
                    r = self.cell(app, v).report
                    row.append(f"| {r.ics:.3f} | {r.hrcs:.3f} | {r.lbcs:.3f} ")
                except KeyError:
                    row.append("| - | - | - ")
            lines.append("".join(row) + "|")
        return "\n".join(lines)


def evaluate(
    profiles: Iterable[WorkloadProfile],
    *,
    variants: Sequence[MachineModel] = VARIANTS,
    suites: Optional[Mapping[str, Sequence[str]]] = None,
    timing_model: str = "serial",
    beta: Optional[float] = None,
    clamp: bool = True,
) -> DseTable:
    """Score every (application x variant) cell.

    The expensive compile happened once per profile; this sweep is pure
    arithmetic -- the paper's lightweight DSE loop.
    """
    profiles = list(profiles)
    if suites is None:
        suites = {"all": [p.name for p in profiles]}
    cells: List[DseCell] = []
    for p in profiles:
        # Paper semantics: beta is a USER-DEFINED target per application,
        # held constant across architecture variants (Table I compares
        # variants against the same target).  Default: derived once from the
        # baseline (first) variant.
        from repro.core.congruence import default_beta

        app_beta = beta if beta is not None else default_beta(p, variants[0])
        for m in variants:
            rep = profile_congruence(
                p, m, timing_model=timing_model, beta=app_beta, clamp=clamp
            )
            cells.append(DseCell(app=p.name, variant=m.name, report=rep))
    return DseTable(cells=cells, suites=dict(suites))
