"""Design-space exploration over machine variants (paper §III, Table I).

Given a set of workload profiles (applications) and machine variants
(baseline / denser / densest, or thousands of generated designs), compute the
aggregate congruence score for every (application, variant) pair, pick each
application's best-fit variant (lowest aggregate = smallest radar area = best
alignment), and report suite means -- reproducing the structure of the
paper's Table I and Fig. 3 on our TPU workloads.

Two execution paths share one table interface:

  * ``method="batched"`` (default) delegates the whole cross-product to the
    vectorized kernels in ``repro.core.sweep`` and returns a
    ``LazyDseTable`` that materializes full ``DseCell`` reports only for
    the cells a caller actually asks for -- the fast path that makes
    1000-variant sweeps as cheap as the paper's 3-variant Table I.
  * ``method="scalar"`` is the original per-cell reference loop, kept as the
    equivalence oracle (tests assert batched == scalar to ~1e-9).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core.congruence import (
    CongruenceReport,
    SCORE_NAMES,
    default_beta,
    extended_decomposition,
    profile_congruence,
)
from repro.core.costs import WorkloadProfile
from repro.core.machine import ALL_SUBSYSTEMS, VARIANTS
from repro.core.timing import subsystem_times


@dataclasses.dataclass
class DseCell:
    app: str
    variant: str
    report: CongruenceReport

    @property
    def aggregate(self) -> float:
        return self.report.aggregate


def _top_variants(table, top_k: Optional[int]) -> List[str]:
    """Variant columns to report: all, or the best ``top_k`` by suite mean."""
    variants = table.variants
    if top_k is None:
        return variants
    return sorted(variants, key=table.aggregate_mean)[:top_k]


def _table_json(table, top_k: Optional[int]) -> dict:
    """JSON rendering shared by the eager and lazy tables (uniform result
    protocol: every result type exposes ``to_json(top_k=...)``)."""
    variants = _top_variants(table, top_k)
    scores = {}
    for app in table.apps:
        scores[app] = {}
        for v in variants:
            trip = table._triplet(app, v)
            if trip is not None:
                scores[app][v] = {"ICS": trip[0], "HRCS": trip[1],
                                  "LBCS": trip[2]}
    return {
        "apps": table.apps,
        "variants": variants,
        "suites": {s: list(apps) for s, apps in table.suites.items()},
        "aggregate": {app: {v: table._aggregate(app, v) for v in variants}
                      for app in table.apps},
        "scores": scores,
        "best_fit": {app: table.best_fit(app) for app in table.apps},
        "suite_mean": {s: {v: table.suite_mean(s, v) for v in variants}
                       for s in table.suites},
        "aggregate_mean": {v: table.aggregate_mean(v) for v in variants},
        "overall_best_fit": table.overall_best_fit(),
    }


def _table_markdown(table, variants=None) -> str:
    """Table I rendering shared by the eager and lazy tables.

    ``table`` provides ``variants``, ``suites``, ``best_fit``,
    ``suite_mean``, ``suite_best_fit``, ``aggregate_mean``,
    ``overall_best_fit`` and ``_aggregate(app, variant) -> Optional[float]``.
    """
    variants = table.variants if variants is None else variants
    lines = ["| application | " + " | ".join(variants) + " | best fit |",
             "|---" * (len(variants) + 2) + "|"]
    for suite, suite_apps in table.suites.items():
        lines.append(f"| **{suite}** |" + " |" * (len(variants) + 1))
        for app in suite_apps:
            row = [f"| {app} "]
            for v in variants:
                agg = table._aggregate(app, v)
                row.append("| - " if agg is None else f"| {agg:.3f} ")
            row.append(f"| {table.best_fit(app)} |")
            lines.append("".join(row))
        means = " ".join(f"| {table.suite_mean(suite, v):.3f}"
                         for v in variants)
        lines.append(
            f"| *{suite} mean* {means} | {table.suite_best_fit(suite)} |"
        )
    means = " ".join(f"| {table.aggregate_mean(v):.3f}" for v in variants)
    lines.append(f"| **aggregate** {means} | {table.overall_best_fit()} |")
    return "\n".join(lines)


def _radar_markdown(table) -> str:
    """Fig. 3 rendering shared by the eager and lazy tables.

    ``table`` additionally provides ``apps`` and
    ``_triplet(app, variant) -> Optional[(ics, hrcs, lbcs)]``.
    """
    variants = table.variants
    header = "| application |" + "".join(
        f" {v} ICS | {v} HRCS | {v} LBCS |" for v in variants
    )
    lines = [header, "|---" * (1 + 3 * len(variants)) + "|"]
    for app in table.apps:
        row = [f"| {app} "]
        for v in variants:
            trip = table._triplet(app, v)
            if trip is None:
                row.append("| - | - | - ")
            else:
                ics, hrcs, lbcs = trip
                row.append(f"| {ics:.3f} | {hrcs:.3f} | {lbcs:.3f} ")
        lines.append("".join(row) + "|")
    return "\n".join(lines)


@dataclasses.dataclass
class DseTable:
    """Table I analogue: rows = applications, columns = machine variants."""

    cells: List[DseCell]
    suites: Mapping[str, Sequence[str]]  # suite name -> list of app names

    def cell(self, app: str, variant: str) -> DseCell:
        for c in self.cells:
            if c.app == app and c.variant == variant:
                return c
        raise KeyError((app, variant))

    @property
    def apps(self) -> List[str]:
        seen: Dict[str, None] = {}
        for c in self.cells:
            seen.setdefault(c.app, None)
        return list(seen)

    @property
    def variants(self) -> List[str]:
        seen: Dict[str, None] = {}
        for c in self.cells:
            seen.setdefault(c.variant, None)
        return list(seen)

    def best_fit(self, app: str) -> str:
        """Lowest aggregate congruence = best-fit architecture (paper §III-C)."""
        best, best_score = None, float("inf")
        for c in self.cells:
            if c.app == app and c.aggregate < best_score:
                best, best_score = c.variant, c.aggregate
        assert best is not None
        return best

    def suite_mean(self, suite: str, variant: str) -> float:
        apps = set(self.suites[suite])
        vals = [c.aggregate for c in self.cells if c.variant == variant and c.app in apps]
        return sum(vals) / len(vals) if vals else float("nan")

    def suite_best_fit(self, suite: str) -> str:
        return min(self.variants, key=lambda v: self.suite_mean(suite, v))

    def aggregate_mean(self, variant: str) -> float:
        vals = [c.aggregate for c in self.cells if c.variant == variant]
        return sum(vals) / len(vals) if vals else float("nan")

    def overall_best_fit(self) -> str:
        return min(self.variants, key=self.aggregate_mean)

    # ------------------------------------------------------------------ #

    def _aggregate(self, app: str, variant: str) -> Optional[float]:
        try:
            return self.cell(app, variant).aggregate
        except KeyError:
            return None

    def _triplet(self, app: str, variant: str) -> Optional[Tuple[float, float, float]]:
        try:
            r = self.cell(app, variant).report
        except KeyError:
            return None
        return (r.ics, r.hrcs, r.lbcs)

    def markdown(self, top_k: Optional[int] = None) -> str:
        """Table I markdown; ``top_k`` keeps only the best variant columns."""
        return _table_markdown(self, _top_variants(self, top_k))

    def to_json(self, top_k: Optional[int] = None) -> dict:
        """JSON-serializable table summary (uniform result protocol)."""
        return _table_json(self, top_k)

    def radar_markdown(self) -> str:
        """Fig. 3 analogue: per-app ICS/HRCS/LBCS triplets per variant."""
        return _radar_markdown(self)


class LazyDseTable:
    """``DseTable`` interface backed by a batched ``SweepResult``.

    All aggregate queries (best fits, suite means, markdown) read the score
    arrays directly; full ``CongruenceReport`` objects -- including the
    per-component extended decomposition, which is inherently per-cell --
    are materialized only when ``cell()`` is called, and cached.  This is
    what keeps 10k-variant sweeps cheap: the O(A*V) work is vectorized and
    the O(1) cells a caller inspects pay the scalar cost.
    """

    def __init__(self, result, suites: Mapping[str, Sequence[str]]):
        self.result = result
        self.suites: Dict[str, Sequence[str]] = dict(suites)
        self._cell_cache: Dict[Tuple[str, str], DseCell] = {}
        self._app_idx = {name: i for i, name in
                         reversed(list(enumerate(result.profiles.names)))}
        self._var_idx = {name: i for i, name in
                         reversed(list(enumerate(result.machines.names)))}

    # ------------------------------ lookups --------------------------- #

    @property
    def apps(self) -> List[str]:
        seen: Dict[str, None] = {}
        for name in self.result.profiles.names:
            seen.setdefault(name, None)
        return list(seen)

    @property
    def variants(self) -> List[str]:
        seen: Dict[str, None] = {}
        for name in self.result.machines.names:
            seen.setdefault(name, None)
        return list(seen)

    def _indices(self, app: str, variant: str) -> Tuple[int, int]:
        if app not in self._app_idx or variant not in self._var_idx:
            raise KeyError((app, variant))
        return self._app_idx[app], self._var_idx[variant]

    def cell(self, app: str, variant: str) -> DseCell:
        """Materialize one full cell (report + extended decomposition)."""
        key = (app, variant)
        if key not in self._cell_cache:
            a, v = self._indices(app, variant)
            self._cell_cache[key] = DseCell(
                app=app, variant=variant, report=self._report(a, v))
        return self._cell_cache[key]

    @property
    def cells(self) -> List[DseCell]:
        """Materialize the full cross-product (expensive for huge sweeps)."""
        return [self.cell(app, v)
                for app in self.result.profiles.names
                for v in self.result.machines.names]

    def _report(self, a: int, v: int) -> CongruenceReport:
        res = self.result
        profile = res.profiles.profiles[a]
        machine = res.machines.model(v)
        gamma = float(res.gamma[a, v])
        beta = float(res.beta[a])
        alphas = {s.value: float(res.alphas[s.value][a, v])
                  for s in ALL_SUBSYSTEMS}
        scores = {SCORE_NAMES[s]: float(res.scores[SCORE_NAMES[s]][a, v])
                  for s in ALL_SUBSYSTEMS}
        baseline = subsystem_times(profile, machine)
        extended = extended_decomposition(
            profile, machine, gamma=gamma, beta=beta,
            timing_model=res.timing_model, eps=res.eps, clamp=res.clamp,
            times=baseline)
        return CongruenceReport(
            name=profile.name,
            machine=machine.name,
            timing_model=res.timing_model,
            gamma=gamma,
            beta=beta,
            alphas=alphas,
            scores=scores,
            extended=extended,
            baseline=baseline,
        )

    # --------------------------- aggregates --------------------------- #

    def best_fit(self, app: str) -> str:
        return self.result.best_fit(app)

    def suite_mean(self, suite: str, variant: str) -> float:
        apps = set(self.suites[suite])
        rows = [i for i, name in enumerate(self.result.profiles.names)
                if name in apps]
        if not rows or variant not in self._var_idx:
            return float("nan")
        col = self._var_idx[variant]
        return float(self.result.aggregate[rows, col].mean())

    def suite_best_fit(self, suite: str) -> str:
        return min(self.variants, key=lambda v: self.suite_mean(suite, v))

    def aggregate_mean(self, variant: str) -> float:
        if variant not in self._var_idx:
            return float("nan")
        return float(self.result.aggregate[:, self._var_idx[variant]].mean())

    def overall_best_fit(self) -> str:
        return min(self.variants, key=self.aggregate_mean)

    # ----------------------------- reports ---------------------------- #

    def _aggregate(self, app: str, variant: str) -> Optional[float]:
        try:
            a, v = self._indices(app, variant)
        except KeyError:
            return None
        return float(self.result.aggregate[a, v])

    def _triplet(self, app: str, variant: str) -> Optional[Tuple[float, float, float]]:
        try:
            a, v = self._indices(app, variant)
        except KeyError:
            return None
        s = self.result.scores
        return (float(s["ICS"][a, v]), float(s["HRCS"][a, v]),
                float(s["LBCS"][a, v]))

    def markdown(self, top_k: Optional[int] = None) -> str:
        """Table I markdown; ``top_k`` keeps only the best variant columns."""
        return _table_markdown(self, _top_variants(self, top_k))

    def to_json(self, top_k: Optional[int] = None) -> dict:
        """JSON-serializable table summary (uniform result protocol)."""
        return _table_json(self, top_k)

    def radar_markdown(self) -> str:
        return _radar_markdown(self)


def evaluate(
    profiles: Iterable[WorkloadProfile],
    *,
    variants=VARIANTS,
    suites: Optional[Mapping[str, Sequence[str]]] = None,
    timing_model: str = "serial",
    beta: Optional[float] = None,
    clamp: bool = True,
    method: str = "auto",
    backend: Optional[str] = None,
):
    """Score every (application x variant) cell.

    The expensive compile happened once per profile; this sweep is pure
    arithmetic -- the paper's lightweight DSE loop.

    ``variants`` accepts either a sequence of ``MachineModel`` or a packed
    ``sweep.MachineBatch`` (e.g. from ``ParamSpace.sample``).  ``method``
    selects the execution path: ``"batched"`` (vectorized, returns a
    ``LazyDseTable``), ``"scalar"`` (reference per-cell loop, returns an
    eager ``DseTable``), or ``"auto"`` (batched).  Both paths run the SAME
    ``kernels_xp`` math (scalar = batch of size 1) and expose the same
    table interface.  ``backend`` picks the kernel backend for the batched
    path (``"numpy"``/``"jax"``/``"pallas"``; default resolves
    $REPRO_SWEEP_BACKEND).

    Example (synthetic profile against the paper's three named variants):

    >>> from repro.core import WorkloadProfile, evaluate
    >>> apps = [WorkloadProfile(name="app0", flops=2e14, hbm_bytes=1.5e11,
    ...                         collective_bytes={"all-reduce": 2e10},
    ...                         num_devices=256, model_flops=5e16)]
    >>> table = evaluate(apps)          # batched path, LazyDseTable
    >>> table.variants
    ['baseline', 'denser', 'densest']
    >>> table.best_fit("app0") in table.variants
    True
    >>> cell = table.cell("app0", "baseline")   # full report, lazily
    >>> cell.aggregate == table._aggregate("app0", "baseline")
    True
    """
    from repro.core.sweep import MachineBatch, batched_congruence

    profiles = list(profiles)
    if suites is None:
        suites = {"all": [p.name for p in profiles]}
    if method == "auto":
        method = "batched"

    if method == "batched":
        machines = (variants if isinstance(variants, MachineBatch)
                    else MachineBatch.from_models(list(variants)))
        result = batched_congruence(
            profiles, machines, beta=beta, beta_ref=0,
            timing_model=timing_model, clamp=clamp, backend=backend)
        return LazyDseTable(result, dict(suites))

    if method != "scalar":
        raise ValueError(f"unknown evaluate method {method!r}")

    models = (variants.models() if isinstance(variants, MachineBatch)
              else list(variants))
    cells: List[DseCell] = []
    for p in profiles:
        # Paper semantics: beta is a USER-DEFINED target per application,
        # held constant across architecture variants (Table I compares
        # variants against the same target).  Default: derived once from the
        # baseline (first) variant.
        app_beta = beta if beta is not None else default_beta(p, models[0])
        for m in models:
            rep = profile_congruence(
                p, m, timing_model=timing_model, beta=app_beta, clamp=clamp
            )
            cells.append(DseCell(app=p.name, variant=m.name, report=rep))
    return DseTable(cells=cells, suites=dict(suites))
