"""Step-time estimation -- the "timing analysis" stage of the paper's flow.

VPR re-runs *only* static timing on the fixed routed netlist when subsystem
delays change.  Our analogue: evaluate a closed-form machine model over the
fixed ``WorkloadProfile`` extracted from the compiled HLO.  Changing machine
constants (including per-subsystem idealization) never triggers recompilation,
which is what makes congruence profiling lightweight.

Two timing models (DESIGN.md §2, adaptation note 1):
  * ``serial``  -- t = t_compute + t_memory + t_interconnect.  Matches the
    paper's critical-path semantics, where zeroing a subsystem removes its
    full contribution.  Default for congruence scores.
  * ``overlap`` -- t = max(terms), the Roofline ideal with perfect
    compute/comm overlap.  Used for optimistic bounds in the DSE tables.

The roofline arithmetic itself lives in ``repro.core.kernels_xp`` (one
backend-agnostic copy shared with the batched sweep engine); this module is
the scalar adapter -- it packs one (profile, machine) pair as a batch of
size 1 and unpacks floats.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import kernels_xp as K
from repro.core.costs import WorkloadProfile
from repro.core.machine import ALL_SUBSYSTEMS, MachineModel, Subsystem

TIMING_MODELS = ("serial", "overlap")


def profile_arrays(profile: WorkloadProfile) -> K.ProfileArrays:
    """Pack one profile as a batch-of-1 ``ProfileArrays`` (the scalar path's
    ``hbm_bytes``-else-``bytes_accessed`` fallback applied here)."""
    mem = profile.hbm_bytes if profile.hbm_bytes > 0 else profile.bytes_accessed
    arr = lambda v: np.asarray([v], dtype=np.float64)
    return K.ProfileArrays(
        flops=arr(profile.flops),
        mem_bytes=arr(mem),
        collective_bytes=arr(profile.total_collective_bytes),
        pod_collective_bytes=arr(profile.pod_collective_bytes),
        model_flops=arr(profile.model_flops),
        num_devices=arr(profile.num_devices),
    )


def machine_arrays(machine: MachineModel) -> K.MachineArrays:
    """Pack one machine model as a batch-of-1 ``MachineArrays``."""
    arr = lambda v: np.asarray([v], dtype=np.float64)
    return K.MachineArrays(
        peak_flops=arr(machine.peak_flops),
        hbm_bw=arr(machine.hbm_bw),
        ici_bw=arr(machine.ici_bw),
        ici_links=arr(machine.ici_links),
        inter_pod_bw=arr(machine.inter_pod_bw),
        scale_compute=arr(machine.scale_for(Subsystem.COMPUTE)),
        scale_memory=arr(machine.scale_for(Subsystem.MEMORY)),
        scale_interconnect=arr(machine.scale_for(Subsystem.INTERCONNECT)),
    )


@dataclasses.dataclass(frozen=True)
class TimingBreakdown:
    """Per-subsystem time (seconds) plus the combined estimate."""

    compute: float
    memory: float
    interconnect: float
    total_serial: float
    total_overlap: float

    def term(self, subsystem: Subsystem) -> float:
        return {
            Subsystem.COMPUTE: self.compute,
            Subsystem.MEMORY: self.memory,
            Subsystem.INTERCONNECT: self.interconnect,
        }[subsystem]

    def total(self, model: str = "serial") -> float:
        if model == "serial":
            return self.total_serial
        if model == "overlap":
            return self.total_overlap
        raise ValueError(f"unknown timing model {model!r}; have {TIMING_MODELS}")

    @property
    def dominant(self) -> Subsystem:
        return max(ALL_SUBSYSTEMS, key=self.term)

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute,
            "memory_s": self.memory,
            "interconnect_s": self.interconnect,
            "serial_s": self.total_serial,
            "overlap_s": self.total_overlap,
        }


def subsystem_times(profile: WorkloadProfile, machine: MachineModel) -> TimingBreakdown:
    """The three roofline terms under ``machine``'s (possibly idealized)
    scales -- the shared ``kernels_xp`` math at batch size 1."""
    with np.errstate(divide="ignore", invalid="ignore"):
        tc, tm, ti = K.scaled_times(
            np, profile_arrays(profile), machine_arrays(machine))
    t_compute = float(tc[0, 0])
    t_memory = float(tm[0, 0])
    t_interconnect = float(ti[0, 0])
    return TimingBreakdown(
        compute=t_compute,
        memory=t_memory,
        interconnect=t_interconnect,
        total_serial=t_compute + t_memory + t_interconnect,
        total_overlap=max(t_compute, t_memory, t_interconnect),
    )


def step_time(
    profile: WorkloadProfile, machine: MachineModel, model: str = "serial"
) -> float:
    """Estimated step time in seconds (the paper's γ / α depending on scales)."""
    return subsystem_times(profile, machine).total(model)
