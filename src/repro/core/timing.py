"""Step-time estimation -- the "timing analysis" stage of the paper's flow.

VPR re-runs *only* static timing on the fixed routed netlist when subsystem
delays change.  Our analogue: evaluate a closed-form machine model over the
fixed ``WorkloadProfile`` extracted from the compiled HLO.  Changing machine
constants (including per-subsystem idealization) never triggers recompilation,
which is what makes congruence profiling lightweight.

Two timing models (DESIGN.md §2, adaptation note 1):
  * ``serial``  -- t = t_compute + t_memory + t_interconnect.  Matches the
    paper's critical-path semantics, where zeroing a subsystem removes its
    full contribution.  Default for congruence scores.
  * ``overlap`` -- t = max(terms), the Roofline ideal with perfect
    compute/comm overlap.  Used for optimistic bounds in the DSE tables.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.costs import WorkloadProfile
from repro.core.machine import ALL_SUBSYSTEMS, MachineModel, Subsystem

TIMING_MODELS = ("serial", "overlap")


@dataclasses.dataclass(frozen=True)
class TimingBreakdown:
    """Per-subsystem time (seconds) plus the combined estimate."""

    compute: float
    memory: float
    interconnect: float
    total_serial: float
    total_overlap: float

    def term(self, subsystem: Subsystem) -> float:
        return {
            Subsystem.COMPUTE: self.compute,
            Subsystem.MEMORY: self.memory,
            Subsystem.INTERCONNECT: self.interconnect,
        }[subsystem]

    def total(self, model: str = "serial") -> float:
        if model == "serial":
            return self.total_serial
        if model == "overlap":
            return self.total_overlap
        raise ValueError(f"unknown timing model {model!r}; have {TIMING_MODELS}")

    @property
    def dominant(self) -> Subsystem:
        return max(ALL_SUBSYSTEMS, key=self.term)

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute,
            "memory_s": self.memory,
            "interconnect_s": self.interconnect,
            "serial_s": self.total_serial,
            "overlap_s": self.total_overlap,
        }


def subsystem_times(profile: WorkloadProfile, machine: MachineModel) -> TimingBreakdown:
    """The three roofline terms under ``machine``'s (possibly idealized) scales.

    compute      = per-device HLO FLOPs / peak FLOP/s
    memory       = per-device HLO bytes / HBM BW
    interconnect = per-device collective bytes / ICI BW, with traffic that
                   crosses the pod axis charged at the slower inter-pod rate.
    """
    s_c = machine.scale_for(Subsystem.COMPUTE)
    s_m = machine.scale_for(Subsystem.MEMORY)
    s_i = machine.scale_for(Subsystem.INTERCONNECT)

    t_compute = s_c * profile.flops / machine.peak_flops
    mem_bytes = profile.hbm_bytes if profile.hbm_bytes > 0 else profile.bytes_accessed
    t_memory = s_m * mem_bytes / machine.hbm_bw

    ici_bytes = profile.total_collective_bytes - profile.pod_collective_bytes
    t_ici = ici_bytes / machine.ici_bw_total
    t_pod = (
        profile.pod_collective_bytes / machine.inter_pod_bw
        if profile.pod_collective_bytes
        else 0.0
    )
    t_interconnect = s_i * (t_ici + t_pod)

    total_serial = t_compute + t_memory + t_interconnect
    total_overlap = max(t_compute, t_memory, t_interconnect)
    return TimingBreakdown(
        compute=t_compute,
        memory=t_memory,
        interconnect=t_interconnect,
        total_serial=total_serial,
        total_overlap=total_overlap,
    )


def step_time(
    profile: WorkloadProfile, machine: MachineModel, model: str = "serial"
) -> float:
    """Estimated step time in seconds (the paper's γ / α depending on scales)."""
    return subsystem_times(profile, machine).total(model)
