"""Silicon cost layer: per-subsystem area weights + a dynamic-power term.

PR 1's area proxy was a hardcoded mean of the four provisioned rates.  This
promotes it into a configurable ``CostModel`` -- the PPA axes the paper
trades congruence against when raising DSP/BRAM density (§I) -- so sweeps
can rank variants on a *three*-objective front: (aggregate congruence,
area, power).

Both estimators are deliberately coarse, first-order proxies (this is
*early* design exploration -- the paper's whole premise is ranking designs
before committing to implementation):

  area(m)  = sum_i w_i * rate_i / ref_rate_i          (weights sum to 1)
  power(m) = static + sum_i p_i * (rate_i / ref_rate_i) ** e_i

Area is linear in provisioned throughput (more MXUs / HBM stacks / SerDes
lanes).  Power is superlinear for compute (e = 1.5 by default: rate gains
come partly from frequency/voltage, which cost ~f*V^2) and linear for the
bandwidth subsystems (mostly more parallel lanes at constant clock).  Delay
``scale`` factors model degradation, not provisioned resources, so they
enter neither estimator.

Every method is plain arithmetic on duck-typed rate fields, so it accepts a
``sweep.MachineBatch``, a ``kernels_xp.MachineArrays`` (NumPy *or* traced
JAX -- the gradient co-design mode differentiates straight through it), or
a scalar ``MachineModel``.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.machine import MachineModel, TPU_V5E

#: The provisioned rates that enter the cost model, in canonical order.
#: Every accepted machine type (MachineModel, MachineBatch, MachineArrays)
#: exposes all four as attributes, ici_bw_total included.
RATE_FIELDS = ("peak_flops", "hbm_bw", "ici_bw_total", "inter_pod_bw")


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Relative silicon area + dynamic power estimators vs a reference chip.

    ``area_weights`` are normalized to sum to 1 at evaluation time; the
    default equal split reproduces PR 1's four-rate-mean proxy exactly, so
    existing sweeps and Pareto fronts are unchanged.

    Example -- the reference chip costs 1.0 area and ``1.0 + static_power``
    power by construction; reweighting changes variant rankings:

    >>> from repro.core import CostModel, TPU_V5E
    >>> cm = CostModel()
    >>> round(float(cm.area(TPU_V5E)), 9)
    1.0
    >>> float(cm.power(TPU_V5E)) == 1.0 + cm.static_power
    True
    >>> compute_heavy = CostModel(area_weights={"peak_flops": 3.0,
    ...                                         "hbm_bw": 1.0})
    >>> denser = TPU_V5E.with_rates(name="2x", peak_flops=2 * TPU_V5E.peak_flops)
    >>> float(compute_heavy.area(denser)) > float(cm.area(denser))
    True
    """

    reference: MachineModel = TPU_V5E
    area_weights: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {f: 1.0 for f in RATE_FIELDS})
    power_weights: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {f: 1.0 for f in RATE_FIELDS})
    power_exponents: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: {"peak_flops": 1.5, "hbm_bw": 1.0,
                                 "ici_bw_total": 1.0, "inter_pod_bw": 1.0})
    static_power: float = 0.1

    def __post_init__(self) -> None:
        for mapping in (self.area_weights, self.power_weights,
                        self.power_exponents):
            for field in mapping:
                if field not in RATE_FIELDS:
                    raise KeyError(
                        f"unknown rate field {field!r}; have {RATE_FIELDS}")
        for name, mapping in (("area_weights", self.area_weights),
                              ("power_weights", self.power_weights)):
            if sum(mapping.get(f, 0.0) for f in RATE_FIELDS) <= 0.0:
                raise ValueError(
                    f"{name} must have a positive total over {RATE_FIELDS}")

    # ------------------------------------------------------------------ #

    def _norms(self, machines):
        """Per-rate throughput normalized to the reference chip."""
        return {f: getattr(machines, f) / getattr(self.reference, f)
                for f in RATE_FIELDS}

    def area(self, machines):
        """Relative silicon/cost proxy (1.0 = the reference chip)."""
        norms = self._norms(machines)
        total_w = sum(self.area_weights.get(f, 0.0) for f in RATE_FIELDS)
        return sum(self.area_weights.get(f, 0.0) * norms[f]
                   for f in RATE_FIELDS) / total_w

    def subsystem_area(self, machines, field: str):
        """One subsystem's relative area: ``rate_field / reference rate``.

        This is the quantity a per-subsystem area *envelope* budgets
        (``constrained_codesign(area_envelope={field: b})`` keeps it
        ``<= b``).  The ``area_weights`` deliberately do not enter: an
        envelope bounds the subsystem's provisioned throughput directly,
        while the weights only say how subsystems aggregate into the one
        scalar die-area proxy.  Consequence: a single-key envelope on
        ``field`` budgets exactly what a scalar ``area_budget`` under
        ``CostModel(area_weights={field: 1.0})`` budgets -- the
        consistency pinned in tests/test_frontier.py.

        >>> from repro.core import CostModel, TPU_V5E
        >>> cm = CostModel()
        >>> float(cm.subsystem_area(TPU_V5E, "peak_flops"))
        1.0
        >>> single = CostModel(area_weights={"hbm_bw": 1.0})
        >>> denser = TPU_V5E.with_rates(name="2x", hbm_bw=2 * TPU_V5E.hbm_bw)
        >>> float(cm.subsystem_area(denser, "hbm_bw")) == float(single.area(denser))
        True
        """
        if field not in RATE_FIELDS:
            raise KeyError(f"unknown rate field {field!r}; have {RATE_FIELDS}")
        return getattr(machines, field) / getattr(self.reference, field)

    def power(self, machines):
        """Relative dynamic power proxy (1.0 + static at the reference)."""
        norms = self._norms(machines)
        total_w = sum(self.power_weights.get(f, 0.0) for f in RATE_FIELDS)
        dyn = sum(self.power_weights.get(f, 0.0)
                  * norms[f] ** self.power_exponents.get(f, 1.0)
                  for f in RATE_FIELDS) / total_w
        return self.static_power + dyn

    def objectives(self, machines):
        """(area, power) pair -- the two silicon axes of the 3-D front."""
        return self.area(machines), self.power(machines)


#: Default model: equal area weights (== PR 1's proxy), DVFS-flavored power.
DEFAULT_COST_MODEL = CostModel()
