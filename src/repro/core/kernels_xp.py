"""Backend-agnostic congruence kernels -- ONE copy of the timing/Eq. 1 math.

Before this module the repo carried two implementations of the paper's
analytic core: the scalar reference in ``timing.py``/``congruence.py`` and
the struct-of-arrays NumPy kernels in ``sweep.py``, kept bit-equal only by
tests.  Here the roofline terms, Eq. 1, the default-beta rule and the L2
aggregate are written once against an array-namespace handle ``xp`` and
evaluated through a registered ``Backend``:

  * ``numpy`` -- eager float64 NumPy; the default, byte-for-byte the old
    behavior.  Scalar callers (``timing.subsystem_times``,
    ``congruence.profile_congruence``) run the same kernels at batch size 1.
  * ``jax``   -- ``jit``-compiled, device-placed ``jax.numpy`` under x64 so
    results match NumPy to ~1e-12.  Because the whole pipeline is traced,
    it is also differentiable end-to-end (``repro.core.codesign`` takes
    ``jax.grad`` through it).

Backend selection: explicit ``backend=`` argument > ``REPRO_SWEEP_BACKEND``
environment variable > ``numpy``.

Data layout: kernels consume ``ProfileArrays`` (shape ``(A,)`` per field)
and ``MachineArrays`` (shape ``(V,)`` per field) namedtuples -- both are
JAX pytrees, so the jitted entry points retrace only on shape changes.
All (A,)x(V,) kernels broadcast to ``(A, V)``.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.machine import IDEAL_EPS

DEFAULT_BACKEND_ENV = "REPRO_SWEEP_BACKEND"


class ProfileArrays(NamedTuple):
    """``A`` workload profiles, one array per field the timing model reads.

    ``mem_bytes`` carries the scalar path's fallback (``hbm_bytes`` when
    positive, else raw ``bytes_accessed``) applied at pack time.
    """

    flops: object
    mem_bytes: object
    collective_bytes: object
    pod_collective_bytes: object
    model_flops: object
    num_devices: object


class MachineArrays(NamedTuple):
    """``V`` machine variants, one array per model constant."""

    peak_flops: object
    hbm_bw: object
    ici_bw: object
    ici_links: object
    inter_pod_bw: object
    scale_compute: object
    scale_memory: object
    scale_interconnect: object

    @property
    def ici_bw_total(self):
        return self.ici_bw * self.ici_links


class CongruenceArrays(NamedTuple):
    """One full congruence pass: everything ``SweepResult`` stores, as
    ``(A, V)`` arrays (``beta`` is the ``(A,)`` per-app target)."""

    gamma: object
    beta: object
    alpha_compute: object
    alpha_memory: object
    alpha_interconnect: object
    lbcs: object
    hrcs: object
    ics: object
    aggregate: object


# --------------------------------------------------------------------------- #
# The kernels (single source of truth for the paper's math)
# --------------------------------------------------------------------------- #


def raw_times(xp, p: ProfileArrays, m: MachineArrays) -> Tuple[object, object, object]:
    """Unscaled per-subsystem roofline terms, each shaped ``(A, V)``.

    compute      = per-device HLO FLOPs / peak FLOP/s
    memory       = per-device HLO bytes / HBM BW
    interconnect = per-device collective bytes / ICI BW, with traffic that
                   crosses the pod axis charged at the slower inter-pod rate.

    The per-subsystem delay scales are factored out so idealization
    (replacing one scale with ``eps``) is a multiply, not a re-evaluation.
    """
    raw_c = p.flops[:, None] / m.peak_flops[None, :]
    raw_m = p.mem_bytes[:, None] / m.hbm_bw[None, :]
    ici_bytes = p.collective_bytes - p.pod_collective_bytes
    t_ici = ici_bytes[:, None] / m.ici_bw_total[None, :]
    pod = p.pod_collective_bytes[:, None]
    t_pod = xp.where(pod != 0.0, pod / m.inter_pod_bw[None, :], 0.0)
    raw_i = t_ici + t_pod
    return raw_c, raw_m, raw_i


def scaled_times(xp, p: ProfileArrays, m: MachineArrays) -> Tuple[object, object, object]:
    """Per-subsystem times under the machine's (possibly idealized) scales."""
    raw_c, raw_m, raw_i = raw_times(xp, p, m)
    return (m.scale_compute[None, :] * raw_c,
            m.scale_memory[None, :] * raw_m,
            m.scale_interconnect[None, :] * raw_i)


def combine(xp, tc, tm, ti, timing_model: str):
    """Fold the three terms into a step time (DESIGN.md §2).

    ``serial``  -- t = tc + tm + ti (paper critical-path semantics).
    ``overlap`` -- t = max(terms), the Roofline ideal.
    """
    if timing_model == "serial":
        return tc + tm + ti
    if timing_model == "overlap":
        return xp.maximum(xp.maximum(tc, tm), ti)
    raise ValueError(f"unknown timing model {timing_model!r}")


def step_time_kernel(xp, p: ProfileArrays, m: MachineArrays,
                     timing_model: str = "serial"):
    """``(A, V)`` step-time matrix."""
    return combine(xp, *scaled_times(xp, p, m), timing_model)


def eq1(xp, alpha, gamma, beta):
    """Paper Eq. 1 over arrays, with the gamma == beta degeneracy -> 0.

        Score_i = 1 - (alpha_i - beta_i) / (gamma_i - beta_i)
    """
    denom = gamma - beta
    safe = xp.where(denom == 0.0, 1.0, denom)
    return xp.where(denom == 0.0, 0.0, 1.0 - (alpha - beta) / safe)


def default_beta_kernel(xp, p: ProfileArrays, m_ref: MachineArrays):
    """Per-app default target beta against reference variant column 0.

    The paper's beta is a user-defined target delay held constant across
    variants; our default is the ideal-compute time (useful model FLOPs at
    full MXU peak), floored at half the reference gamma so Eq. 1 stays
    meaningful, with a 5%-of-gamma fallback when model FLOPs are unknown.
    Always evaluated against the *serial* baseline, matching the scalar
    ``congruence.default_beta``.
    """
    tc, tm, ti = scaled_times(xp, p, m_ref)
    gamma_ref = (tc + tm + ti)[:, 0]
    valid = (p.model_flops > 0) & (p.num_devices > 0)
    denom = xp.where(valid, p.num_devices * m_ref.peak_flops[0], 1.0)
    t_ideal = xp.where(valid, p.model_flops / denom, xp.inf)
    return xp.where(valid, xp.minimum(t_ideal, 0.5 * gamma_ref),
                    0.05 * gamma_ref)


def congruence_kernel(
    xp,
    p: ProfileArrays,
    m: MachineArrays,
    beta,
    timing_model: str = "serial",
    eps: float = IDEAL_EPS,
    clamp: bool = False,
) -> CongruenceArrays:
    """One full congruence pass over the ``(A, V)`` cross-product.

    gamma, the three idealized alphas (each a scale substitution on the
    precomputed raw terms), the Eq. 1 scores and the L2 aggregate (paper
    §III-C: lower = smaller radar area = better fit), in one traceable
    expression.  ``beta`` is the ``(A,)`` per-app target.
    """
    raw = raw_times(xp, p, m)
    scales = (m.scale_compute, m.scale_memory, m.scale_interconnect)
    scaled = tuple(s[None, :] * r for s, r in zip(scales, raw))
    gamma = combine(xp, *scaled, timing_model)
    beta_col = beta[:, None]

    alphas = []
    scores = []
    for k in range(3):
        terms = list(scaled)
        terms[k] = eps * raw[k]
        alpha = combine(xp, *terms, timing_model)
        score = eq1(xp, alpha, gamma, beta_col)
        if clamp:
            score = xp.clip(score, 0.0, 1.0)
        alphas.append(alpha)
        scores.append(score)

    aggregate = xp.sqrt(scores[0] ** 2 + scores[1] ** 2 + scores[2] ** 2)
    return CongruenceArrays(
        gamma=gamma,
        beta=beta,
        alpha_compute=alphas[0],
        alpha_memory=alphas[1],
        alpha_interconnect=alphas[2],
        lbcs=scores[0],
        hrcs=scores[1],
        ics=scores[2],
        aggregate=aggregate,
    )


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #


class Backend:
    """One array-namespace evaluation strategy for the kernels above.

    Subclasses provide ``asarray``/``to_numpy`` conversion and may wrap the
    kernel entry points (jit, device placement, error-state management).
    """

    name: str = "abstract"
    #: True when the backend supports ``jax.grad`` through the kernels.
    differentiable: bool = False

    # -- conversions ---------------------------------------------------- #

    def asarray(self, a):
        raise NotImplementedError

    def to_numpy(self, a) -> np.ndarray:
        raise NotImplementedError

    def profile_arrays(self, p: ProfileArrays) -> ProfileArrays:
        return ProfileArrays(*(self.asarray(f) for f in p))

    def machine_arrays(self, m: MachineArrays) -> MachineArrays:
        return MachineArrays(*(self.asarray(f) for f in m))

    # -- kernel entry points -------------------------------------------- #

    def step_time(self, p: ProfileArrays, m: MachineArrays,
                  timing_model: str = "serial") -> np.ndarray:
        raise NotImplementedError

    def default_beta(self, p: ProfileArrays, m_ref: MachineArrays) -> np.ndarray:
        raise NotImplementedError

    def congruence(self, p: ProfileArrays, m: MachineArrays, beta,
                   timing_model: str = "serial", eps: float = IDEAL_EPS,
                   clamp: bool = False) -> CongruenceArrays:
        """Run the full pass and return *NumPy* ``CongruenceArrays``."""
        raise NotImplementedError

    def sharded_stats(self, p: ProfileArrays, m: MachineArrays, beta, mesh,
                      timing_model: str = "serial", clamp: bool = False,
                      pad_to: Optional[int] = None):
        """Mesh-sharded, gather-free statistics pass over one variant chunk.

        The mega-sweep reduction: score the ``(A, V_chunk)`` cross-product
        with the variant axis split over ``mesh`` and reduce ON-DEVICE to
        the three statistics ``shard_sweep`` merges -- per-variant
        suite-mean aggregates ``(V_chunk,)``, per-app minima ``(A,)`` and
        per-app argmin indices ``(A,)`` (0-based within the chunk).  Only
        those O(V) + O(A) rows ever cross devices; the score tensor stays
        sharded and is discarded.

        ``pad_to`` is a chunk-width hint: implementations pad the variant
        axis up to at least ``pad_to`` (with benign machines, masked out of
        the reductions) so equal-width chunks of a sharded loop share ONE
        compiled artifact instead of retracing per remainder chunk.

        Backends without a distribution strategy return ``None`` --
        ``shard_sweep`` then falls back to the host-chunked loop.  The
        ``jax`` backend shards via ``NamedSharding`` placement; the
        ``pallas`` backend runs its fused kernel under ``jax.shard_map``
        (see ``repro.core.kernels_pallas``).
        """
        return None


class NumpyBackend(Backend):
    """Eager float64 NumPy -- the default and the numerical reference."""

    name = "numpy"

    def asarray(self, a):
        return np.asarray(a, dtype=np.float64)

    def to_numpy(self, a) -> np.ndarray:
        return np.asarray(a)

    def step_time(self, p, m, timing_model="serial"):
        with np.errstate(divide="ignore", invalid="ignore"):
            return step_time_kernel(np, p, m, timing_model)

    def default_beta(self, p, m_ref):
        with np.errstate(divide="ignore", invalid="ignore"):
            return default_beta_kernel(np, p, m_ref)

    def congruence(self, p, m, beta, timing_model="serial",
                   eps=IDEAL_EPS, clamp=False):
        with np.errstate(divide="ignore", invalid="ignore"):
            return congruence_kernel(np, p, m, self.asarray(beta),
                                     timing_model, eps, clamp)


class JaxBackend(Backend):
    """``jax.numpy`` under x64 with jitted entry points.

    Each entry point is compiled once per (shape, static-config) and placed
    on the default device; x64 keeps results within ~1e-12 of the NumPy
    reference (tests pin 1e-6, comfortably met).  The same traced kernels
    power the gradient co-design mode in ``repro.core.codesign``.
    """

    name = "jax"
    differentiable = True

    def __init__(self):
        try:
            import jax
            import jax.numpy as jnp
            from jax.experimental import enable_x64
        except ImportError as exc:  # pragma: no cover - jax is baked in
            raise RuntimeError(
                "backend 'jax' requires jax; install it or use backend='numpy'"
            ) from exc
        self._jax = jax
        self._jnp = jnp
        self._x64 = enable_x64
        self._jit_cache: Dict[str, Callable] = {}

    def asarray(self, a):
        with self._x64():
            return self._jnp.asarray(a, dtype=self._jnp.float64)

    def to_numpy(self, a) -> np.ndarray:
        return np.asarray(a)

    def _jitted(self, key: str, fn: Callable, static: Tuple[str, ...]) -> Callable:
        if key not in self._jit_cache:
            self._jit_cache[key] = self._jax.jit(fn, static_argnames=static)
        return self._jit_cache[key]

    def step_time(self, p, m, timing_model="serial"):
        with self._x64():
            fn = self._jitted(
                "step_time",
                lambda p, m, timing_model: step_time_kernel(
                    self._jnp, p, m, timing_model),
                ("timing_model",))
            out = fn(self.profile_arrays(p), self.machine_arrays(m),
                     timing_model=timing_model)
            return self.to_numpy(out)

    def default_beta(self, p, m_ref):
        with self._x64():
            fn = self._jitted(
                "default_beta",
                lambda p, m: default_beta_kernel(self._jnp, p, m), ())
            return self.to_numpy(
                fn(self.profile_arrays(p), self.machine_arrays(m_ref)))

    def congruence(self, p, m, beta, timing_model="serial",
                   eps=IDEAL_EPS, clamp=False):
        with self._x64():
            fn = self._jitted(
                "congruence",
                lambda p, m, beta, timing_model, eps, clamp: congruence_kernel(
                    self._jnp, p, m, beta, timing_model, eps, clamp),
                ("timing_model", "eps", "clamp"))
            out = fn(self.profile_arrays(p), self.machine_arrays(m),
                     self.asarray(beta), timing_model=timing_model,
                     eps=eps, clamp=clamp)
            return CongruenceArrays(*(self.to_numpy(f) for f in out))

    def sharded_stats(self, p, m, beta, mesh, timing_model="serial",
                      clamp=False, pad_to=None):
        """Shard the variant axis over ``mesh`` via ``NamedSharding``.

        Machine columns are placed split along the mesh axis, profiles and
        beta replicated; the jitted reduction then runs SPMD and only the
        ``(V_chunk,)`` means plus ``(A,)`` min/argmin rows come back to the
        host.  The chunk is padded (all-1.0 machines, masked to ``+inf``
        before the min/argmin) to a multiple of the device count and at
        least ``pad_to`` so every equal-width chunk reuses one executable.
        """
        jax, jnp = self._jax, self._jnp
        from jax.sharding import NamedSharding, PartitionSpec

        axis = mesh.axis_names[0]
        ndev = int(mesh.size)
        v = int(np.asarray(m.peak_flops).shape[0])
        if v == 0:
            return None
        v_pad = max(v, int(pad_to or 0))
        v_pad = -(-v_pad // ndev) * ndev

        with self._x64():
            split = NamedSharding(mesh, PartitionSpec(axis))
            rep = NamedSharding(mesh, PartitionSpec())

            def _col(f):
                arr = np.asarray(f, dtype=np.float64)
                if v_pad != v:
                    arr = np.concatenate([arr, np.ones(v_pad - v)])
                return jax.device_put(jnp.asarray(arr), split)

            m_dev = MachineArrays(*(_col(f) for f in m))
            p_dev = ProfileArrays(
                *(jax.device_put(self.asarray(f), rep) for f in p))
            beta_dev = jax.device_put(self.asarray(beta), rep)

            key = f"sharded_stats/{v}/{v_pad}"
            if key not in self._jit_cache:
                def stats(p, m, beta, timing_model, clamp):
                    out = congruence_kernel(jnp, p, m, beta, timing_model,
                                            clamp=clamp)
                    masked = jnp.where(jnp.arange(v_pad)[None, :] < v,
                                       out.aggregate, jnp.inf)
                    return (out.aggregate.mean(axis=0),
                            masked.min(axis=1),
                            masked.argmin(axis=1))
                self._jit_cache[key] = jax.jit(
                    stats, static_argnames=("timing_model", "clamp"))
            agg, app_min, app_idx = self._jit_cache[key](
                p_dev, m_dev, beta_dev, timing_model=timing_model,
                clamp=clamp)
            return (np.asarray(agg)[:v],
                    np.asarray(app_min),
                    np.asarray(app_idx).astype(np.int64))


_BACKEND_FACTORIES: Dict[str, Callable[[], Backend]] = {
    "numpy": NumpyBackend,
    "jax": JaxBackend,
}
_BACKEND_CACHE: Dict[str, Backend] = {}

#: Backends registered by a module that is only imported on first use, so
#: ``import repro.core`` stays light.  The module's import must call
#: ``register_backend`` under the same name.
_LAZY_BACKENDS: Dict[str, str] = {
    "pallas": "repro.core.kernels_pallas",
}


def register_backend(name: str, factory: Callable[[], Backend]) -> None:
    """Register a new backend factory (see the Pallas-fused path in
    ``repro.core.kernels_pallas`` for the worked example, and
    ``docs/backends.md`` for the contract)."""
    _BACKEND_FACTORIES[name] = factory
    _BACKEND_CACHE.pop(name, None)


def available_backends() -> Tuple[str, ...]:
    """Every selectable backend name, lazily-registered ones included."""
    return tuple(sorted(set(_BACKEND_FACTORIES) | set(_LAZY_BACKENDS)))


def validate_backend_name(name: Optional[str]) -> None:
    """Reject an unknown backend name with a ``ValueError``.

    The registry is open (``register_backend``), so callers can't bake a
    static choices list; every entry point -- CLIs via
    ``validate_backend_arg``, ``CodesignSpec.validate()``, the serving
    front door -- funnels through this one check so a bogus name fails
    with the registry's current contents instead of deep inside
    ``get_backend`` after expensive work.  ``None`` and constructed
    ``Backend`` instances pass (both are valid ``backend=`` values).
    """
    if isinstance(name, Backend) or name is None:
        return
    if name.lower() not in available_backends():
        raise ValueError(f"unknown backend {name!r}; available: "
                         f"{', '.join(available_backends())}")


def validate_backend_arg(parser, name: Optional[str]) -> None:
    """argparse wrapper over ``validate_backend_name``: reject an unknown
    ``--backend`` at parse time with the CLI's usage message."""
    try:
        validate_backend_name(name)
    except ValueError as e:
        parser.error(str(e))


def get_backend(name: Optional[str] = None) -> Backend:
    """Resolve a backend: explicit name > $REPRO_SWEEP_BACKEND > numpy.

    Passing an already-constructed ``Backend`` returns it unchanged, so
    every ``backend=`` parameter accepts either form.
    """
    if isinstance(name, Backend):
        return name
    if name is None:
        name = os.environ.get(DEFAULT_BACKEND_ENV, "") or "numpy"
    name = name.lower()
    if name not in _BACKEND_FACTORIES and name in _LAZY_BACKENDS:
        import importlib

        importlib.import_module(_LAZY_BACKENDS[name])
    if name not in _BACKEND_FACTORIES:
        raise ValueError(
            f"unknown backend {name!r}; have {available_backends()}")
    if name not in _BACKEND_CACHE:
        _BACKEND_CACHE[name] = _BACKEND_FACTORIES[name]()
    return _BACKEND_CACHE[name]
