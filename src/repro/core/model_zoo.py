"""Model-zoo profile suites: the registry's configs as measured workloads.

The sweep/frontier/serving layers score *profiles*; until now the only
profiles available without a manual dry-run were the synthetic trio in
``benchmarks/common.py``.  This module closes the measurement loop: every
config in ``repro.configs`` x scenario in {train, serve-prefill,
serve-decode} x a batch/seq grid (``configs/shapes.zoo_shapes``) is lowered
and compiled through the dry-run extraction path
(``launch/extract.run_cell``) and emitted as a ``WorkloadProfile`` suite
that plugs directly into ``run_sweep`` / ``shard_sweep`` /
``frontier_codesign`` / ``CodesignService``.

Extraction is expensive (a full XLA compile per cell), so profiles are
cached as canonical JSON artifacts keyed by a fingerprint of (config,
shape, extraction version):

  * smoke suite (tiny configs, single host device, compiles anywhere) --
    checked in under ``src/repro/core/zoo_cache/`` and doubling as the
    golden files for ``tests/test_model_zoo.py``;
  * full suite (published configs, 16x16 pod mesh, needs the dry-run's
    fake host devices) -- ``benchmarks/artifacts/zoo/``, regenerated via
    ``python -m repro.core.model_zoo``.

The calibration layer (``calibration_report``) cross-checks the two
step-time code paths on every cell: the batched Eq.1 kernel path
(``sweep.batched_step_time`` -> ``kernels_xp``) against the scalar
roofline path (``roofline.analyze`` -> ``timing``), reporting the
per-cell ratio, dominant-term agreement and worst offenders -- so
congruence scores are anchored to the measured HLO costs, not assumed.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import (
    ShapeSpec,
    ZOO_SCENARIOS,
    scenario_kind,
    zoo_shapes,
)
from repro.core import kernels_xp as K
from repro.core import roofline as R
from repro.core.costs import WorkloadProfile
from repro.core.machine import TPU_V5E, MachineModel
from repro.core.sweep import MachineBatch, ProfileBatch, batched_step_time

#: Bump whenever the extraction math changes shape -- stale caches are
#: detected by fingerprint mismatch and re-extracted (or rejected).
ZOO_EXTRACTION_VERSION = 1

#: Smoke suite: one arch per major family branch (dense attention, SSM),
#: small enough that the fast CI tier recompiles them from scratch.
SMOKE_ARCHS: Tuple[str, ...] = ("chatglm3-6b", "falcon-mamba-7b")

#: Checked-in smoke cache (module-relative: importable from any cwd).
SMOKE_CACHE_DIR = os.path.join(os.path.dirname(__file__), "zoo_cache")

#: Default full-suite cache under the repo's benchmark-artifact tree
#: (anchored to the source tree, like ``benchmarks.common.ART_DIR``, so
#: suite resolution does not depend on the caller's cwd).
FULL_CACHE_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "artifacts", "zoo")

#: Volatile WorkloadProfile fields zeroed/dropped by canonicalization --
#: wall-clock measurements that differ run to run but carry no cost info.
_VOLATILE_META = ("probe_seconds",)


@dataclasses.dataclass(frozen=True)
class ZooCell:
    """One (config, scenario, shape) extraction unit."""

    arch: str
    scenario: str
    shape: ShapeSpec
    smoke: bool

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape.name}"

    @property
    def cache_key(self) -> str:
        return f"{self.arch}__{self.shape.name}"

    @property
    def config(self):
        return get_config(self.arch, smoke=self.smoke)


def zoo_cells(
    archs: Optional[Sequence[str]] = None,
    scenarios: Optional[Sequence[str]] = None,
    *,
    smoke: bool = False,
) -> List[ZooCell]:
    """The zoo grid: every (arch x scenario x shape) cell, in stable order."""
    if archs is None:
        archs = SMOKE_ARCHS if smoke else ARCH_IDS
    scenarios = tuple(scenarios) if scenarios is not None else ZOO_SCENARIOS
    for s in scenarios:
        scenario_kind(s)  # validates the name
    return [
        ZooCell(arch=a, scenario=s, shape=shape, smoke=smoke)
        for a in archs
        for s in scenarios
        for shape in zoo_shapes(s, smoke=smoke)
    ]


# --------------------------------------------------------------------------- #
# Fingerprints + canonical JSON (the golden-file contract)
# --------------------------------------------------------------------------- #


def cell_fingerprint(cell: ZooCell) -> str:
    """Digest of everything that determines a cell's extracted costs.

    Covers the full config (``repr`` of the frozen dataclass is
    deterministic), the shape, the scenario and the extraction version --
    so a cached profile is provably stale the moment any input changes.
    """
    payload = json.dumps(
        {
            "version": ZOO_EXTRACTION_VERSION,
            "arch": cell.arch,
            "scenario": cell.scenario,
            "smoke": cell.smoke,
            "config": repr(cell.config),
            "shape": dataclasses.asdict(cell.shape),
        },
        sort_keys=True,
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def canonical_profile_dict(profile: WorkloadProfile) -> dict:
    """JSON form with volatile wall-clock fields zeroed.

    ``compile_seconds``/``probe_seconds`` differ between byte-identical
    extractions; everything else is a deterministic function of (config,
    shape, jax version), which is what the golden tests pin.
    """
    d = profile.to_json()
    d["compile_seconds"] = 0.0
    d["meta"] = {k: v for k, v in d.get("meta", {}).items()
                 if k not in _VOLATILE_META}
    return d


def canonical_profile_bytes(profile: WorkloadProfile) -> bytes:
    return (json.dumps(canonical_profile_dict(profile), indent=1,
                       sort_keys=True) + "\n").encode()


def cache_path(cell: ZooCell, cache_dir: str) -> str:
    return os.path.join(cache_dir, cell.cache_key + ".json")


def default_cache_dir(smoke: bool) -> str:
    return SMOKE_CACHE_DIR if smoke else FULL_CACHE_DIR


# --------------------------------------------------------------------------- #
# Extraction (lazy imports: compiling pulls in jax; loading does not)
# --------------------------------------------------------------------------- #


def extract_profile(cell: ZooCell, *, calibrate: Optional[bool] = None,
                    verbose: bool = False) -> WorkloadProfile:
    """Compile one zoo cell and extract its WorkloadProfile.

    Smoke cells compile on a single-host-device (1, 1) mesh, so they run
    in any process; full cells need the 16x16 pod mesh and therefore the
    dry-run's 256+ fake host devices (``launch.xla_flags``).  Depth-probe
    calibration defaults off for smoke (unrolled tiny stacks need none)
    and on for full configs (scan-over-layers under-counting).
    """
    import jax

    from repro.launch import extract as EX
    from repro.launch import mesh as MESH
    from repro.launch import xla_flags

    cfg = cell.config
    if cell.smoke:
        mesh = MESH.make_mesh((1, 1), ("data", "model"))
        mesh_label = "host1x1"
    else:
        xla_flags.ensure_host_device_count(256)
        mesh = MESH.make_production_mesh(multi_pod=False)
        mesh_label = "pod16x16"
    if calibrate is None:
        calibrate = not cell.smoke
    profile = EX.run_cell(
        cfg, cell.shape, mesh, mesh_label, EX.default_variant(cfg), None,
        multi_pod=False, verbose=verbose, calibrate=calibrate)
    profile.meta.update(
        scenario=cell.scenario,
        suite="zoo-smoke" if cell.smoke else "zoo",
        fingerprint=cell_fingerprint(cell),
        extraction_version=ZOO_EXTRACTION_VERSION,
        jax_version=jax.__version__,
    )
    return profile


def profiles_from_configs(
    archs: Optional[Sequence[str]] = None,
    scenarios: Optional[Sequence[str]] = None,
    *,
    smoke: bool = False,
    cache_dir: Optional[str] = None,
    refresh: bool = False,
    extract_missing: bool = True,
    calibrate: Optional[bool] = None,
    max_cells: Optional[int] = None,
    verbose: bool = False,
) -> List[WorkloadProfile]:
    """The zoo bridge: registry configs -> measured WorkloadProfile suite.

    For every cell of ``zoo_cells(archs, scenarios, smoke=...)``: load the
    cached profile if its fingerprint matches the cell's current inputs,
    otherwise re-extract (compile) and re-cache.  ``extract_missing=False``
    makes missing/stale cells a hard error instead -- the cache-only mode
    CI and the CLIs use so a sweep never triggers a surprise zoo compile.
    """
    cache_dir = cache_dir or default_cache_dir(smoke)
    cells = zoo_cells(archs, scenarios, smoke=smoke)
    if max_cells is not None:
        cells = cells[:max_cells]
    out: List[WorkloadProfile] = []
    for cell in cells:
        path = cache_path(cell, cache_dir)
        if not refresh and os.path.exists(path):
            profile = WorkloadProfile.load(path)
            if profile.meta.get("fingerprint") == cell_fingerprint(cell):
                out.append(profile)
                continue
            if not extract_missing:
                raise RuntimeError(
                    f"zoo cache entry {path} is stale (config/shape/"
                    f"extraction-version changed since it was written); "
                    f"regenerate with: PYTHONPATH=src python -m "
                    f"repro.core.model_zoo {'--smoke ' if smoke else ''}"
                    f"--refresh")
        elif not refresh and not extract_missing:
            raise RuntimeError(
                f"zoo cache entry {path} is missing; extract the suite "
                f"first: PYTHONPATH=src python -m repro.core.model_zoo"
                f"{' --smoke' if smoke else ''}")
        if not extract_missing:
            raise RuntimeError(
                f"zoo cache entry {path} needs re-extraction but "
                f"extract_missing=False")
        if verbose:
            print(f"== zoo extract {cell.name} [{cell.scenario}] ==",
                  flush=True)
        profile = extract_profile(cell, calibrate=calibrate, verbose=verbose)
        os.makedirs(cache_dir, exist_ok=True)
        with open(path, "wb") as f:
            f.write(canonical_profile_bytes(profile))
        out.append(WorkloadProfile.from_json(canonical_profile_dict(profile)))
    return out


# --------------------------------------------------------------------------- #
# Suite names (the ONE grammar shared by CLIs, CodesignSpec and the service)
# --------------------------------------------------------------------------- #

SUITE_BASES = ("zoo", "zoo-smoke")


def parse_suite(suite: str) -> Tuple[bool, Optional[str]]:
    """``zoo[:scenario]`` | ``zoo-smoke[:scenario]`` -> (smoke, scenario)."""
    if not isinstance(suite, str):
        raise ValueError(f"suite must be a string, got {type(suite).__name__}")
    base, sep, scenario = suite.partition(":")
    if base not in SUITE_BASES:
        raise ValueError(
            f"unknown suite {suite!r}; expected "
            f"{' | '.join(SUITE_BASES)} with an optional "
            f":scenario of {ZOO_SCENARIOS}, or a generated suite "
            f"gen:<count>[:seed=<int>][:mode=halton|rng]")
    if sep and scenario not in ZOO_SCENARIOS:
        raise ValueError(
            f"unknown zoo scenario {scenario!r} in suite {suite!r}; "
            f"have {ZOO_SCENARIOS}")
    return base == "zoo-smoke", (scenario if sep else None)


def validate_suite_name(suite: Optional[str]) -> None:
    """Shared validation hook (``CodesignSpec.validate`` and CLIs).

    Dispatches between the zoo grammar and the generated-suite grammar
    (``repro.core.genload``) so every caller of the ONE validation path
    accepts ``gen:<count>`` suites for free.
    """
    if suite is None:
        return
    from repro.core.genload import is_gen_suite, parse_gen_suite
    if is_gen_suite(suite):
        parse_gen_suite(suite)
    else:
        parse_suite(suite)


def resolve_suite(
    suite: str,
    *,
    cache_dir: Optional[str] = None,
    extract_missing: Optional[bool] = None,
) -> List[WorkloadProfile]:
    """Suite name -> profile list, cache-first.

    Smoke suites extract on a cache miss (tiny configs, seconds each);
    full suites are cache-only by default -- a missing artifact raises
    with the regeneration command rather than starting a multi-minute
    pod-mesh compile inside a sweep.  Generated suites (``gen:<count>``,
    see ``repro.core.genload``) regenerate deterministically from the
    suite string alone and never touch the cache.
    """
    from repro.core.genload import is_gen_suite, resolve_gen_suite
    if is_gen_suite(suite):
        return resolve_gen_suite(suite)
    smoke, scenario = parse_suite(suite)
    if extract_missing is None:
        extract_missing = smoke
    return profiles_from_configs(
        scenarios=(scenario,) if scenario else None,
        smoke=smoke, cache_dir=cache_dir, extract_missing=extract_missing)


# --------------------------------------------------------------------------- #
# Calibration: Eq.1 batched kernels vs the scalar roofline path
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class CalibrationCell:
    name: str
    scenario: str
    eq1_s: float          # batched kernel path (sweep.batched_step_time)
    roofline_s: float     # scalar path (roofline.analyze)
    ratio: float          # eq1_s / roofline_s
    dominant_eq1: str
    dominant_roofline: str

    @property
    def agree(self) -> bool:
        return self.dominant_eq1 == self.dominant_roofline


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Per-cell agreement between the two step-time code paths.

    Both paths consume the same measured HLO costs; the batched path is
    the kernel layer every sweep/service request runs through, the scalar
    path is the roofline module the dry-run reports with.  Ratio ~= 1 and
    matching dominant terms on every cell is the pinned invariant
    (tests/test_model_zoo.py).
    """

    machine: str
    backend: str
    timing_model: str
    cells: Tuple[CalibrationCell, ...]

    @property
    def dominant_agreement(self) -> float:
        if not self.cells:
            return math.nan
        return sum(c.agree for c in self.cells) / len(self.cells)

    def worst_offenders(self, top_k: int = 5) -> List[CalibrationCell]:
        """Cells ranked by |log ratio| (worst Eq.1-vs-roofline mismatch)."""
        def badness(c: CalibrationCell) -> float:
            if not (math.isfinite(c.ratio) and c.ratio > 0):
                return math.inf
            return abs(math.log(c.ratio))
        return sorted(self.cells, key=badness, reverse=True)[:top_k]

    def to_json(self, top_k: Optional[int] = None) -> dict:
        return {
            "machine": self.machine,
            "backend": self.backend,
            "timing_model": self.timing_model,
            "num_cells": len(self.cells),
            "dominant_agreement": self.dominant_agreement,
            "worst_offenders": [c.name for c in self.worst_offenders()],
            "cells": [dataclasses.asdict(c)
                      for c in self.cells[:top_k or len(self.cells)]],
        }

    def markdown(self, top_k: Optional[int] = None) -> str:
        lines = [
            f"### Zoo calibration -- Eq.1 kernels vs roofline "
            f"({self.machine}, {self.backend} backend, "
            f"{self.timing_model} timing)",
            "",
            f"{len(self.cells)} cells, dominant-term agreement "
            f"{100.0 * self.dominant_agreement:.1f}%",
            "",
            "| cell | scenario | Eq.1 (s) | roofline (s) | ratio "
            "| dominant (Eq.1 / roofline) |",
            "|---|---|---|---|---|---|",
        ]
        shown = self.cells[:top_k or len(self.cells)]
        for c in shown:
            mark = "" if c.agree else " **!=**"
            lines.append(
                f"| {c.name} | {c.scenario} | {c.eq1_s:.3e} "
                f"| {c.roofline_s:.3e} | {c.ratio:.4f} "
                f"| {c.dominant_eq1} / {c.dominant_roofline}{mark} |")
        if len(shown) < len(self.cells):
            lines.append(f"| ... {len(self.cells) - len(shown)} more |  "
                         f"|  |  |  |  |")
        worst = self.worst_offenders()
        if worst:
            lines += ["", "Worst offenders (by |log ratio|): "
                      + ", ".join(f"{c.name} ({c.ratio:.4f})"
                                  for c in worst)]
        return "\n".join(lines)


def calibration_report(
    profiles: Sequence[WorkloadProfile],
    machine: MachineModel = TPU_V5E,
    *,
    backend: Optional[str] = None,
    timing_model: str = "serial",
) -> CalibrationReport:
    """Cross-check Eq.1 batched step times against scalar roofline times.

    Step times on the batched side come from the selected kernel backend
    (the exact code every sweep runs); dominant terms on both sides come
    from the reference numpy kernels / ``timing`` module respectively.
    """
    profiles = list(profiles)
    pb = ProfileBatch.from_profiles(profiles)
    mb = MachineBatch.from_models([machine])
    eq1 = batched_step_time(pb, mb, timing_model=timing_model,
                            backend=backend)[:, 0]
    tc, tm, ti = K.scaled_times(np, pb.arrays(), mb.arrays())
    terms = np.stack([tc[:, 0], tm[:, 0], ti[:, 0]])
    term_names = ("compute", "memory", "interconnect")
    cells = []
    for i, p in enumerate(profiles):
        rep = R.analyze(p, machine)
        roofline_s = (rep.step_time_serial_s if timing_model == "serial"
                      else rep.step_time_overlap_s)
        ratio = (float(eq1[i]) / roofline_s if roofline_s > 0 else math.nan)
        cells.append(CalibrationCell(
            name=p.name,
            scenario=str(p.meta.get("scenario", p.step_kind)),
            eq1_s=float(eq1[i]),
            roofline_s=roofline_s,
            ratio=ratio,
            dominant_eq1=term_names[int(np.argmax(terms[:, i]))],
            dominant_roofline=rep.dominant,
        ))
    be = K.get_backend(backend)
    return CalibrationReport(
        machine=machine.name,
        backend=be.name,
        timing_model=timing_model,
        cells=tuple(cells),
    )


# --------------------------------------------------------------------------- #
# CLI: extract/refresh the caches and print the calibration table
# --------------------------------------------------------------------------- #


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Extract the model-zoo profile suite and report "
                    "Eq.1-vs-roofline calibration.")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke suite (tiny configs, single device, checked-"
                         "in cache) instead of the full registry")
    ap.add_argument("--arch", action="append", help="arch id(s); default all")
    ap.add_argument("--scenario", action="append", choices=ZOO_SCENARIOS,
                    help="scenario(s); default all")
    ap.add_argument("--cache-dir", default=None,
                    help="profile cache directory (default: the suite's "
                         "canonical cache)")
    ap.add_argument("--refresh", action="store_true",
                    help="re-extract even when the cached fingerprint "
                         "matches")
    ap.add_argument("--max-cells", type=int, default=None, metavar="N",
                    help="extract at most N cells (bounded CI runs)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip depth-probe cost calibration (full suite "
                         "defaults to calibrated)")
    ap.add_argument("--out", default=None,
                    help="write the calibration report to <out>.md/.json "
                         "(default: stdout)")
    args = ap.parse_args(argv)

    if not args.smoke:
        # Must land before jax initializes; the extraction itself verifies
        # the count via ensure_host_device_count and fails loudly if not.
        from repro.launch import xla_flags
        xla_flags.request_host_devices(512)

    profiles = profiles_from_configs(
        archs=tuple(args.arch) if args.arch else None,
        scenarios=tuple(args.scenario) if args.scenario else None,
        smoke=args.smoke,
        cache_dir=args.cache_dir,
        refresh=args.refresh,
        calibrate=False if args.no_calibrate else None,
        max_cells=args.max_cells,
        verbose=True,
    )
    report = calibration_report(profiles)
    md = report.markdown()
    if args.out:
        with open(args.out + ".md", "w") as f:
            f.write(md + "\n")
        with open(args.out + ".json", "w") as f:
            json.dump(report.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}.{{md,json}}")
    else:
        print(md)
    print(f"{len(profiles)} profiles; dominant-term agreement "
          f"{100.0 * report.dominant_agreement:.1f}%")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
