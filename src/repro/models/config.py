"""Model configuration schema covering all assigned architecture families.

One ``ModelConfig`` describes any of: dense decoder LMs (llama/qwen/chatglm/
deepseek), MoE decoders (grok/qwen2-moe), SSM stacks (falcon-mamba), hybrid
recurrent/local-attention stacks (recurrentgemma), encoder-decoder audio
models (whisper) and vision-prefixed LMs (paligemma).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    AUDIO = "audio"     # encoder-decoder, audio frontend stub
    VLM = "vlm"         # vision-prefixed decoder, patch frontend stub


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0           # per-expert hidden dim
    n_shared_experts: int = 0      # always-active shared experts
    d_ff_shared: int = 0           # per-shared-expert hidden dim
    router_jitter: float = 0.0
    impl: str = "gmm"   # gmm (sort+ragged_dot) | dense (all experts) | capacity
    capacity_factor: float = 1.25  # capacity impl: C = Tg*k*cf/E (drops beyond)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 = ceil(d_model / 16)
    scan_chunk: int = 256          # chunked-scan length (memory/compile knob)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    # Block pattern period, e.g. ("rec", "rec", "att") for RecurrentGemma 1:2.
    pattern: Tuple[str, ...] = ("rec", "rec", "att")
    lru_width: int = 0             # 0 = d_model
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 = d_model // n_heads
    # --- attention options ---------------------------------------------- #
    rope_style: str = "full"       # full | half (partial/interleaved "2d") | none
    rope_theta: float = 10000.0
    qk_norm: bool = False          # qwen3-style per-head RMS on q,k
    qkv_bias: bool = False         # qwen1.5-style
    attn_window: Optional[int] = None   # sliding-window size (local attention)
    attn_logit_softcap: Optional[float] = None
    attn_q_chunk: int = 0          # blockwise attention q-chunk (0 = off)
    # --- MLP / norms ------------------------------------------------------ #
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma-style sqrt(d_model) embedding scale
    # --- family extensions ------------------------------------------------ #
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder (audio): encoder layer count + frontend sequence length
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500    # whisper: 30 s -> 1500 frames after conv
    decoder_pos_len: int = 0       # learned decoder position table (audio)
    # vlm: number of vision prefix tokens (SigLIP stub output length)
    n_vision_tokens: int = 0
    # --- numerics / execution --------------------------------------------- #
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # none | full | dots
    logits_chunk: int = 0          # 0 = unchunked cross-entropy
    attn_impl: str = "xla"         # xla | pallas
    scan_layers: bool = True

    # ------------------------------------------------------------------ #

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def is_attention_free(self) -> bool:
        return self.family == Family.SSM

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k decode is runnable."""
        return self.family in (Family.SSM, Family.HYBRID)

    @property
    def has_encoder(self) -> bool:
        return self.family == Family.AUDIO

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- parameter counts (analytic; used for MODEL_FLOPS) ------------- #

    def param_counts(self) -> Tuple[float, float]:
        """(total_params, active_params).  Active differs only for MoE."""
        d, v = self.d_model, self.vocab_size
        embed = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> float:
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            return p

        def mlp_params(d_ff: int) -> float:
            n_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
            return n_mats * d * d_ff

        norms = 2 * d  # two per block
        total = active = 0.0

        if self.family in (Family.DENSE, Family.VLM):
            per_layer = attn_params() + mlp_params(self.d_ff) + norms
            total = active = self.n_layers * per_layer
        elif self.family == Family.AUDIO:
            enc = self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff) + norms)
            # decoder blocks add cross-attention
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff) + 3 * d)
            total = active = enc + dec
        elif self.family == Family.MOE:
            m = self.moe
            assert m is not None
            router = d * m.n_experts
            experts_total = m.n_experts * mlp_params(m.d_ff_expert)
            experts_active = m.top_k * mlp_params(m.d_ff_expert)
            shared = m.n_shared_experts * mlp_params(m.d_ff_shared)
            if m.n_shared_experts:
                shared += d * d  # shared-expert gate
            per_layer_total = attn_params() + router + experts_total + shared + norms
            per_layer_active = attn_params() + router + experts_active + shared + norms
            total = self.n_layers * per_layer_total
            active = self.n_layers * per_layer_active
        elif self.family == Family.SSM:
            s = self.ssm
            assert s is not None
            d_in = s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            per_layer = (
                d * 2 * d_in                   # in_proj (x and gate)
                + s.conv_width * d_in          # depthwise conv
                + d_in * (dt_rank + 2 * s.state_dim)  # x -> dt,B,C
                + dt_rank * d_in               # dt_proj
                + d_in * s.state_dim           # A
                + d_in                         # D
                + d_in * d                     # out_proj
                + d                            # norm
            )
            total = active = self.n_layers * per_layer
        elif self.family == Family.HYBRID:
            h = self.hybrid
            assert h is not None
            w = h.lru_width or d
            rec_layer = (
                2 * d * w                      # in_proj x + gate branches
                + h.conv_width * w             # temporal conv
                + 2 * w * w // 8               # RG-LRU input/recurrence gates (block-diag, 8 heads)
                + w                            # LRU decay params
                + w * d                        # out_proj
            )
            att_layer = attn_params()
            n_rec = sum(1 for i in range(self.n_layers)
                        if h.pattern[i % len(h.pattern)] == "rec")
            n_att = self.n_layers - n_rec
            per_mlp = mlp_params(self.d_ff) + norms
            total = active = (
                n_rec * (rec_layer + per_mlp) + n_att * (att_layer + per_mlp)
            )
        else:  # pragma: no cover
            raise ValueError(self.family)

        total += embed
        active += embed
        if self.family == Family.VLM and self.n_vision_tokens:
            pass  # SigLIP frontend is a stub; its params are out of scope
        return float(total), float(active)
