"""Neural net layers (pure JAX, no framework deps).

Every layer is an (init, apply) pair.  ``*_init`` returns ``(params, axes)``
where ``axes`` mirrors the param pytree with tuples of *logical* axis names
("embed", "heads", "mlp", "experts", "vocab", ...).  The distributed layer
maps logical axes onto mesh axes per sharding variant, so model code never
mentions physical meshes.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]


def dtype_of(name: str):
    return jnp.dtype(name)


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #


def norm_init(cfg: ModelConfig, dim: Optional[int] = None) -> Tuple[Params, Axes]:
    dim = dim or cfg.d_model
    dt = dtype_of(cfg.param_dtype)
    if cfg.norm == "layernorm":
        p = {"scale": jnp.ones((dim,), dt), "bias": jnp.zeros((dim,), dt)}
        a = {"scale": ("embed",), "bias": ("embed",)}
    else:
        p = {"scale": jnp.ones((dim,), dt)}
        a = {"scale": ("embed",)}
    return p, a


def norm_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """Per-head RMS norm over the trailing head_dim (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings ("full" neox-style, "half" = partial/interleaved a la GLM)
# --------------------------------------------------------------------------- #


def rope_tables(
    positions: jax.Array, rotary_dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables: (..., seq, rotary_dim//2), f32."""
    half = rotary_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(
    x: jax.Array, cos: jax.Array, sin: jax.Array, style: str
) -> jax.Array:
    """x: (B, S, H, hd).  "full": rotate all dims (paired halves).
    "half": chatglm-style 2d rotary -- rotate only the first half of head_dim,
    interleaved pairing; the second half passes through."""
    if style == "none":
        return x
    hd = x.shape[-1]
    if style == "half":
        rot, keep = jnp.split(x, 2, axis=-1)
        xr = rot.astype(jnp.float32).reshape(*rot.shape[:-1], -1, 2)
        x1, x2 = xr[..., 0], xr[..., 1]
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
        o1 = x1 * c - x2 * s
        o2 = x2 * c + x1 * s
        out = jnp.stack([o1, o2], axis=-1).reshape(rot.shape)
        return jnp.concatenate([out.astype(x.dtype), keep], axis=-1)
    # full, neox pairing (first half with second half)
    half = hd // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def rotary_dim_of(cfg: ModelConfig) -> int:
    return cfg.head_dim_ // 2 if cfg.rope_style == "half" else cfg.head_dim_


# --------------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------------- #


def embed_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p: Params = {"tok": _init_dense(k1, (cfg.vocab_size, cfg.d_model), dt, scale=1.0)}
    a: Axes = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = _init_dense(k2, (cfg.d_model, cfg.vocab_size), dt)
        a["unembed"] = ("embed", "vocab")
    return p, a


def embed_apply(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = p["tok"].astype(dtype_of(cfg.compute_dtype))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cd = dtype_of(cfg.compute_dtype)
    w = p["unembed"] if not cfg.tie_embeddings else p["tok"].T
    return jnp.einsum("bsd,dv->bsv", x.astype(cd), w.astype(cd))


# --------------------------------------------------------------------------- #
# Attention (GQA; causal / sliding-window / prefix-LM; self or cross; cached)
# --------------------------------------------------------------------------- #


def attn_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    dt = dtype_of(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": _init_dense(ks[0], (d, cfg.n_heads, hd), dt),
        "wk": _init_dense(ks[1], (d, cfg.n_kv_heads, hd), dt),
        "wv": _init_dense(ks[2], (d, cfg.n_kv_heads, hd), dt),
        "wo": _init_dense(ks[3], (cfg.n_heads, hd, d), dt, scale=1.0 / math.sqrt(cfg.q_dim)),
    }
    a: Axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
        a["q_norm"] = ("head_dim",)
        a["k_norm"] = ("head_dim",)
    return p, a


@jax.tree_util.register_static
class MaskSpec:
    """Attention-mask description; the (S_q, S_k) boolean mask itself is
    built lazily per q-chunk inside attention (a full 32k x 32k mask is 1 GB
    per device -- never materialize it)."""

    def __init__(self, *, causal: bool = True, window: Optional[int] = None,
                 prefix_len: int = 0, everything: bool = False):
        self.causal = causal
        self.window = window
        self.prefix_len = prefix_len
        self.everything = everything  # True -> no masking at all

    def build(self, q_pos: jax.Array, k_pos: jax.Array) -> Optional[jax.Array]:
        """(B, S_q) x (B, S_k) -> (B, S_q, S_k) bool, or None if unmasked."""
        if self.everything:
            return None
        dq = q_pos[..., :, None]
        dk = k_pos[..., None, :]
        mask = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
        if self.causal:
            m = dk <= dq
            if self.prefix_len:
                m = m | (dk < self.prefix_len)
            mask = mask & m
        if self.window is not None:
            mask = mask & (dq - dk < self.window)
        return mask


def _attn_mask(q_pos, k_pos, *, causal, window, prefix_len: int = 0):
    """Compatibility helper: materialized mask (small shapes only)."""
    return MaskSpec(causal=causal, window=window, prefix_len=prefix_len).build(
        q_pos, k_pos)


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Masked softmax attention core.  q: (B,Sq,K,G,hd); k,v: (B,T,K,hd);
    mask: (B,Sq,T) bool or None."""
    cd = q.dtype
    B, Sq, K, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) * scale
    scores = scores.astype(jnp.float32)
    if cfg.attn_logit_softcap:
        cap = cfg.attn_logit_softcap
        scores = cap * jnp.tanh(scores / cap)
    if mask is not None:
        big_neg = jnp.asarray(-1e30, jnp.float32)
        scores = jnp.where(mask[:, None, None, :, :], scores, big_neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def attn_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    rope: Optional[Tuple[jax.Array, jax.Array]] = None,
    mask: Optional[MaskSpec] = None,
    q_pos: Optional[jax.Array] = None,
    k_pos: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    kv_rope: Optional[Tuple[jax.Array, jax.Array]] = None,
    static_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention.

    x: (B, S, D).  ``mask`` is a MaskSpec evaluated lazily against
    (q_pos, k_pos) -- per q-chunk when ``cfg.attn_q_chunk`` divides S, so the
    full (S, T) mask / score matrices are never materialized at long context.
    With ``cache`` (dict of k/v (B, S_max, K, hd)) and ``cache_index``:
    decode mode -- writes new k/v at cache_index and attends over the cache.
    ``kv_x`` switches to cross-attention.
    """
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // K

    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)

    if cache is not None and (static_cache or kv_x is not None):
        # cross-attention with precomputed encoder k/v (whisper decode)
        k, v = cache["k"].astype(cd), cache["v"].astype(cd)
    else:
        src = kv_x if kv_x is not None else x
        k = jnp.einsum("bsd,dhk->bshk", src.astype(cd), p["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", src.astype(cd), p["wv"].astype(cd))
        if cfg.qkv_bias:
            k = k + p["bk"].astype(cd)
            v = v + p["bv"].astype(cd)

    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        if not (cache is not None and (static_cache or kv_x is not None)):
            k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)

    if rope is not None:
        cos_q, sin_q = rope
        q = apply_rope(q, cos_q, sin_q, cfg.rope_style)
        if kv_x is None and not static_cache:
            cos_k, sin_k = kv_rope if kv_rope is not None else rope
            k = apply_rope(k, cos_k, sin_k, cfg.rope_style)

    new_cache = None
    if cache is not None and kv_x is None and not static_cache:
        # decode/prefill-with-cache: insert k,v at cache_index
        assert cache_index is not None
        idx = jnp.asarray(cache_index)
        if idx.ndim:
            # per-row positions (continuous batching: one index per slot);
            # decode-only, so S == 1 and each row writes its own cache slot
            rows = jnp.arange(B)
            k_cache = cache["k"].at[rows, idx].set(
                k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[rows, idx].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            k_cache = lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0)
            )
            v_cache = lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0)
            )
        new_cache = {"k": k_cache, "v": v_cache}
        k, v = k_cache.astype(cd), v_cache.astype(cd)

    T = k.shape[1]
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    if mask is None:
        mask = MaskSpec(everything=True)

    # Pallas flash-attention path (TPU target; interpret-mode on CPU).
    # Covers self-attention without prefix-LM masking; q_pos must be the
    # plain 0..S-1 range (full-sequence forward).
    if (cfg.attn_impl == "pallas" and kv_x is None and new_cache is None
            and cache is None and not mask.everything
            and mask.prefix_len == 0 and mask.causal):
        from repro.kernels import ops as kops

        def _blk(n: int, pref: int = 128) -> int:
            for b in (pref, 64, 32, 16, 8, 4, 2, 1):
                if n % b == 0:
                    return b
            return 1

        ctx = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=mask.window,
            block_q=_blk(S), block_kv=_blk(T),
        ).transpose(0, 2, 1, 3)
        out = jnp.einsum("bshk,hkd->bsd", ctx.reshape(B, S, H, hd),
                         p["wo"].astype(cd))
        return out, new_cache

    qg = q.reshape(B, S, K, G, hd)
    qc = cfg.attn_q_chunk
    if qc and S > qc and S % qc == 0:
        # blockwise attention: scan over q chunks; scores stay (B,qc,T)
        n_chunks = S // qc
        q_chunks = qg.reshape(B, n_chunks, qc, K, G, hd).swapaxes(0, 1)
        qpos_chunks = q_pos.reshape(B, n_chunks, qc).swapaxes(0, 1)

        def chunk(carry, inp):
            q_c, qp_c = inp
            m = mask.build(qp_c, k_pos)
            ctx_c = _sdpa(q_c, k, v, m, cfg)
            return carry, ctx_c

        _, ctx = lax.scan(chunk, 0, (q_chunks, qpos_chunks))
        ctx = ctx.swapaxes(0, 1).reshape(B, S, H, hd)
    else:
        ctx = _sdpa(qg, k, v, mask.build(q_pos, k_pos), cfg).reshape(B, S, H, hd)

    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(cd))
    return out, new_cache


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Tuple[Params, Axes]:
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        p = {
            "w_gate": _init_dense(ks[0], (d, f), dt),
            "w_up": _init_dense(ks[1], (d, f), dt),
            "w_down": _init_dense(ks[2], (f, d), dt),
        }
        a = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    else:  # plain gelu (whisper)
        p = {
            "w_up": _init_dense(ks[0], (d, f), dt),
            "b_up": jnp.zeros((f,), dt),
            "w_down": _init_dense(ks[1], (f, d), dt),
            "b_down": jnp.zeros((d,), dt),
        }
        a = {"w_up": ("embed", "mlp"), "b_up": ("mlp",),
             "w_down": ("mlp", "embed"), "b_down": ("embed",)}
    return p, a


def mlp_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    if cfg.mlp in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd))
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate, approximate=True)
        return jnp.einsum("bsf,fd->bsd", act * up, p["w_down"].astype(cd))
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd)) + p["b_up"].astype(cd)
    h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd)) + p["b_down"].astype(cd)


# --------------------------------------------------------------------------- #
# Mixture of Experts
# --------------------------------------------------------------------------- #


def moe_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    m = cfg.moe
    assert m is not None
    dt = dtype_of(cfg.param_dtype)
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 6)
    p: Params = {
        "router": _init_dense(ks[0], (d, E), dt),
        "w_gate": _init_dense(ks[1], (E, d, f), dt),
        "w_up": _init_dense(ks[2], (E, d, f), dt),
        "w_down": _init_dense(ks[3], (E, f, d), dt, scale=1.0 / math.sqrt(f)),
    }
    a: Axes = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    if m.n_shared_experts:
        fs = m.d_ff_shared * m.n_shared_experts
        p["shared"] = {
            "w_gate": _init_dense(ks[4], (d, fs), dt),
            "w_up": _init_dense(jax.random.fold_in(ks[4], 1), (d, fs), dt),
            "w_down": _init_dense(jax.random.fold_in(ks[4], 2), (fs, d), dt),
        }
        p["shared_gate"] = _init_dense(ks[5], (d, 1), dt)
        a["shared"] = {
            "w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed"),
        }
        a["shared_gate"] = ("embed", None)
    return p, a


def moe_apply(
    p: Params, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE.  x: (B, S, D) -> (y, aux_loss).

    impl="gmm": sort tokens by expert and run grouped matmuls via
    ``lax.ragged_dot`` (the TPU megablox-style dataflow).
    impl="dense": run every expert on every token (tiny smoke tests only).
    """
    m = cfg.moe
    assert m is not None
    cd = dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D).astype(cd)
    E, k = m.n_experts, m.top_k

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)                     # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * router_prob) * E * m.aux_loss_weight

    act = jax.nn.silu if cfg.mlp == "swiglu" else functools.partial(
        jax.nn.gelu, approximate=True)

    if m.impl == "dense":
        # (T, E, f) -- every expert everywhere; only for tiny configs.
        h_g = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(cd))
        h_u = jnp.einsum("td,edf->tef", xt, p["w_up"].astype(cd))
        h = act(h_g) * h_u
        y_all = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(cd))
        combine = jnp.zeros((T, E), cd).at[jnp.arange(T)[:, None], idx].add(
            gates.astype(cd))
        y = jnp.einsum("ted,te->td", y_all, combine)
    elif m.impl == "capacity":
        y = _moe_capacity(p, cfg, xt, gates, idx, act)
    else:
        flat_e = idx.reshape(-1)                          # (T*k,)
        order = jnp.argsort(flat_e)                       # stable
        token_of = order // k
        xs = jnp.take(xt, token_of, axis=0)               # (T*k, D) grouped
        group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
        h_g = lax.ragged_dot(xs, p["w_gate"].astype(cd), group_sizes)
        h_u = lax.ragged_dot(xs, p["w_up"].astype(cd), group_sizes)
        h = act(h_g) * h_u
        out = lax.ragged_dot(h, p["w_down"].astype(cd), group_sizes)  # (T*k, D)
        w = jnp.take(gates.reshape(-1), order, axis=0).astype(cd)[:, None]
        y = jnp.zeros((T, D), cd).at[token_of].add(out * w)

    if m.n_shared_experts:
        sh = p["shared"]
        g = jnp.einsum("td,df->tf", xt, sh["w_gate"].astype(cd))
        u = jnp.einsum("td,df->tf", xt, sh["w_up"].astype(cd))
        ys = jnp.einsum("tf,fd->td", act(g) * u, sh["w_down"].astype(cd))
        sg = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xt, p["shared_gate"].astype(cd)).astype(jnp.float32)
        ).astype(cd)
        y = y + ys * sg

    return y.reshape(B, S, D), aux


def _moe_capacity(p: Params, cfg: ModelConfig, xt: jax.Array,
                  gates: jax.Array, idx: jax.Array, act) -> jax.Array:
    """Capacity-based MoE dispatch (GShard/Switch dataflow, TPU-shaped).

    Tokens are routed into a per-expert buffer of fixed capacity C via
    gather/scatter (linear cost, well-behaved VJPs), experts run as one
    batched dense einsum (E, C, d) x (E, d, f) -- no ragged primitives, so
    forward AND backward stay at ~active-expert FLOPs, unlike the XLA
    ragged_dot fallback whose VJP materializes dense (rows, f, E) tensors.
    Overflowing tokens are dropped (standard; exact when capacity_factor is
    generous).  Routing is computed per data-parallel group (ctx.dp_groups)
    so dispatch never crosses device boundaries.
    """
    from repro.distributed import ctx as _ctx

    m = cfg.moe
    cd = xt.dtype
    T, D = xt.shape
    E, k = m.n_experts, m.top_k
    G = _ctx.data_parallel_groups()
    if T % G != 0:
        G = 1
    Tg = T // G
    C = min(Tg * k, int(-(-Tg * k * m.capacity_factor // E)))

    xg = _ctx.constrain(xt.reshape(G, Tg, D), "moe_tokens")
    gg = gates.reshape(G, Tg, k).astype(cd)
    ig = idx.reshape(G, Tg, k)

    def one_group(x, gate, eidx, w_gate, w_up, w_down):
        flat_e = eidx.reshape(-1)                       # (Tg*k,)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        token_of = order // k
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        slot = jnp.arange(Tg * k) - starts[sorted_e]    # rank within expert
        keep = slot < C
        # dropped rows scatter to row C (mode=drop discards them)
        scat_e = jnp.where(keep, sorted_e, E)
        scat_c = jnp.where(keep, slot, C)
        buf = jnp.zeros((E, C, D), cd).at[scat_e, scat_c].set(
            jnp.take(x, token_of, axis=0), mode="drop")
        h_g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cd))
        h_u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(cd))
        h = act(h_g) * h_u
        out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(cd))
        # combine: gather each kept row back to its token, weighted
        rows = out[jnp.minimum(scat_e, E - 1), jnp.minimum(scat_c, C - 1)]
        w = jnp.take(gate.reshape(-1), order) * keep.astype(cd)
        y = jnp.zeros((Tg, D), cd).at[token_of].add(rows * w[:, None])
        return y

    shmap = _ctx.shmap_info()
    if shmap is not None:
        # Megatron-MoE dataflow under explicit shard_map: tokens sharded over
        # the data axes (one routing group per data shard, replicated across
        # the model axis), expert f-dim sharded over "model"; each device
        # computes its f-slice for its data-shard's tokens, combines LOCALLY
        # to token-sized partial outputs, and a single psum('model') per
        # layer reduces (Tg, D) -- k*capacity_factor x less interconnect
        # traffic than letting the partitioner all-reduce the (E, C, D)
        # expert buffers.
        dp_axes, tp_axis, mesh = shmap
        from jax.sharding import PartitionSpec as P

        dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

        def kernel(x_blk, g_blk, i_blk, w1, w2, w3):
            y = one_group(x_blk[0], g_blk[0], i_blk[0], w1, w2, w3)
            y = jax.lax.psum(y, tp_axis)
            return y[None]

        y = jax.shard_map(
            kernel,
            mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, None, None), P(dp, None, None),
                      P(None, None, tp_axis), P(None, None, tp_axis),
                      P(None, tp_axis, None)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(xg, gg, ig, p["w_gate"], p["w_up"], p["w_down"])
    else:
        y = jax.vmap(
            lambda x, g, i: one_group(x, g, i, p["w_gate"], p["w_up"],
                                      p["w_down"])
        )(xg, gg, ig)
    y = _ctx.constrain(y, "moe_tokens")
    return y.reshape(T, D)


# --------------------------------------------------------------------------- #
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# --------------------------------------------------------------------------- #

_LRU_BLOCKS = 8      # block-diagonal gate structure
_LRU_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    h = cfg.hybrid
    assert h is not None
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    w = h.lru_width or d
    wb = w // _LRU_BLOCKS
    ks = jax.random.split(key, 7)
    p: Params = {
        "w_x": _init_dense(ks[0], (d, w), dt),
        "w_y": _init_dense(ks[1], (d, w), dt),
        "conv_w": _init_dense(ks[2], (h.conv_width, w), dt, scale=0.1),
        "conv_b": jnp.zeros((w,), dt),
        "gate_a": _init_dense(ks[3], (_LRU_BLOCKS, wb, wb), dt),
        "gate_x": _init_dense(ks[4], (_LRU_BLOCKS, wb, wb), dt),
        "lambda": jnp.full((w,), 2.0, dt),  # softplus param for decay a
        "w_out": _init_dense(ks[5], (w, d), dt),
    }
    a: Axes = {
        "w_x": ("embed", "mlp"),
        "w_y": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "gate_a": (None, "mlp_block", "mlp_block"),
        "gate_x": (None, "mlp_block", "mlp_block"),
        "lambda": ("mlp",),
        "w_out": ("mlp", "embed"),
    }
    return p, a


def causal_conv1d(
    x: jax.Array, w: jax.Array, b: Optional[jax.Array],
    state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over (B, S, C).  w: (width, C).

    Returns (y, new_state) with state = last (width-1) inputs for decode.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + xp[:, i: i + x.shape[1], :] * w[i].astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


def _lru_scan(a: jax.Array, bx: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + bx_t over axis 1.  a, bx: (B, S, W) f32."""
    from repro.distributed import ctx as _ctx

    # keep the channel dim sharded through the scan (replicated carries make
    # the partitioner all-gather every step's inputs -- see _ssm_scan)
    h0 = _ctx.constrain(h0, "lru_state")

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    hT, ys = lax.scan(step, h0,
                      (_ctx.constrain(a.swapaxes(0, 1), "lru_seq"),
                       _ctx.constrain(bx.swapaxes(0, 1), "lru_seq")))
    return ys.swapaxes(0, 1), hT


def rglru_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Recurrent block: [x->conv->RG-LRU] gated by GeLU(y-branch)."""
    h = cfg.hybrid
    assert h is not None
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    B, S, _ = x.shape
    w = p["w_x"].shape[1]
    wb = w // _LRU_BLOCKS

    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(cd))
    yb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_y"].astype(cd)), approximate=True)

    conv_state = state["conv"] if state is not None else None
    xb, new_conv = causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)

    # block-diagonal gates
    xg = xb.reshape(B, S, _LRU_BLOCKS, wb)
    r = jax.nn.sigmoid(jnp.einsum(
        "bshw,hwe->bshe", xg.astype(jnp.float32), p["gate_a"].astype(jnp.float32)
    ).reshape(B, S, w))
    i = jax.nn.sigmoid(jnp.einsum(
        "bshw,hwe->bshe", xg.astype(jnp.float32), p["gate_x"].astype(jnp.float32)
    ).reshape(B, S, w))

    log_a = -_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * xb.astype(jnp.float32)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8)) * gated

    h0 = state["lru"] if state is not None else jnp.zeros((B, w), jnp.float32)
    ys, hT = _lru_scan(a, bx, h0)

    out = (ys.astype(cd) * yb)
    out = jnp.einsum("bsw,wd->bsd", out, p["w_out"].astype(cd))
    new_state = {"conv": new_conv, "lru": hT} if state is not None else None
    return out, new_state


# --------------------------------------------------------------------------- #
# Mamba-1 block (falcon-mamba)
# --------------------------------------------------------------------------- #


def mamba_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    s = cfg.ssm
    assert s is not None
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = s.dt_rank or -(-d // 16)
    n = s.state_dim
    ks = jax.random.split(key, 7)
    p: Params = {
        "w_in": _init_dense(ks[0], (d, 2 * d_in), dt),
        "conv_w": _init_dense(ks[1], (s.conv_width, d_in), dt, scale=0.5),
        "conv_b": jnp.zeros((d_in,), dt),
        "w_xdbc": _init_dense(ks[2], (d_in, dt_rank + 2 * n), dt),
        "w_dt": _init_dense(ks[3], (dt_rank, d_in), dt),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (d_in,)) * 0.1 + 0.001, 1e-4)
        )).astype(dt),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, 1))
                         ).astype(dt),
        "D": jnp.ones((d_in,), dt),
        "w_out": _init_dense(ks[5], (d_in, d), dt),
    }
    a: Axes = {
        "w_in": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "w_xdbc": ("mlp", None),
        "w_dt": (None, "mlp"),
        "dt_bias": ("mlp",),
        "A_log": ("mlp", "state"),
        "D": ("mlp",),
        "w_out": ("mlp", "embed"),
    }
    return p, a


def _ssm_scan(
    xi: jax.Array,        # (B, S, Din)  post-conv/silu activations
    dt_in: jax.Array,     # (B, S, R)    low-rank dt projection input
    Bm: jax.Array,        # (B, S, N)
    Cm: jax.Array,        # (B, S, N)
    w_dt: jax.Array,      # (R, Din)
    dt_bias: jax.Array,   # (Din,)
    A: jax.Array,         # (Din, N), negative
    h0: jax.Array,        # (B, Din, N)
    chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Selective-scan core -> (y: (B,S,Din), hT).

    The (B,S,Din,N) discretized dA/dBx tensors are NEVER materialized for the
    full sequence: each chunk computes its own slice inside a rematerialized
    scan body, so live memory is one chunk's worth (the same blocking the
    Pallas kernel uses on TPU)."""
    B, S, Din = xi.shape
    N = A.shape[1]
    if S % chunk != 0:
        chunk = S  # fall back to single chunk for odd sizes (decode, tests)
    n_chunks = S // chunk

    def chunk_step(h, inp):
        xi_c, dtin_c, B_c, C_c = inp  # leading dim = chunk, batch second
        dt_c = jax.nn.softplus(
            jnp.einsum("tbr,rd->tbd", dtin_c.astype(jnp.float32),
                       w_dt.astype(jnp.float32))
            + dt_bias.astype(jnp.float32))           # (chunk, B, Din)
        dA_c = jnp.exp(dt_c[..., None] * A[None, None])  # (chunk,B,Din,N)
        dBx_c = (dt_c * xi_c.astype(jnp.float32))[..., None] \
            * B_c.astype(jnp.float32)[:, :, None, :]

        def step(hh, t):
            dA_t, dBx_t, C_t = t
            hh = dA_t * hh + dBx_t
            y_t = jnp.einsum("bdn,bn->bd", hh, C_t)
            return hh, y_t

        h, ys = lax.scan(step, h, (dA_c, dBx_c, C_c.astype(jnp.float32)))
        return h, ys

    from repro.distributed import ctx as _ctx

    to_chunks = lambda x: x.swapaxes(0, 1).reshape(
        n_chunks, chunk, B, *x.shape[2:])
    # Shard the channel dim of the recurrence across the model axis: the
    # scan carry h0 defaults to replicated, which otherwise makes the
    # partitioner all-gather every chunk's (chunk, B, Din, N) inputs.
    h0 = _ctx.constrain(h0, "ssm_state")
    xs = (_ctx.constrain(to_chunks(xi), "ssm_chunks_d"),
          to_chunks(dt_in), to_chunks(Bm), to_chunks(Cm))
    hT, ys = lax.scan(jax.checkpoint(chunk_step), h0, xs)
    y = ys.reshape(S, B, Din).swapaxes(0, 1)
    return y, hT


def mamba_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
    scan_chunk: int = 256,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    s = cfg.ssm
    assert s is not None
    cd = dtype_of(cfg.compute_dtype)
    x = x.astype(cd)
    B, S, _ = x.shape
    d_in = p["conv_b"].shape[0]
    n = s.state_dim
    dt_rank = p["w_dt"].shape[0]

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xi, new_conv = causal_conv1d(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    dbc = jnp.einsum("bse,en->bsn", xi, p["w_xdbc"].astype(cd))
    dt_in, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (Din, N)
    h0 = state["ssm"] if state is not None else jnp.zeros((B, d_in, n), jnp.float32)
    y, hT = _ssm_scan(xi, dt_in, Bm, Cm, p["w_dt"], p["dt_bias"], A, h0,
                      chunk=scan_chunk)
    y = y + p["D"].astype(jnp.float32) * xi.astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd))
    new_state = {"conv": new_conv, "ssm": hT} if state is not None else None
    return out, new_state
