"""Model stacks for all assigned families.

Public API:
  init_model(key, cfg, abstract=False)       -> (params, axes)
  forward(params, cfg, batch)                -> (hidden, aux_loss)
  loss_fn(params, cfg, batch)                -> (loss, metrics)
  init_cache(cfg, batch, max_len, abstract)  -> (cache, axes)
  prefill(params, cfg, batch, cache)         -> (cache, logits_last)
  decode_step(params, cfg, cache, tokens, index) -> (cache, logits)

``batch`` is a dict: {"tokens": (B,S) int32, "labels": (B,S) int32, and for
stub-frontend families "frames": (B,F,D) / "patches": (B,P,D)}.

Layers are stacked along a leading "layers" axis and iterated with
``lax.scan`` (keeps HLO size O(1) in depth); remat policy per config.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models.config import Family, ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #


def _stack_init(init_fn: Callable, key, n: int, abstract: bool):
    """vmap an (params, axes) init over n layers; prepend 'layers' to axes."""
    keys = jax.random.split(key, n)
    cap: Dict[str, Any] = {}

    def wrapped(k):
        p, a = init_fn(k)
        cap["axes"] = a
        return p

    if abstract:
        params = jax.eval_shape(jax.vmap(wrapped), keys)
    else:
        params = jax.vmap(wrapped)(keys)
    axes = jax.tree.map(
        lambda _, a: ("layers",) + tuple(a), params, cap["axes"]
    )
    return params, axes


def _maybe(key, init_fn, abstract: bool):
    if abstract:
        cap = {}

        def wrapped(k):
            p, a = init_fn(k)
            cap["axes"] = a
            return p

        params = jax.eval_shape(wrapped, key)
        return params, cap["axes"]
    return init_fn(key)


def _remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------- #
# per-family block definitions
# --------------------------------------------------------------------------- #


def _dense_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    attn_p, attn_a = L.attn_init(ks[0], cfg)
    mlp_p, mlp_a = L.mlp_init(ks[1], cfg)
    n1p, n1a = L.norm_init(cfg)
    n2p, n2a = L.norm_init(cfg)
    return (
        {"attn": attn_p, "mlp": mlp_p, "ln1": n1p, "ln2": n2p},
        {"attn": attn_a, "mlp": mlp_a, "ln1": n1a, "ln2": n2a},
    )


def _dense_block_apply(bp, cfg, x, *, rope, mask, q_pos=None, k_pos=None,
                       cache=None, index=None):
    h, new_kv = L.attn_apply(
        bp["attn"], cfg, L.norm_apply(bp["ln1"], cfg, x),
        rope=rope, mask=mask, q_pos=q_pos, k_pos=k_pos,
        cache=cache, cache_index=index,
    )
    x = constrain(x + h, "acts")
    y = L.mlp_apply(bp["mlp"], cfg, L.norm_apply(bp["ln2"], cfg, x))
    return constrain(x + y, "acts"), new_kv, 0.0


def _moe_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    attn_p, attn_a = L.attn_init(ks[0], cfg)
    moe_p, moe_a = L.moe_init(ks[1], cfg)
    n1p, n1a = L.norm_init(cfg)
    n2p, n2a = L.norm_init(cfg)
    return (
        {"attn": attn_p, "moe": moe_p, "ln1": n1p, "ln2": n2p},
        {"attn": attn_a, "moe": moe_a, "ln1": n1a, "ln2": n2a},
    )


def _moe_block_apply(bp, cfg, x, *, rope, mask, q_pos=None, k_pos=None,
                     cache=None, index=None):
    h, new_kv = L.attn_apply(
        bp["attn"], cfg, L.norm_apply(bp["ln1"], cfg, x),
        rope=rope, mask=mask, q_pos=q_pos, k_pos=k_pos,
        cache=cache, cache_index=index,
    )
    x = constrain(x + h, "acts")
    y, aux = L.moe_apply(bp["moe"], cfg, L.norm_apply(bp["ln2"], cfg, x))
    return constrain(x + y, "acts"), new_kv, aux


def _ssm_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    mp, ma = L.mamba_init(ks[0], cfg)
    np_, na = L.norm_init(cfg)
    return {"mamba": mp, "ln": np_}, {"mamba": ma, "ln": na}


def _ssm_block_apply(bp, cfg, x, *, state=None):
    h, new_state = L.mamba_apply(
        bp["mamba"], cfg, L.norm_apply(bp["ln"], cfg, x),
        state=state, scan_chunk=cfg.ssm.scan_chunk,
    )
    return constrain(x + h, "acts"), new_state, 0.0


def _rec_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    rp, ra = L.rglru_init(ks[0], cfg)
    mp, ma = L.mlp_init(ks[1], cfg)
    n1p, n1a = L.norm_init(cfg)
    n2p, n2a = L.norm_init(cfg)
    return (
        {"rec": rp, "mlp": mp, "ln1": n1p, "ln2": n2p},
        {"rec": ra, "mlp": ma, "ln1": n1a, "ln2": n2a},
    )


def _rec_block_apply(bp, cfg, x, *, state=None):
    h, new_state = L.rglru_apply(
        bp["rec"], cfg, L.norm_apply(bp["ln1"], cfg, x), state=state
    )
    x = constrain(x + h, "acts")
    y = L.mlp_apply(bp["mlp"], cfg, L.norm_apply(bp["ln2"], cfg, x))
    return constrain(x + y, "acts"), new_state, 0.0


def _xattn_block_init(key, cfg: ModelConfig):
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    ks = jax.random.split(key, 5)
    self_p, self_a = L.attn_init(ks[0], cfg)
    cross_p, cross_a = L.attn_init(ks[1], cfg)
    mlp_p, mlp_a = L.mlp_init(ks[2], cfg)
    norms = [L.norm_init(cfg) for _ in range(3)]
    return (
        {"self": self_p, "cross": cross_p, "mlp": mlp_p,
         "ln1": norms[0][0], "ln2": norms[1][0], "ln3": norms[2][0]},
        {"self": self_a, "cross": cross_a, "mlp": mlp_a,
         "ln1": norms[0][1], "ln2": norms[1][1], "ln3": norms[2][1]},
    )


def _xattn_block_apply(bp, cfg, x, *, mask, q_pos=None, k_pos=None,
                       enc_out=None, cache=None, index=None):
    self_cache = cache["self"] if cache is not None else None
    h, new_self = L.attn_apply(
        bp["self"], cfg, L.norm_apply(bp["ln1"], cfg, x),
        mask=mask, q_pos=q_pos, k_pos=k_pos,
        cache=self_cache, cache_index=index,
    )
    x = constrain(x + h, "acts")
    cross_cache = cache["cross"] if cache is not None else None
    h, _ = L.attn_apply(
        bp["cross"], cfg, L.norm_apply(bp["ln2"], cfg, x),
        kv_x=enc_out, cache=cross_cache,
        static_cache=cross_cache is not None,
    )
    x = constrain(x + h, "acts")
    y = L.mlp_apply(bp["mlp"], cfg, L.norm_apply(bp["ln3"], cfg, x))
    return constrain(x + y, "acts"), new_self, 0.0


# --------------------------------------------------------------------------- #
# model init
# --------------------------------------------------------------------------- #


_BLOCK_INIT = {
    Family.DENSE: _dense_block_init,
    Family.VLM: _dense_block_init,
    Family.MOE: _moe_block_init,
    Family.SSM: _ssm_block_init,
}


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, n_tail_rec) for the hybrid pattern scan."""
    period = len(cfg.hybrid.pattern)
    n_groups = cfg.n_layers // period
    return n_groups, cfg.n_layers - n_groups * period


def init_model(key, cfg: ModelConfig, abstract: bool = False):
    ks = jax.random.split(key, 8)
    params: Params = {}
    axes: Params = {}

    p, a = _maybe(ks[0], lambda k: L.embed_init(k, cfg), abstract)
    params["embed"], axes["embed"] = p, a
    p, a = _maybe(ks[1], lambda k: L.norm_init(cfg), abstract)
    params["final_norm"], axes["final_norm"] = p, a

    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE, Family.SSM):
        init_fn = functools.partial(_BLOCK_INIT[cfg.family], cfg=cfg)
        params["layers"], axes["layers"] = _stack_init(
            lambda k: init_fn(k), ks[2], cfg.n_layers, abstract
        )
    elif cfg.family == Family.HYBRID:
        n_groups, n_tail = hybrid_layout(cfg)

        def group_init(k):
            k1, k2 = jax.random.split(k)
            rec_p, rec_a = _stack_init(
                lambda kk: _rec_block_init(kk, cfg), k1, 2, abstract=False
            )
            att_p, att_a = _dense_block_init(k2, cfg)
            return {"rec": rec_p, "att": att_p}, {"rec": rec_a, "att": att_a}

        params["groups"], axes["groups"] = _stack_init(
            group_init, ks[2], n_groups, abstract
        )
        if n_tail:
            params["tail"], axes["tail"] = _stack_init(
                lambda k: _rec_block_init(k, cfg), ks[3], n_tail, abstract
            )
    elif cfg.family == Family.AUDIO:
        params["enc_layers"], axes["enc_layers"] = _stack_init(
            lambda k: _dense_block_init(k, cfg.replace(rope_style="none")),
            ks[2], cfg.n_encoder_layers, abstract,
        )
        params["dec_layers"], axes["dec_layers"] = _stack_init(
            lambda k: _xattn_block_init(k, cfg), ks[3], cfg.n_layers, abstract
        )
        p, a = _maybe(ks[4], lambda k: L.norm_init(cfg), abstract)
        params["enc_norm"], axes["enc_norm"] = p, a

        def pos_init(k):
            enc = L._init_dense(k, (cfg.encoder_seq_len, cfg.d_model),
                                L.dtype_of(cfg.param_dtype), scale=0.02)
            return enc, ("positions", "embed")

        p, a = _maybe(ks[5], pos_init, abstract)
        params["enc_pos"], axes["enc_pos"] = p, a

        if cfg.decoder_pos_len:
            def dpos_init(k):
                dec = L._init_dense(k, (cfg.decoder_pos_len, cfg.d_model),
                                    L.dtype_of(cfg.param_dtype), scale=0.02)
                return dec, ("positions", "embed")

            p, a = _maybe(ks[6], dpos_init, abstract)
            params["dec_pos"], axes["dec_pos"] = p, a
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    return params, axes


# --------------------------------------------------------------------------- #
# forward (train / full-sequence)
# --------------------------------------------------------------------------- #


def _rope_for(cfg: ModelConfig, positions: jax.Array):
    if cfg.rope_style == "none":
        return None
    return L.rope_tables(positions, L.rotary_dim_of(cfg), cfg.rope_theta)


def _scan_blocks(cfg: ModelConfig, stacked, x, body):
    """scan over stacked layer params; body(bp, x) -> (x, aux)."""

    def f(carry, bp):
        xx, aux = carry
        xx, aux_d = body(bp, xx)
        return (xx, aux + aux_d), None

    f = _remat(cfg, f)
    if cfg.scan_layers:
        (x, aux), _ = lax.scan(f, (x, 0.0), stacked)
    else:
        carry = (x, 0.0)
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            bp = jax.tree.map(lambda t: t[i], stacked)
            carry, _ = f(carry, bp)
        x, aux = carry
    return x, aux



def _scan_or_unroll(cfg: ModelConfig, body, carry, xs):
    """lax.scan when cfg.scan_layers, else an unrolled python loop (used by
    the dry-run cost probes -- XLA's cost_analysis counts while-loop bodies
    once, so probes compile unrolled at reduced depth)."""
    if cfg.scan_layers:
        return lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *x: jnp.stack(x), *ys)
    else:
        ys = None
    return carry, ys


def _scan_blocks_cached(cfg: ModelConfig, stacked, cache, x, body):
    """scan over (stacked params, stacked cache); body -> (x, new_c, aux)."""

    def f(carry, xs):
        xx, aux = carry
        bp, c = xs
        xx, new_c, aux_d = body(bp, xx, c)
        return (xx, aux + aux_d), new_c

    f = _remat(cfg, f)
    (x, aux), new_cache = _scan_or_unroll(cfg, f, (x, 0.0), (stacked, cache))
    return x, aux, new_cache


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    cache=None,
):
    """Full-sequence forward -> (hidden (B,S,D), aux_loss[, new_cache]).

    With ``cache`` (prefill mode) the per-layer k/v / recurrent states are
    written in the same pass (single-pass prefill; no recompute)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], cfg, tokens)
    x = constrain(x, "acts")
    prefix_len = 0

    if cfg.family == Family.VLM:
        patches = batch["patches"].astype(x.dtype)  # SigLIP stub embeddings
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
        S = x.shape[1]

    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    rope = _rope_for(cfg, positions)
    q_pos = positions
    new_cache = None

    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        block = (_moe_block_apply if cfg.family == Family.MOE
                 else _dense_block_apply)
        mask = L.MaskSpec(causal=True, window=cfg.attn_window,
                          prefix_len=prefix_len)
        if cache is not None:
            S_cache = cache["k"].shape[2]
            k_pos = jnp.broadcast_to(
                jnp.arange(S_cache, dtype=jnp.int32)[None], (B, S_cache))
            body = lambda bp, xx, c: block(bp, cfg, xx, rope=rope, mask=mask,
                                           q_pos=q_pos, k_pos=k_pos,
                                           cache=c, index=0)
            x, aux, new_cache = _scan_blocks_cached(
                cfg, params["layers"], cache, x, body)
        else:
            body = lambda bp, xx: block(bp, cfg, xx, rope=rope, mask=mask,
                                        q_pos=q_pos, k_pos=q_pos)[::2]
            x, aux = _scan_blocks(cfg, params["layers"], x, body)

    elif cfg.family == Family.SSM:
        if cache is not None:
            body = lambda bp, xx, c: _ssm_block_apply(bp, cfg, xx, state=c)
            x, aux, new_cache = _scan_blocks_cached(
                cfg, params["layers"], cache, x, body)
        else:
            body = lambda bp, xx: _ssm_block_apply(bp, cfg, xx)[::2]
            x, aux = _scan_blocks(cfg, params["layers"], x, body)

    elif cfg.family == Family.HYBRID:
        window = cfg.attn_window
        mask = L.MaskSpec(causal=True, window=window)

        if cache is not None:
            W = cache["groups"]["att"]["k"].shape[2]

            def group_body(gp, xx, c):
                def rec_body(bp, xxx, st):
                    return _rec_block_apply(bp, cfg, xxx, state=st)

                xx, _, new_rec = _scan_blocks_cached(
                    cfg.replace(remat="none"), gp["rec"], c["rec"], xx, rec_body)
                # local attention + ring-buffer write of the last W positions
                xx2, _, _ = _dense_block_apply(gp["att"], cfg, xx,
                                               rope=rope, mask=mask,
                                               q_pos=q_pos, k_pos=q_pos)
                cd = L.dtype_of(cfg.compute_dtype)
                xn = L.norm_apply(gp["att"]["ln1"], cfg, xx)
                k = jnp.einsum("bsd,dhk->bshk", xn.astype(cd),
                               gp["att"]["attn"]["wk"].astype(cd))
                v = jnp.einsum("bsd,dhk->bshk", xn.astype(cd),
                               gp["att"]["attn"]["wv"].astype(cd))
                if rope is not None:
                    k = L.apply_rope(k, *rope, cfg.rope_style)
                SS = k.shape[1]
                take = min(W, SS)
                pos0 = SS - take
                slots = (pos0 + jnp.arange(take)) % W
                new_k = c["att"]["k"].at[:, slots].set(
                    k[:, -take:].astype(c["att"]["k"].dtype))
                new_v = c["att"]["v"].at[:, slots].set(
                    v[:, -take:].astype(c["att"]["v"].dtype))
                return xx2, {"rec": new_rec,
                             "att": {"k": new_k, "v": new_v}}, 0.0

            x, aux, new_groups = _scan_blocks_cached(
                cfg, params["groups"], cache["groups"], x, group_body)
            new_cache = {"groups": new_groups}
            if "tail" in params:
                body = lambda bp, xx, st: _rec_block_apply(bp, cfg, xx, state=st)
                x, _, new_tail = _scan_blocks_cached(
                    cfg, params["tail"], cache["tail"], x, body)
                new_cache["tail"] = new_tail
        else:
            def group_body2(gp, xx):
                def rec_body(bp, xxx):
                    return _rec_block_apply(bp, cfg, xxx)[::2]
                xx, _ = _scan_blocks(cfg.replace(remat="none"), gp["rec"],
                                     xx, rec_body)
                xx, _, _ = _dense_block_apply(gp["att"], cfg, xx,
                                              rope=rope, mask=mask,
                                              q_pos=q_pos, k_pos=q_pos)
                return xx, 0.0

            x, aux = _scan_blocks(cfg, params["groups"], x, group_body2)
            if "tail" in params:
                body = lambda bp, xx: _rec_block_apply(bp, cfg, xx)[::2]
                x, tail_aux = _scan_blocks(cfg, params["tail"], x, body)
                aux = aux + tail_aux

    elif cfg.family == Family.AUDIO:
        if "dec_pos" in params:
            x = x + params["dec_pos"].astype(x.dtype)[None, :S]
        enc = encode(params, cfg, batch["frames"])
        mask = L.MaskSpec(causal=True)
        if cache is not None:
            S_cache = cache["self"]["k"].shape[2]
            k_pos = jnp.broadcast_to(
                jnp.arange(S_cache, dtype=jnp.int32)[None], (B, S_cache))
            body = lambda bp, xx, c: (
                lambda r: (r[0], {"self": r[1], "cross": c["cross"]}, r[2])
            )(_xattn_block_apply(bp, cfg, xx, mask=mask, q_pos=q_pos,
                                 k_pos=k_pos, enc_out=enc, cache=c, index=0))
            x, aux, new_cache = _scan_blocks_cached(
                cfg, params["dec_layers"], cache, x, body)
        else:
            body = lambda bp, xx: _xattn_block_apply(
                bp, cfg, xx, mask=mask, q_pos=q_pos, k_pos=q_pos,
                enc_out=enc)[::2]
            x, aux = _scan_blocks(cfg, params["dec_layers"], x, body)
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], cfg, x)
    if cfg.family == Family.VLM:
        x = x[:, prefix_len:]  # loss only over text positions
    if cache is not None:
        return x, aux, new_cache
    return x, aux


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, F, D)."""
    x = frames.astype(L.dtype_of(cfg.compute_dtype))
    x = x + params["enc_pos"].astype(x.dtype)[None, : x.shape[1]]
    B, F = x.shape[0], x.shape[1]
    mask = L.MaskSpec(everything=True)
    enc_cfg = cfg.replace(rope_style="none")
    body = lambda bp, xx: _dense_block_apply(
        bp, enc_cfg, xx, rope=None, mask=mask)[::2]
    x, _ = _scan_blocks(cfg, params["enc_layers"], x, body)
    return L.norm_apply(params["enc_norm"], cfg, x)


# --------------------------------------------------------------------------- #
# loss
# --------------------------------------------------------------------------- #


def _xent(params, cfg, hidden, labels):
    """Mean token cross-entropy; optionally chunked over sequence."""
    cd = L.dtype_of(cfg.compute_dtype)

    def chunk_loss(h_chunk, y_chunk):
        logits = L.unembed_apply(params["embed"], cfg, h_chunk)
        logits = constrain(logits, "logits").astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, y_chunk[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        correct = jnp.argmax(logits, axis=-1) == y_chunk
        return jnp.sum(lse - picked), jnp.sum(correct)

    B, S, _ = hidden.shape
    if cfg.logits_chunk and S % cfg.logits_chunk == 0 and S > cfg.logits_chunk:
        n = S // cfg.logits_chunk
        hs = hidden.reshape(B, n, cfg.logits_chunk, -1).swapaxes(0, 1)
        ys = labels.reshape(B, n, cfg.logits_chunk).swapaxes(0, 1)

        def f(acc, xs):
            h, y = xs
            ls, cs = jax.checkpoint(chunk_loss)(h, y)
            return (acc[0] + ls, acc[1] + cs), None

        (loss_sum, correct), _ = lax.scan(f, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ys))
    else:
        loss_sum, correct = chunk_loss(hidden, labels)

    denom = jnp.float32(B * S)
    return loss_sum / denom, correct / denom


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    hidden, aux = forward(params, cfg, batch)
    loss, acc = _xent(params, cfg, hidden, batch["labels"])
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux, "accuracy": acc}


# --------------------------------------------------------------------------- #
# caches + decode
# --------------------------------------------------------------------------- #


def _kv_cache_shape(cfg, n_layers, B, S):
    return {
        "k": jnp.zeros((n_layers, B, S, cfg.n_kv_heads, cfg.head_dim_),
                       L.dtype_of(cfg.compute_dtype)),
        "v": jnp.zeros((n_layers, B, S, cfg.n_kv_heads, cfg.head_dim_),
                       L.dtype_of(cfg.compute_dtype)),
    }


_KV_AXES = {"k": ("layers", "batch", None, "kv_heads", "head_dim"),
            "v": ("layers", "batch", None, "kv_heads", "head_dim")}


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               abstract: bool = False):
    """Decode cache + logical axes.  max_len = full context length."""
    B = batch_size

    def build():
        if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
            S = max_len + (cfg.n_vision_tokens if cfg.family == Family.VLM else 0)
            if cfg.attn_window:
                S = min(S, cfg.attn_window)
            return _kv_cache_shape(cfg, cfg.n_layers, B, S), dict(_KV_AXES)
        if cfg.family == Family.SSM:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            cache = {
                "conv": jnp.zeros((cfg.n_layers, B, s.conv_width - 1, d_in),
                                  L.dtype_of(cfg.compute_dtype)),
                "ssm": jnp.zeros((cfg.n_layers, B, d_in, s.state_dim), jnp.float32),
            }
            ax = {"conv": ("layers", "batch", None, "mlp"),
                  "ssm": ("layers", "batch", "mlp", "state")}
            return cache, ax
        if cfg.family == Family.HYBRID:
            h = cfg.hybrid
            w = h.lru_width or cfg.d_model
            n_groups, n_tail = hybrid_layout(cfg)
            W = min(max_len, cfg.attn_window or max_len)

            def rec_state(n_outer, n_inner=None):
                lead = (n_outer,) if n_inner is None else (n_outer, n_inner)
                return {
                    "conv": jnp.zeros(lead + (B, h.conv_width - 1, w),
                                      L.dtype_of(cfg.compute_dtype)),
                    "lru": jnp.zeros(lead + (B, w), jnp.float32),
                }

            def rec_axes(extra):
                return {"conv": extra + ("batch", None, "mlp"),
                        "lru": extra + ("batch", "mlp")}

            cache = {
                "groups": {
                    "rec": rec_state(n_groups, 2),
                    "att": _kv_cache_shape(cfg, n_groups, B, W),
                },
            }
            ax = {
                "groups": {
                    "rec": rec_axes(("layers", None)),
                    "att": dict(_KV_AXES),
                },
            }
            if n_tail:
                cache["tail"] = rec_state(n_tail)
                ax["tail"] = rec_axes(("layers",))
            return cache, ax
        if cfg.family == Family.AUDIO:
            cache = {
                "self": _kv_cache_shape(cfg, cfg.n_layers, B, max_len),
                "cross": _kv_cache_shape(cfg, cfg.n_layers, B, cfg.encoder_seq_len),
            }
            ax = {"self": dict(_KV_AXES), "cross": dict(_KV_AXES)}
            return cache, ax
        raise ValueError(cfg.family)  # pragma: no cover

    if abstract:
        cap = {}

        def w():
            c, a = build()
            cap["a"] = a
            return c

        cache = jax.eval_shape(w)
        return cache, cap["a"]
    return build()


def decode_step(params: Params, cfg: ModelConfig, cache,
                tokens: jax.Array, index: jax.Array):
    """One-token decode.  tokens: (B, 1); index: position of the new token in
    the context -- a scalar shared by all rows, or a (B,) vector of per-row
    positions (continuous batching with staggered admissions).
    Returns (new_cache, logits (B, 1, V))."""
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], cfg, tokens)
    index = jnp.asarray(index, jnp.int32)
    if cfg.family == Family.VLM:
        index = index + cfg.n_vision_tokens  # cache slots are absolute
    if index.ndim:
        positions = jnp.reshape(index, (B, 1))
    else:
        positions = jnp.full((B, 1), index, jnp.int32)
    rope = _rope_for(cfg, positions)
    q_pos = positions

    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        S_cache = cache["k"].shape[2]
        if cfg.attn_window and S_cache <= cfg.attn_window:
            # ring-buffer slots; all slots <= index are valid within window
            slot = index % S_cache
            k_pos = jnp.broadcast_to(jnp.arange(S_cache, dtype=jnp.int32)[None],
                                     (B, S_cache))
            # slot i holds position: latest p <= index with p % S == i
            # (positions broadcasts (B, 1) against (B, S) for both index kinds)
            k_pos = positions - ((positions - k_pos) % S_cache)
            write_index = slot
        else:
            k_pos = jnp.broadcast_to(jnp.arange(S_cache, dtype=jnp.int32)[None],
                                     (B, S_cache))
            write_index = index
        mask = L.MaskSpec(causal=True, window=cfg.attn_window)

        block = (_moe_block_apply if cfg.family == Family.MOE
                 else _dense_block_apply)

        def f(xx, xs):
            bp, c = xs
            xx, new_kv, _ = block(bp, cfg, xx, rope=rope, mask=mask,
                                  q_pos=q_pos, k_pos=k_pos,
                                  cache=c, index=write_index)
            return xx, new_kv

        x, new_cache = _scan_or_unroll(cfg, f, x, (params["layers"], cache))

    elif cfg.family == Family.SSM:
        def f(xx, xs):
            bp, c = xs
            xx, new_state, _ = _ssm_block_apply(bp, cfg, xx, state=c)
            return xx, new_state

        x, new_cache = _scan_or_unroll(cfg, f, x, (params["layers"], cache))

    elif cfg.family == Family.HYBRID:
        W = cache["groups"]["att"]["k"].shape[2]
        slot = index % W
        k_pos = jnp.broadcast_to(jnp.arange(W, dtype=jnp.int32)[None], (B, W))
        k_pos = positions - ((positions - k_pos) % W)
        mask = L.MaskSpec(causal=True, window=cfg.attn_window)

        def group_f(xx, xs):
            gp, c = xs

            def rec_f(xxx, rs):
                bp, st = rs
                xxx, new_st, _ = _rec_block_apply(bp, cfg, xxx, state=st)
                return xxx, new_st

            xx, new_rec = _scan_or_unroll(cfg, rec_f, xx, (gp["rec"], c["rec"]))
            xx, new_kv, _ = _dense_block_apply(
                gp["att"], cfg, xx, rope=rope, mask=mask,
                q_pos=q_pos, k_pos=k_pos,
                cache=c["att"], index=slot)
            return xx, {"rec": new_rec, "att": new_kv}

        x, new_groups = _scan_or_unroll(
            cfg, group_f, x, (params["groups"], cache["groups"]))
        new_cache = {"groups": new_groups}
        if "tail" in cache:
            def rec_f(xx, rs):
                bp, st = rs
                xx, new_st, _ = _rec_block_apply(bp, cfg, xx, state=st)
                return xx, new_st

            x, new_tail = _scan_or_unroll(
                cfg, rec_f, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

    elif cfg.family == Family.AUDIO:
        if "dec_pos" in params:
            if index.ndim:
                x = x + jnp.take(params["dec_pos"], index,
                                 axis=0)[:, None].astype(x.dtype)
            else:
                x = x + lax.dynamic_slice_in_dim(
                    params["dec_pos"], index, 1, axis=0).astype(x.dtype)[None]
        S_cache = cache["self"]["k"].shape[2]
        k_pos = jnp.broadcast_to(jnp.arange(S_cache, dtype=jnp.int32)[None],
                                 (B, S_cache))
        mask = L.MaskSpec(causal=True)

        def f(xx, xs):
            bp, c = xs
            xx, new_self, _ = _xattn_block_apply(
                bp, cfg, xx, mask=mask, q_pos=q_pos, k_pos=k_pos,
                cache=c, index=index)
            return xx, {"self": new_self, "cross": c["cross"]}

        x, new_cache = _scan_or_unroll(cfg, f, x, (params["dec_layers"], cache))
    else:  # pragma: no cover
        raise ValueError(cfg.family)

    x = L.norm_apply(params["final_norm"], cfg, x)
    logits = L.unembed_apply(params["embed"], cfg, x)
    return new_cache, logits


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], cache):
    """Run the full prompt, fill the cache, return (cache, last-token logits).

    Single-pass: cache writes happen inside the same forward (no recompute).
    Decode equivalence is asserted in tests.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape

    if cfg.family == Family.AUDIO:
        enc = encode(params, cfg, batch["frames"])
        # precompute per-layer cross k/v into the cache
        cd = L.dtype_of(cfg.compute_dtype)

        def xkv(carry, bp):
            k = jnp.einsum("bsd,dhk->bshk", enc.astype(cd), bp["cross"]["wk"].astype(cd))
            v = jnp.einsum("bsd,dhk->bshk", enc.astype(cd), bp["cross"]["wv"].astype(cd))
            return carry, {"k": k, "v": v}

        _, cross = _scan_or_unroll(cfg, xkv, 0, params["dec_layers"])
        cache = dict(cache)
        cache["cross"] = cross

    hidden, _, new_cache = forward(params, cfg, batch, cache=cache)
    logits = L.unembed_apply(params["embed"], cfg, hidden[:, -1:])
    return new_cache, logits
