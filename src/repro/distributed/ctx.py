"""Activation-sharding context.

Model code calls ``constrain(x, kind)`` at block boundaries; the launcher
installs the active rules (mesh + PartitionSpecs per activation kind) via the
``use_rules`` context manager.  Outside any context it is the identity, so
single-device tests and examples need no mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def _rules() -> Optional[Dict[str, object]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Dict[str, object]):
    """rules: {"acts": PartitionSpec, "logits": PartitionSpec, ...}."""
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shmap_info():
    """(dp_axes, tp_axis, mesh) for explicit shard_map regions, or None."""
    rules = _rules()
    if rules and "shmap" in rules:
        info = rules["shmap"]
        return info["dp"], info["tp"], info["mesh"]
    return None


def data_parallel_groups() -> int:
    """Number of data-parallel shards the launcher runs with (used by the
    capacity-MoE dispatch to keep routing device-local); 1 outside a mesh."""
    rules = _rules()
    if rules and "dp_groups" in rules:
        return int(rules["dp_groups"])  # type: ignore[arg-type]
    return 1


def constrain(x: jax.Array, kind: str) -> jax.Array:
    rules = _rules()
    if not rules or kind not in rules:
        return x
    spec = rules[kind]
    if isinstance(spec, (int, dict)):
        return x
    pspec = getattr(spec, "spec", spec)  # NamedSharding -> its PartitionSpec
    ndim = getattr(x, "ndim", None)
    try:
        if ndim is not None and len(pspec) > ndim:
            return x
    except TypeError:
        pass
    return jax.lax.with_sharding_constraint(x, spec)
