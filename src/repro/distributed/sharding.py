"""Logical-axis -> mesh sharding rules (DP / TP / EP / ZeRO / FSDP / pod).

Models annotate parameters with logical axes ("embed", "heads", "mlp",
"experts", "vocab", ...).  A sharding *variant* maps logical axes onto mesh
axes; divisibility is checked per-tensor, replicating any axis that does not
divide evenly (e.g. kv_heads=2 on a 16-way model axis).

Variants (the software-densification DSE axis, DESIGN.md §4):
  tp      -- baseline: TP over "model" (heads/mlp/vocab), DP over pod+data;
             optimizer states follow parameters.
  zero1   -- tp + optimizer states additionally sharded over "data"
             (ZeRO stage 1).
  fsdp    -- zero1 + parameters themselves sharded over "data" on their
             largest replicated dim (ZeRO-3 / FSDP: XLA all-gathers per
             layer, enabling compute/comm overlap and per-chip fit for the
             67B/314B archs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SHARDING_VARIANTS = ("tp", "zero1", "fsdp")

# logical axis -> mesh axis for tensor-parallel dims
_TP_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",   # EP: experts over model axis when divisible,
                          # else TP falls through to the "mlp" dim
    "batch": "data",      # cache/batch leading dims
}

# logical axes never sharded
_REPLICATED = {"layers", "head_dim", "conv", "state", "positions",
               "mlp_block", None}


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    variant: str = "tp"
    multi_pod: bool = False

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for_tensor(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    sc: ShardingConfig,
    *,
    fsdp_this: bool = False,
) -> P:
    """PartitionSpec for one tensor given its logical axes."""
    assert len(shape) == len(axes), (shape, axes)
    entries: list = []
    used = set()
    model_wanted_failed = False
    for dim, ax in zip(shape, axes):
        mesh_ax: Optional[str] = None
        if ax == "batch":
            # batch dims shard over the full data-parallel hierarchy
            total = 1
            for a in sc.data_axes:
                total *= _axis_size(mesh, a)
            if dim % total == 0 and not used.intersection(sc.data_axes):
                entries.append(sc.data_axes if len(sc.data_axes) > 1
                               else sc.data_axes[0])
                used.update(sc.data_axes)
                continue
            entries.append(None)
            continue
        if ax not in _REPLICATED:
            cand = _TP_RULES.get(ax)
            if cand is not None and cand not in used:
                if dim % _axis_size(mesh, cand) == 0:
                    mesh_ax = cand
                elif cand == "model":
                    model_wanted_failed = True
        entries.append(mesh_ax)
        if mesh_ax is not None:
            used.add(mesh_ax)

    if model_wanted_failed and "model" not in used:
        # PaLM-style fallback: when kv_heads (MQA/GQA < TP degree) cannot be
        # sharded, shard the head_dim instead -- keeps KV caches and k/v
        # projections distributed rather than replicated TP-degree times.
        for i, (dim, ax) in enumerate(zip(shape, axes)):
            if (ax == "head_dim" and entries[i] is None
                    and dim % _axis_size(mesh, "model") == 0):
                entries[i] = "model"
                used.add("model")
                break

    if fsdp_this:
        # shard the largest still-replicated dim over "data"
        dsize = _axis_size(mesh, "data")
        best, best_dim = -1, 0
        for i, (dim, e) in enumerate(zip(shape, entries)):
            if e is None and dim % dsize == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0:
            entries[best] = "data"
    return P(*entries)


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)
    )


def param_specs(
    params: Any, axes: Any, mesh: Mesh, sc: ShardingConfig,
    *, fsdp: Optional[bool] = None, min_fsdp_size: int = 2 ** 20,
) -> Any:
    """Pytree of NamedSharding for a (params, axes) pair.

    fsdp: shard big replicated dims over "data" too (defaults to the
    variant's behaviour); small tensors (< min_fsdp_size elements) stay
    replicated to avoid pathological tiny collectives.
    """
    if fsdp is None:
        fsdp = sc.variant == "fsdp"

    def one(p, a):
        size = 1
        for d in p.shape:
            size *= d
        spec = spec_for_tensor(
            p.shape, tuple(a), mesh, sc,
            fsdp_this=fsdp and size >= min_fsdp_size,
        )
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, params, axes)


def opt_state_specs(
    params: Any, axes: Any, mesh: Mesh, sc: ShardingConfig,
    *, min_fsdp_size: int = 2 ** 20,
) -> Any:
    """Adam moment shardings: ZeRO-1+ shards them over "data" as well."""
    zero = sc.variant in ("zero1", "fsdp")
    return param_specs(params, axes, mesh, sc, fsdp=zero,
                       min_fsdp_size=min_fsdp_size)


def batch_spec(mesh: Mesh, sc: ShardingConfig, ndim: int = 2,
               batch_size: Optional[int] = None) -> NamedSharding:
    """Token batches: (B, S, ...) with B over pod+data (replicated when the
    global batch does not divide the data-parallel world, e.g. long_500k)."""
    total = 1
    for a in sc.data_axes:
        total *= _axis_size(mesh, a)
    if batch_size is not None and batch_size % total != 0:
        return NamedSharding(mesh, P(*([None] * ndim)))
    lead = sc.data_axes if len(sc.data_axes) > 1 else sc.data_axes[0]
    return NamedSharding(mesh, P(lead, *([None] * (ndim - 1))))


def activation_rules(mesh: Mesh, sc: ShardingConfig,
                     kind: str = "train") -> Dict[str, NamedSharding]:
    """Rules consumed by repro.distributed.ctx.constrain.

    Full-sequence kinds (train/prefill) shard the residual stream's sequence
    dim over "model" between blocks (Megatron-style sequence parallelism):
    layer-boundary activations and scan carries shrink by the TP degree,
    which is what lets the 32k-seq cells fit 16 GB/chip.  XLA inserts the
    all-gather before attention/MLP and the reduce-scatter after -- the
    collective cost shows up in the interconnect roofline term where the
    congruence profiler can see it.
    """
    lead = sc.data_axes if len(sc.data_axes) > 1 else sc.data_axes[0]
    seq = "model" if kind in ("train", "prefill") else None
    dp_groups = 1
    for a in sc.data_axes:
        dp_groups *= mesh.shape[a]
    return {
        "acts": NamedSharding(mesh, P(lead, seq, None)),
        "logits": NamedSharding(mesh, P(lead, None, "model")),
        "moe_tokens": NamedSharding(mesh, P(lead, None, None)),
        "ssm_state": NamedSharding(mesh, P(lead, "model", None)),
        "lru_state": NamedSharding(mesh, P(lead, "model")),
        "lru_seq": NamedSharding(mesh, P(None, lead, "model")),
        "ssm_chunks_d": NamedSharding(mesh, P(None, None, lead, "model")),
        "dp_groups": dp_groups,
        "shmap": {"dp": sc.data_axes, "tp": "model", "mesh": mesh},
    }


def scalar_spec(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
