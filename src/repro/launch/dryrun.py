"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline/congruence profile.

jax locks the device count at first backend use, and the dry-run needs 512
placeholder host devices so ``jax.make_mesh`` can build the 2x16x16
production mesh -- so this module requests them via ``XLA_FLAGS`` before
anything imports jax.  The request APPENDS to whatever flags the caller
already exported (it used to overwrite them), and ``main`` fails loudly via
``ensure_host_device_count`` if jax was initialized with fewer devices
before the request landed.

The extraction itself (``run_cell`` and friends) lives in
``repro.launch.extract``, which has no import-time side effects; the names
are re-exported here for callers.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch chatglm3-6b \
      --shape train_4k --mesh pod --variant fsdp
  PYTHONPATH=src python -m repro.launch.dryrun --list

Artifacts: one JSON WorkloadProfile per cell under --out
(default benchmarks/artifacts/), consumed by the congruence/roofline
benchmarks and EXPERIMENTS.md tables.
"""

from repro.launch import xla_flags

xla_flags.request_host_devices(512)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.launch import mesh as MESH  # noqa: E402
from repro.launch.extract import (  # noqa: E402,F401  (re-exported API)
    calibrate_costs,
    default_variant,
    run_cell,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", help="arch id(s); default all")
    ap.add_argument("--shape", action="append", help="shape id(s); default all")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--variant", default=None,
                    help="sharding variant (tp|zero1|fsdp); default per arch")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--moe-impl", default=None,
                    help="override MoE impl (gmm|dense|capacity)")
    ap.add_argument("--sp", choices=("on", "off"), default="on",
                    help="sequence-parallel activation sharding")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for cfg, shape, ok, reason in C.cells(
                tuple(args.arch) if args.arch else None,
                tuple(args.shape) if args.shape else None):
            status = "RUN" if ok else f"SKIP ({reason})"
            print(f"{cfg.name:22s} {shape.name:12s} {status}")
        return 0

    xla_flags.ensure_host_device_count(
        512 if args.mesh in ("multipod", "both") else 256)
    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod16x16", MESH.make_production_mesh(multi_pod=False),
                       False))
    if args.mesh in ("multipod", "both"):
        meshes.append(("pods2x16x16",
                       MESH.make_production_mesh(multi_pod=True), True))

    failures = []
    n_ok = n_skip = 0
    for cfg, shape, ok, reason in C.cells(
            tuple(args.arch) if args.arch else None,
            tuple(args.shape) if args.shape else None):
        if not ok:
            n_skip += 1
            print(f"SKIP {cfg.name}/{shape.name}: {reason}")
            continue
        variant = args.variant or default_variant(cfg)
        if args.moe_impl and cfg.moe is not None:
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                      impl=args.moe_impl))
        for mesh_label, mesh, multi_pod in meshes:
            print(f"== {cfg.name}/{shape.name} @ {mesh_label} [{variant}] ==",
                  flush=True)
            try:
                run_cell(cfg, shape, mesh, mesh_label, variant, args.out,
                         multi_pod=multi_pod, tag=args.tag,
                         sp=args.sp == "on")
                n_ok += 1
            except Exception as exc:  # noqa: BLE001
                failures.append((cfg.name, shape.name, mesh_label, repr(exc)))
                traceback.print_exc()
                if args.fail_fast:
                    return 1

    print(f"\ndry-run complete: {n_ok} cells compiled, {n_skip} skipped, "
          f"{len(failures)} failed")
    for f in failures:
        print("FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
