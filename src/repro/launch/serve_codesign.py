"""Co-design service launcher: the micro-batched scoring front door.

  PYTHONPATH=src python -m repro.launch.serve_codesign --smoke

Submits a mix of sweep / mega-sweep / frontier requests against one
``CodesignService``, streams mega-sweep shard progress, and prints each
response through the uniform result protocol plus the service's cache
accounting (population hits, memo hits, micro-batched requests, frontier
warm starts).  Validation happens at parse time via the one shared path
(``CodesignSpec.validate`` / ``validate_backend_arg``).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import CodesignSpec, WorkloadProfile
from repro.core.kernels_xp import validate_backend_arg
from repro.serving.codesign_service import CodesignRequest, CodesignService


def _suites(num_suites: int, apps: int):
    """Deterministic synthetic suites spanning the bottleneck spectrum."""
    out = []
    for s in range(num_suites):
        suite = []
        for a in range(apps):
            k = s * apps + a
            suite.append(WorkloadProfile(
                name=f"suite{s}/app{a}",
                flops=2e14 * (1 + 0.3 * (k % 5)),
                hbm_bytes=1.5e11 * (1 + 0.5 * (k % 3)),
                collective_bytes={"all-reduce": 2e10 * (1 + (k % 4))},
                num_devices=256, model_flops=5e16))
        out.append(suite)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny populations / few steps (CI mode)")
    ap.add_argument("--suites", type=int, default=4,
                    help="concurrent sweep requests (micro-batched)")
    ap.add_argument("--apps", type=int, default=3, help="apps per suite")
    ap.add_argument("--n", type=int, default=None,
                    help="sweep population size (default 256; smoke 32)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-pending", type=int, default=64)
    ap.add_argument("--backend", default=None,
                    help="kernel backend (numpy/jax/pallas)")
    ap.add_argument("--budgets", type=float, nargs="*",
                    default=[0.3, 0.6, 1.0], help="frontier area budgets")
    ap.add_argument("--steps", type=int, default=None,
                    help="frontier descent steps (default 40; smoke 4)")
    ap.add_argument("--format", choices=("markdown", "json"),
                    default="markdown")
    ap.add_argument("--top-k", type=int, default=5)
    args = ap.parse_args(argv)
    validate_backend_arg(ap, args.backend)

    n = args.n if args.n is not None else (32 if args.smoke else 256)
    steps = args.steps if args.steps is not None else (4 if args.smoke else 40)
    # Parse-time validation through the one shared path: a bad spec dies
    # here with a usage error, before any service work starts.
    try:
        sweep_spec = CodesignSpec(n=n, seed=0, backend=args.backend).validate()
        frontier_spec = CodesignSpec(
            budgets=args.budgets, steps=steps,
            refine_steps=max(steps // 5, 1)).validate()
    except ValueError as exc:
        ap.error(str(exc))

    svc = CodesignService(workers=args.workers, max_pending=args.max_pending,
                          auto_start=False)
    suites = _suites(args.suites, args.apps)
    t0 = time.perf_counter()

    # Burst of concurrent sweeps: compatible requests ride one SoA pass.
    sweep_jids = [svc.submit(CodesignRequest(kind="sweep", profiles=s,
                                             spec=sweep_spec))
                  for s in suites]
    # A mega-sweep streams shard progress; a frontier seeds the warm cache.
    mega_jid = svc.submit(CodesignRequest(
        kind="mega_sweep", profiles=suites[0], spec=sweep_spec,
        num_shards=4))
    frontier_jid = svc.submit(CodesignRequest(
        kind="frontier", profiles=suites[0][:1], spec=frontier_spec))
    svc.drain()

    for ev in svc.stream(mega_jid):
        if ev["event"] == "shard":
            print(f"mega-sweep shard {ev['shard'] + 1}/{ev['num_shards']} "
                  f"variants [{ev['lo']}, {ev['hi']})")

    # A tighter follow-up schedule warm-starts from the solved frontier.
    warm_jid = svc.submit(CodesignRequest(
        kind="frontier", profiles=suites[0][:1],
        spec=CodesignSpec(budgets=[min(args.budgets) * 0.8], steps=steps,
                          refine_steps=max(steps // 5, 1))))
    svc.drain()
    dt = time.perf_counter() - t0

    for label, jid in ([(f"sweep[{i}]", j)
                        for i, j in enumerate(sweep_jids)][:1]
                       + [("mega_sweep", mega_jid),
                          ("frontier", frontier_jid),
                          ("frontier+warm", warm_jid)]):
        out = svc.render(jid, fmt=args.format, top_k=args.top_k, timeout=5)
        print(f"\n== {label} ({svc.poll(jid)['cache'] or 'cold'}) ==")
        print(out if args.format == "markdown"
              else json.dumps(out, indent=1, default=str)[:2000])

    total = len(sweep_jids) + 3
    print(f"\nserved {total} requests in {dt:.2f}s "
          f"({total / dt:.1f} req/s); stats: {dict(svc.stats)}")
    svc.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
