"""XLA_FLAGS plumbing for fake-host-device dry runs.

jax parses ``XLA_FLAGS`` once, when the backend first initializes, and the
device count is locked from then on.  The dry-run launchers need
``--xla_force_host_platform_device_count=N`` exported before that happens;
historically they *overwrote* ``XLA_FLAGS``, silently dropping any flags the
caller had exported.  ``request_host_devices`` appends instead, and
``ensure_host_device_count`` turns the late-import failure mode (jax already
initialized with too few devices -> cryptic mesh errors) into a loud,
actionable RuntimeError.

This module must stay importable without jax side effects: it only touches
``os.environ``; jax is imported lazily inside ``ensure_host_device_count``.
"""

from __future__ import annotations

import os
import re
from typing import Optional

HOST_PLATFORM_FLAG = "--xla_force_host_platform_device_count"

_FLAG_RE = re.compile(re.escape(HOST_PLATFORM_FLAG) + r"=(\d+)")


def requested_host_devices() -> Optional[int]:
    """Host-device count currently requested via XLA_FLAGS, if any."""
    m = _FLAG_RE.search(os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def request_host_devices(n: int) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS.

    Pre-existing flags are preserved (append, never overwrite).  If a
    host-platform count is already present it wins, whatever its value:
    jax has possibly initialized under it already, and two copies of the
    flag would be ambiguous.  Call ``ensure_host_device_count`` afterwards
    to verify the count actually in effect.
    """
    if requested_host_devices() is not None:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    flag = f"{HOST_PLATFORM_FLAG}={int(n)}"
    os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()


def ensure_host_device_count(n: int) -> None:
    """Fail loudly unless jax sees at least ``n`` devices.

    Calling this initializes jax's backend if it was not initialized yet,
    so call it only after ``request_host_devices``.
    """
    import jax

    have = jax.device_count()
    if have < int(n):
        raise RuntimeError(
            f"this run needs {n} devices but jax initialized with {have} "
            f"(XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r}). jax locks "
            f"the device count at first use; export "
            f"{HOST_PLATFORM_FLAG}={n} (or import the launcher) before "
            f"anything touches jax devices in this process."
        )
