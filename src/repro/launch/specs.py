"""ShapeDtypeStruct stand-ins for every model input (the dry-run contract).

``input_specs(cfg, shape, mesh, sc)`` returns (args, in_shardings,
out_shardings, step_fn, meta) for the cell's step function -- weak-type
correct, shardable, no device allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec, tokens_of
from repro.distributed import sharding as SH
from repro.models import transformer as T
from repro.models.config import Family, ModelConfig
from repro.optim import adamw
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.step import init_state, make_train_step


def _sds(tree: Any, shardings: Any) -> Any:
    """Attach shardings to a pytree of ShapeDtypeStructs."""
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings,
    )


def _batch_struct(cfg: ModelConfig, shape: ShapeSpec, seq_len: int,
                  batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if cfg.family == Family.AUDIO:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    if cfg.family == Family.VLM:
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return out


@dataclasses.dataclass
class CellSpec:
    step_fn: Any
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def input_specs(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    sc: SH.ShardingConfig,
    oc: Optional[adamw.OptimizerConfig] = None,
) -> CellSpec:
    oc = oc or adamw.OptimizerConfig()
    key = jax.random.PRNGKey(0)
    total, active = cfg.param_counts()
    tokens = tokens_of(cfg, shape)
    meta = {
        "params": total,
        "params_active": active,
        "tokens": tokens,
        "step_kind": shape.kind,
    }

    state, axes = init_state(key, cfg, oc, abstract=True)
    p_shard = SH.param_specs(state["params"], axes, mesh, sc)
    o_shard = {
        "m": SH.opt_state_specs(state["opt"]["m"], axes, mesh, sc),
        "v": SH.opt_state_specs(state["opt"]["v"], axes, mesh, sc),
        "step": SH.scalar_spec(mesh),
    }
    if "ef" in state["opt"]:
        o_shard["ef"] = SH.opt_state_specs(state["opt"]["ef"], axes, mesh, sc)

    if shape.kind == "train":
        batch = _batch_struct(cfg, shape, shape.seq_len, shape.global_batch)
        b_shard = jax.tree.map(
            lambda t: SH.batch_spec(mesh, sc, ndim=t.ndim, batch_size=t.shape[0]), batch)
        state_shard = {"params": p_shard, "opt": o_shard}
        args = (_sds(state, state_shard), _sds(batch, b_shard))
        metrics_shard = SH.scalar_spec(mesh)
        return CellSpec(
            step_fn=make_train_step(cfg, oc),
            args=args,
            in_shardings=(state_shard, b_shard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
            meta=meta,
        )

    # inference kinds -----------------------------------------------------
    params = state["params"]
    B = shape.global_batch
    cache, cache_axes = T.init_cache(cfg, B, shape.seq_len, abstract=True)
    c_shard = SH.param_specs(cache, cache_axes, mesh, sc, fsdp=False)

    if shape.kind == "prefill":
        batch = _batch_struct(cfg, shape, shape.seq_len, B)
        b_shard = jax.tree.map(
            lambda t: SH.batch_spec(mesh, sc, ndim=t.ndim, batch_size=t.shape[0]), batch)
        args = (_sds(params, p_shard), _sds(cache, c_shard),
                _sds(batch, b_shard))
        tok_out = NamedSharding(
            mesh, P(sc.data_axes if len(sc.data_axes) > 1 else sc.data_axes[0]))
        return CellSpec(
            step_fn=make_prefill_step(cfg),
            args=args,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(c_shard, tok_out),
            donate_argnums=(1,),
            meta=meta,
        )

    # decode: one new token with a KV cache of seq_len
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = SH.batch_spec(mesh, sc, ndim=2, batch_size=B)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    idx_shard = SH.scalar_spec(mesh)
    args = (_sds(params, p_shard), _sds(cache, c_shard),
            jax.ShapeDtypeStruct(tok.shape, tok.dtype, sharding=tok_shard),
            jax.ShapeDtypeStruct(idx.shape, idx.dtype, sharding=idx_shard))
    tok_out = NamedSharding(mesh, SH.batch_spec(mesh, sc, ndim=1,
                                                 batch_size=B).spec)
    return CellSpec(
        step_fn=make_serve_step(cfg),
        args=args,
        in_shardings=(p_shard, c_shard, tok_shard, idx_shard),
        out_shardings=(c_shard, tok_out),
        donate_argnums=(1,),
        meta=meta,
    )
