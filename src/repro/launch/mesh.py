"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benchmarks) sees the real single device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

DEVICES_PER_POD = 256  # 16 x 16


def _mesh_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType only exists on newer jax; older releases default
    # every axis to Auto, which is exactly what we want anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with the same Auto axis types (tests use small ones)."""
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_variant_mesh(num_devices: Optional[int] = None):
    """1-D ``("variants",)`` mesh over every local device.

    The mega-sweep data layout: the machine-variant axis is embarrassingly
    parallel (profiles replicated, variants split), so ``shard_sweep``
    wants all devices on one axis regardless of the production 2-D/3-D
    topology.  ``Backend.sharded_stats`` consumes this mesh for both the
    NamedSharding (jax) and shard_map (pallas) distribution strategies.
    """
    ndev = int(num_devices or max(1, len(jax.devices())))
    return make_mesh((ndev,), ("variants",))


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient, across jax versions.

    Newer jax spells this ``jax.set_mesh``; on older releases the ``Mesh``
    object itself is the context manager (legacy resource env).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
