"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benchmarks) sees the real single device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

DEVICES_PER_POD = 256  # 16 x 16


def _mesh_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType only exists on newer jax; older releases default
    # every axis to Auto, which is exactly what we want anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with the same Auto axis types (tests use small ones)."""
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient, across jax versions.

    Newer jax spells this ``jax.set_mesh``; on older releases the ``Mesh``
    object itself is the context manager (legacy resource env).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
