"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benchmarks) sees the real single device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

DEVICES_PER_POD = 256  # 16 x 16


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with the same Auto axis types (tests use small ones)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
