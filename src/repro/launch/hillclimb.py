import os

from repro.launch import xla_flags

xla_flags.request_host_devices(512)

"""Hillclimb tooling: measured substitution of the Pallas flash-attention
kernel into a dry-run profile.

The CPU dry-run artifact materializes (S x T) attention scores per layer (no
TPU fusion pipeline, no flash kernel -- Pallas can't compile for the CPU
backend).  On the TPU target, kernels/flash_attention.py keeps score tiles in
VMEM: per-layer attention HBM traffic collapses to the q/k/v/o streams.

Method (measured, not hand-modelled): attention-score traffic is the ONLY
HBM component quadratic in sequence length.  We compile three unrolled
depth-2 probes at S, S/2, S/4 and fit  h(s) = c + a*s + q*s^2 ; the
quadratic term q*S^2 is exactly the per-2-layer score traffic, which the
substitution removes and replaces with the kernel's linear q/k/v/o traffic.
FLOPs and collectives are untouched (the kernel does the same math; flash
backward recomputation is already covered by the remat-full baseline).

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch chatglm3-6b \
      --shape train_4k [--mesh pod] [--moe-impl capacity] --out DIR \
      [--sweep N [--backend jax]] [--grad STEPS]

Co-design modes (after the kernel substitution):
  --sweep N      score N generated machine variants (batched kernels,
                 --backend numpy|jax) and report best fit + Pareto front.
  --grad STEPS   continuous co-design: jax.grad of the scalarized
                 (congruence, area, power) objective through the shared
                 kernels_xp layer, descending machine log-rates from the
                 named-variant seeds.
  --area-budget B / --power-budget P
                 constrain --grad to CostModel.area(m) <= B (and/or
                 power <= P) via repro.core.constrained; --constraint-mode
                 picks projected gradient (default) or augmented
                 Lagrangian, --opt-links relaxes ici_links continuously
                 and rounds with repair.
  --joint        joint (machine, sharding-variant) descent: compiles the
                 cell under every sharding variant (tp/zero1/fsdp) and
                 lets the descent pick per machine variant.  The kernel
                 substitution applies to the primary --variant cell only;
                 the other shardings enter as baseline compiles.
  --budget-sweep LO:HI:N
                 trace the feasibility frontier J*(budget) over N area
                 budgets from LO to HI by warm-started continuation
                 (repro.core.frontier) instead of a single budgeted run.
  --area-envelope K=V[,K=V...]
                 per-subsystem area envelopes (e.g. peak_flops=1.5,
                 hbm_bw=0.8) added as one constraint per entry to --grad
                 descent or to every --budget-sweep point.
  --pack M       multi-tenant packing: place the optimized profile plus
                 --pack-gen generated co-tenant workloads across M
                 machine instances (repro.core.packing); scalar budgets
                 read as fleet TOTALS in this mode.
"""

import argparse
import dataclasses
import json
import sys
import time

import numpy as np

import jax

from repro import configs as C
from repro.configs.shapes import ShapeSpec, resolve_shape
from repro.core import costs as CO
from repro.core import machine as M
from repro.core import roofline as R
from repro.distributed import ctx as CTX
from repro.distributed import sharding as SH
from repro.launch import mesh as MESH
from repro.launch.extract import (
    _cost_dict,
    _probe_cfg,
    default_variant,
    run_cell,
)
from repro.launch.specs import input_specs
from repro.models.config import Family


def _probe_hbm(cfg, shape, mesh, sc, seq_len: int, batch: int,
               state_dim: int = 0) -> float:
    pshape = ShapeSpec(shape.name, seq_len, batch, shape.kind)
    pcfg = _probe_cfg(cfg, 2)
    if state_dim and pcfg.ssm is not None:
        pcfg = pcfg.replace(
            ssm=dataclasses.replace(pcfg.ssm, state_dim=state_dim))
    cell = input_specs(pcfg, pshape, mesh, sc)
    with MESH.use_mesh(mesh), CTX.use_rules(
            SH.activation_rules(mesh, sc, kind=shape.kind)):
        compiled = jax.jit(
            cell.step_fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.args).compile()
    return _cost_dict(compiled, 0)["hbm"]


def quadratic_attention_bytes(cfg, shape, mesh, sc) -> float:
    """q*S^2 for the 2-layer probe: measured score-related HBM traffic."""
    S, B = shape.seq_len, shape.global_batch
    ss = np.array([S, S // 2, S // 4], dtype=np.float64)
    hs = np.array([_probe_hbm(cfg, shape, mesh, sc, int(s), B) for s in ss])
    coeffs = np.polyfit(ss, hs, 2)  # [q, a, c]
    q = max(coeffs[0], 0.0)
    return float(q * S * S)


def flash_kernel_bytes_per_layer(cfg, shape, n_dev: int) -> float:
    """Linear q/k/v/o HBM traffic of the Pallas kernel (fwd+bwd), per device."""
    B, S = shape.global_batch, shape.seq_len
    bytes_q = B * S * cfg.q_dim * 2       # bf16
    bytes_kv = 2 * B * S * cfg.kv_dim * 2
    # fwd: read q,k,v write o ; bwd: read q,k,v,o,do write dq,dk,dv (+lse)
    total = 4 * (bytes_q * 2 + bytes_kv) if shape.kind == "train" else (
        bytes_q * 2 + bytes_kv)
    return total / n_dev


def scan_state_bytes(cfg, shape, mesh, sc) -> float:
    """Measured HBM traffic proportional to the SSM state dim N for the
    2-layer probe: exactly the dA/dBx/h chunk buffers the Pallas
    selective-scan kernel keeps in VMEM."""
    N = cfg.ssm.state_dim
    S, B = shape.seq_len, shape.global_batch
    h_full = _probe_hbm(cfg, shape, mesh, sc, S, B, state_dim=N)
    h_half = _probe_hbm(cfg, shape, mesh, sc, S, B, state_dim=N // 2)
    per_n = (h_full - h_half) / (N - N // 2)
    return max(per_n * N, 0.0)


def scan_kernel_bytes_per_layer(cfg, shape, n_dev: int) -> float:
    """Linear xi/dt/B/C/y traffic of the Pallas scan kernel, per device."""
    B, S = shape.global_batch, shape.seq_len
    d_in = cfg.ssm.expand * cfg.d_model
    n = cfg.ssm.state_dim
    io = B * S * (3 * d_in + 2 * n) * 2  # xi, dt, y (d_in) + B, C (n), bf16
    mult = 3.0 if shape.kind == "train" else 1.0
    return io * mult / n_dev


def machine_candidates(n: int, seed: int = 0):
    """Candidate generator for the co-design step: the paper's three named
    variants plus ``n`` low-discrepancy designs from the default ParamSpace.

    The named variants come first so the batched default-beta reference
    stays the baseline chip (same convention as ``dse.evaluate``)."""
    from repro.core.sweep import MachineBatch, ParamSpace

    return MachineBatch.concat(
        MachineBatch.from_models(M.VARIANTS),
        ParamSpace.default().sample(n, seed=seed))


def codesign_sweep(profile, n: int, seed: int = 0,
                   backend: str = None) -> dict:
    """Score one profile against a sweep population and summarize the
    co-design answer: best-fit variant + (area, congruence) Pareto front."""
    from repro.core.sweep import batched_congruence

    machines = machine_candidates(n, seed=seed)
    res = batched_congruence([profile], machines, clamp=True,
                             backend=backend)
    best = int(res.best_fit_indices()[0])
    front = res.pareto_front()
    return {
        "num_variants": len(machines),
        "backend": res.backend,
        "best_variant": machines.names[best],
        "best_aggregate": float(res.aggregate[0, best]),
        "best_params": machines.params_row(best),
        "pareto": [
            {"variant": machines.names[i],
             "area": float(res.area()[i]),
             "aggregate": float(res.aggregate[0, i])}
            for i in front],
    }


def codesign_grad(profile, steps: int, lr: float = 0.1,
                  area_budget: float = None, power_budget: float = None,
                  constraint_mode: str = "projected",
                  opt_links: bool = False, area_envelope: dict = None,
                  sensitivities: bool = False) -> dict:
    """Gradient co-design: descend the scalarized (congruence, area, power)
    objective from the named-variant seeds by jax.grad through the shared
    kernels (``repro.core.codesign``); the optimized continuous designs
    answer "where should the machine move?" rather than "which sampled
    point wins?".  With a budget (scalar area/power and/or a
    per-subsystem envelope) the descent is constrained
    (``repro.core.constrained``): projected-gradient or augmented-
    Lagrangian, optionally relaxing ici_links with rounding-and-repair."""
    from repro.core.codesign import grad_codesign
    from repro.core.constrained import constrained_codesign
    from repro.core.sweep import MachineBatch

    seeds = MachineBatch.from_models(M.VARIANTS)
    if area_budget is None and power_budget is None and not area_envelope:
        res = grad_codesign([profile], seeds, steps=steps, lr=lr)
    else:
        res = constrained_codesign(
            [profile], seeds, steps=steps, lr=lr, area_budget=area_budget,
            power_budget=power_budget, area_envelope=area_envelope,
            mode=constraint_mode, optimize_links=opt_links)
    out = res.to_json()
    if sensitivities and (area_budget is not None
                          or power_budget is not None or area_envelope):
        # KKT shadow prices at the optimum (repro.core.implicit): which
        # budget is worth relaxing, and by how much per unit of budget.
        from repro.core.implicit import sensitivities_of
        rep = sensitivities_of(res, [profile])
        out["sensitivities"] = rep.to_json()
    return out


def codesign_bilevel(profile, total_budget: float, steps: int,
                     lr: float = 0.1, area_envelope: dict = None):
    """Bilevel budget descent (``repro.core.implicit``): outer descent on
    the area/power split of one total silicon budget, differentiated
    through the inner constrained optimum by the implicit custom-VJP."""
    from repro.core.implicit import bilevel_codesign
    from repro.core.sweep import MachineBatch

    return bilevel_codesign(
        [profile], MachineBatch.from_models(M.VARIANTS),
        total_budget=total_budget, steps=steps, lr=lr,
        area_envelope=area_envelope)


def codesign_frontier(profile, budgets, steps: int, lr: float = 0.1,
                      power_budget: float = None,
                      area_envelope: dict = None):
    """Feasibility frontier J*(budget) from the named-variant seeds
    (``repro.core.frontier``): one warm-started continuation over the
    budget schedule instead of one cold constrained run per budget."""
    from repro.core.frontier import frontier_codesign
    from repro.core.sweep import MachineBatch

    return frontier_codesign(
        [profile], MachineBatch.from_models(M.VARIANTS), budgets,
        steps=steps, lr=lr, power_budget=power_budget,
        area_envelope=area_envelope)


def codesign_joint(profile_group, steps: int, lr: float = 0.1,
                   area_budget: float = None,
                   power_budget: float = None) -> dict:
    """Joint (machine, sharding-variant) co-design over one app's group of
    sharding-variant profiles (``repro.core.constrained.joint_codesign``,
    alternation mode), optionally under the same budgets."""
    from repro.core.constrained import joint_codesign
    from repro.core.sweep import MachineBatch

    res = joint_codesign([profile_group],
                         MachineBatch.from_models(M.VARIANTS),
                         steps=steps, lr=lr, area_budget=area_budget,
                         power_budget=power_budget)
    return res.to_json()


def codesign_pack(profile, num_machines: int, gen: int = 31,
                  lr: float = None, area_budget: float = None,
                  power_budget: float = None, area_envelope: dict = None):
    """Multi-tenant packing: place the optimized profile plus ``gen``
    generated co-tenant stress workloads across ``num_machines`` machine
    instances (``repro.core.packing.pack_codesign``).  Scalar budgets
    read as fleet TOTALS here, not per-machine caps -- the question is
    "how should a shared fleet split its silicon across tenants?"."""
    from repro.core.model_zoo import resolve_suite
    from repro.core.packing import pack_codesign
    from repro.core.sweep import MachineBatch

    apps = [profile] + (resolve_suite(f"gen:{gen}") if gen > 0 else [])
    return pack_codesign(apps, MachineBatch.from_models(M.VARIANTS),
                         num_machines=num_machines, lr=lr,
                         area_budget=area_budget, power_budget=power_budget,
                         area_envelope=area_envelope)


def attention_layers(cfg) -> int:
    if cfg.family == Family.HYBRID:
        from repro.models.transformer import hybrid_layout
        n_groups, _ = hybrid_layout(cfg)
        return n_groups
    if cfg.family == Family.AUDIO:
        return cfg.n_layers * 2 + cfg.n_encoder_layers  # self+cross / enc
    if cfg.family == Family.SSM:
        return 0
    return cfg.n_layers


def parse_budget_sweep(parser, spec):
    """``LO:HI:N`` -> N evenly spaced area budgets, validated at parse
    time (like ``--backend``) so a bogus schedule fails before any
    compile work."""
    if spec is None:
        return None
    parts = spec.split(":")
    if len(parts) != 3:
        parser.error(f"--budget-sweep expects LO:HI:N, got {spec!r}")
    try:
        lo, hi, n = float(parts[0]), float(parts[1]), int(parts[2])
    except ValueError:
        parser.error(f"--budget-sweep expects numeric LO:HI:N, got {spec!r}")
    if not 0.0 < lo < hi:
        parser.error(f"--budget-sweep needs 0 < LO < HI, got {spec!r}")
    if n < 2:
        parser.error(f"--budget-sweep needs N >= 2 budgets, got {n}")
    return [float(b) for b in np.linspace(lo, hi, n)]


def parse_area_envelope(parser, spec):
    """``K=V[,K=V...]`` -> validated envelope dict (keys checked against
    the cost model's rate fields at parse time)."""
    if spec is None:
        return None
    from repro.core.constrained import validate_area_envelope

    env = {}
    for item in spec.split(","):
        key, sep, value = item.partition("=")
        if not sep:
            parser.error(f"--area-envelope expects K=V[,K=V...], "
                         f"got {item!r}")
        try:
            env[key.strip()] = float(value)
        except ValueError:
            parser.error(f"--area-envelope value for {key.strip()!r} must "
                         f"be a number, got {value!r}")
    try:
        return validate_area_envelope(env)
    except ValueError as exc:
        parser.error(str(exc))


def validate_codesign_args(parser, args) -> None:
    """Reject inconsistent co-design flags at parse time (like --backend):
    budgets must be positive, and every constrained/joint flag needs the
    --grad mode it modifies -- not an error minutes into compile work."""
    for name, value in (("--area-budget", args.area_budget),
                        ("--power-budget", args.power_budget)):
        if value is not None and not value > 0.0:
            parser.error(f"{name} must be positive, got {value}")
    budget_sweep = getattr(args, "budget_sweep", None)
    envelope = getattr(args, "area_envelope", None)
    pack = getattr(args, "pack", 0) or 0
    if pack < 0 or getattr(args, "pack_gen", 0) < 0:
        parser.error("--pack/--pack-gen must be non-negative")
    has_budget = (args.area_budget is not None
                  or args.power_budget is not None or envelope is not None)
    if (args.joint or args.opt_links
            or args.constraint_mode or budget_sweep is not None) \
            and not args.grad:
        parser.error("--constraint-mode/--opt-links/--joint/--budget-sweep "
                     "require --grad STEPS")
    if has_budget and not args.grad and not pack:
        parser.error("--area-budget/--power-budget/--area-envelope "
                     "require --grad STEPS or --pack M")
    if pack and (args.grad or args.joint or budget_sweep is not None
                 or args.opt_links or args.constraint_mode):
        parser.error("--pack is its own co-design mode (fleet-total "
                     "budgets); drop --grad/--joint/--budget-sweep/"
                     "--opt-links/--constraint-mode")
    if (args.constraint_mode or args.opt_links) \
            and not has_budget and budget_sweep is None:
        parser.error("--constraint-mode/--opt-links require "
                     "--area-budget and/or --power-budget")
    if args.joint and (args.constraint_mode or args.opt_links):
        parser.error("--joint supports budgets only through the projected "
                     "retraction; drop --constraint-mode/--opt-links")
    if budget_sweep is not None:
        if args.area_budget is not None:
            parser.error("--budget-sweep IS the area-budget axis; "
                         "drop --area-budget")
        if args.joint or args.opt_links or args.constraint_mode:
            parser.error("--budget-sweep traces the frontier by projected "
                         "continuation; drop --joint/--opt-links/"
                         "--constraint-mode")
    if args.joint and envelope is not None:
        parser.error("--joint does not support --area-envelope; use scalar "
                     "--area-budget/--power-budget")
    bilevel = getattr(args, "bilevel", None)
    if bilevel is not None:
        if not bilevel > 0.0:
            parser.error(f"--bilevel must be positive, got {bilevel}")
        if not args.grad:
            parser.error("--bilevel requires --grad STEPS (inner solves)")
        if args.area_budget is not None or args.power_budget is not None:
            parser.error("--bilevel derives the area/power budgets from "
                         "the learned split; drop --area-budget/"
                         "--power-budget")
        if args.joint or args.opt_links or args.constraint_mode \
                or budget_sweep is not None or pack:
            parser.error("--bilevel is its own co-design mode; drop "
                         "--joint/--opt-links/--constraint-mode/"
                         "--budget-sweep/--pack")
    if getattr(args, "sensitivities", False):
        if not args.grad:
            parser.error("--sensitivities requires --grad STEPS")
        if args.joint:
            parser.error("--sensitivities does not support --joint "
                         "(per-variant selection has no single optimum "
                         "to differentiate through)")
        if not has_budget and budget_sweep is None and bilevel is None:
            parser.error("--sensitivities needs a constraint to price; "
                         "add --area-budget/--power-budget/"
                         "--area-envelope")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("pod", "multipod"), default="pod")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--out", default="benchmarks/artifacts_opt")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--mode", choices=("flash", "scan"), default="flash")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch (fast "
                         "compiles; CI exercises the full pipeline)")
    ap.add_argument("--sp", choices=("on", "off"), default="on")
    ap.add_argument("--sweep", type=int, default=0, metavar="N",
                    help="after substitution, sweep N generated machine "
                         "variants and report the best fit + Pareto front")
    ap.add_argument("--sweep-seed", type=int, default=0)
    ap.add_argument("--backend", default=None,
                    help="kernel backend for the co-design sweep "
                         "(numpy/jax/pallas or any registered name; "
                         "default: $REPRO_SWEEP_BACKEND, then numpy)")
    ap.add_argument("--grad", type=int, default=0, metavar="STEPS",
                    help="after substitution, gradient co-design: optimize "
                         "machine log-rates from the named-variant seeds by "
                         "jax.grad of the scalarized (congruence, area, "
                         "power) objective for STEPS steps")
    ap.add_argument("--grad-lr", type=float, default=0.1,
                    help="initial log-rate step size for --grad")
    ap.add_argument("--area-budget", type=float, default=None, metavar="B",
                    help="constrain --grad descent to CostModel.area <= B "
                         "(repro.core.constrained)")
    ap.add_argument("--power-budget", type=float, default=None, metavar="P",
                    help="constrain --grad descent to CostModel.power <= P")
    ap.add_argument("--constraint-mode", default=None,
                    choices=("projected", "lagrangian"),
                    help="budgeted-descent algorithm (default: projected); "
                         "requires --area-budget/--power-budget")
    ap.add_argument("--opt-links", action="store_true",
                    help="relax ici_links continuously during --grad and "
                         "round with repair (requires a budget)")
    ap.add_argument("--joint", action="store_true",
                    help="joint (machine, sharding-variant) descent: "
                         "compile every sharding variant and let --grad "
                         "choose per machine variant")
    ap.add_argument("--budget-sweep", default=None, metavar="LO:HI:N",
                    help="trace the feasibility frontier J*(budget) over N "
                         "area budgets from LO to HI (warm-started "
                         "continuation; requires --grad, replaces "
                         "--area-budget)")
    ap.add_argument("--area-envelope", default=None, metavar="K=V[,K=V...]",
                    help="per-subsystem area envelopes for --grad / "
                         "--budget-sweep, e.g. peak_flops=1.5,hbm_bw=0.8 "
                         "(keys from repro.core.costmodel.RATE_FIELDS)")
    ap.add_argument("--sensitivities", action="store_true",
                    help="after a budgeted --grad run, report KKT shadow "
                         "prices and dJ*/d(budget) at the optimum "
                         "(repro.core.implicit); with --budget-sweep the "
                         "frontier rows carry them automatically")
    ap.add_argument("--bilevel", type=float, default=None, metavar="T",
                    help="bilevel budget descent: split one total silicon "
                         "budget T between area and power by outer "
                         "descent through the inner constrained optimum "
                         "(implicit custom-VJP gradient; requires --grad "
                         "STEPS for the inner solves)")
    ap.add_argument("--pack", type=int, default=0, metavar="M",
                    help="multi-tenant packing: place the optimized "
                         "profile plus --pack-gen generated co-tenants "
                         "across M machine instances "
                         "(repro.core.packing); --area-budget/"
                         "--power-budget read as fleet TOTALS")
    ap.add_argument("--pack-gen", type=int, default=31, metavar="N",
                    help="generated co-tenant workloads for --pack "
                         "(AppSpace.default Halton suite gen:N; 0 packs "
                         "the substituted profile alone)")
    args = ap.parse_args(argv)
    # Fail at parse time with the registry's current contents, not deep
    # inside get_backend() after minutes of compile work.
    from repro.core.kernels_xp import validate_backend_arg
    validate_backend_arg(ap, args.backend)
    budgets = parse_budget_sweep(ap, args.budget_sweep)
    envelope = parse_area_envelope(ap, args.area_envelope)
    validate_codesign_args(ap, args)

    cfg = C.get_config(args.arch, smoke=args.smoke)
    if args.moe_impl and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl=args.moe_impl))
    shape = resolve_shape(args.shape)  # assigned SHAPES or a zoo-grid shape
    multi_pod = args.mesh == "multipod"
    xla_flags.ensure_host_device_count(512 if multi_pod else 256)
    mesh = MESH.make_production_mesh(multi_pod=multi_pod)
    mesh_label = "pods2x16x16" if multi_pod else "pod16x16"
    variant = args.variant or default_variant(cfg)
    sc = SH.ShardingConfig(variant=variant, multi_pod=multi_pod)
    tag = args.tag or args.mode

    if args.mode == "flash" and attention_layers(cfg) == 0:
        print("arch is attention-free; flash substitution not applicable")
        return 1
    if args.mode == "scan" and cfg.ssm is None:
        print("arch has no SSM; scan substitution not applicable")
        return 1

    # 1. baseline cell (compile + calibrate) -- the pre-substitution profile
    profile = run_cell(cfg, shape, mesh, mesh_label, variant, None,
                       multi_pod=multi_pod, verbose=False)
    before = R.analyze(profile, M.TPU_V5E)
    print("before:", before.one_liner())

    # 2. measured traffic isolation + kernel substitution
    t0 = time.time()
    if args.mode == "flash":
        quad2 = quadratic_attention_bytes(cfg, shape, mesh, sc)
        L_att = attention_layers(cfg)
        per_layer = quad2 / 2.0
        removed = per_layer * L_att
        added = flash_kernel_bytes_per_layer(cfg, shape, mesh.size) * L_att
        n_layers = L_att
    else:
        per2 = scan_state_bytes(cfg, shape, mesh, sc)
        per_layer = per2 / 2.0
        removed = per_layer * cfg.n_layers
        added = scan_kernel_bytes_per_layer(
            cfg, shape, mesh.size) * cfg.n_layers
        n_layers = cfg.n_layers
    new_hbm = max(profile.hbm_bytes - removed + added, added)
    print(f"measured fit: {time.time()-t0:.1f}s  kernel-replaced "
          f"traffic/layer {per_layer/1e9:.2f} GB -> kernel "
          f"{added/max(n_layers,1)/1e9:.3f} GB")

    profile.hbm_bytes = new_hbm
    profile.meta[f"{args.mode}_substitution"] = {
        "removed_bytes": removed, "added_bytes": added, "layers": n_layers,
    }
    profile.name += f"+{args.mode}"
    after = R.analyze(profile, M.TPU_V5E)
    print("after: ", after.one_liner())

    if args.sweep > 0:
        # Co-design: which machine design fits the OPTIMIZED workload best?
        cd = codesign_sweep(profile, args.sweep, seed=args.sweep_seed,
                            backend=args.backend)
        profile.meta["codesign_sweep"] = cd
        print(f"codesign sweep over {cd['num_variants']} variants "
              f"({cd['backend']} backend): best={cd['best_variant']} "
              f"aggregate={cd['best_aggregate']:.4f} "
              f"pareto={len(cd['pareto'])} points")

    if args.grad > 0:
        if args.bilevel is not None:
            # Bilevel co-design: how should one silicon budget be SPLIT
            # between area and power?  Outer descent through the inner
            # optimum via the implicit-function-theorem gradient.
            bl = codesign_bilevel(profile, args.bilevel, args.grad,
                                  lr=args.grad_lr, area_envelope=envelope)
            profile.meta["bilevel_codesign"] = bl.to_json()
            print(f"bilevel codesign (total={args.bilevel:.4g}, "
                  f"{bl.outer_steps} outer steps): split "
                  f"{bl.split_trajectory[0]:.3f} -> {bl.split_final:.3f}, "
                  f"J* {bl.objective_trajectory[0]:.4f} -> "
                  f"{bl.objective_final:.4f} "
                  f"(+{bl.improvement_over_uniform:.4f} vs uniform split)")
        elif args.joint:
            # Joint co-design: which (machine, sharding) pair wins?  The
            # primary cell keeps its kernel substitution; the remaining
            # sharding variants enter as baseline compiles.
            group = [profile]
            for sv in SH.SHARDING_VARIANTS:
                if sv == variant:
                    continue
                alt = run_cell(cfg, shape, mesh, mesh_label, sv, None,
                               multi_pod=multi_pod, verbose=False)
                alt.name += f"@{sv}"
                group.append(alt)
            gd = codesign_joint(group, args.grad, lr=args.grad_lr,
                                area_budget=args.area_budget,
                                power_budget=args.power_budget)
            profile.meta["joint_codesign"] = gd
            print(f"joint codesign over {len(group)} shardings: "
                  f"best={gd['best_variant']} picks="
                  f"{gd['selection'][gd['best_variant']]}")
        elif budgets is not None:
            # Feasibility frontier: how much fabric does this workload
            # actually need?  One continuation over the budget schedule.
            fr = codesign_frontier(profile, budgets, args.grad,
                                   lr=args.grad_lr,
                                   power_budget=args.power_budget,
                                   area_envelope=envelope)
            profile.meta["frontier_codesign"] = fr.to_json()
            n_feas = int(fr.feasible.sum())
            knee = f"{fr.knee():.4g}" if n_feas else "n/a"
            print(f"frontier over {len(fr)} budgets "
                  f"[{fr.budgets[0]:.4g}, {fr.budgets[-1]:.4g}]: "
                  f"J* {fr.objective[-1]:.4f} (loosest) .. "
                  f"{fr.objective[0]:.4f} (tightest), "
                  f"feasible {n_feas}/{len(fr)}, knee={knee}")
            if args.sensitivities and fr.shadow_prices is not None:
                pts = ", ".join(
                    f"{b:.4g}->{p:.4f}"
                    for b, p in zip(fr.budgets, fr.shadow_prices[:, 0])
                    if np.isfinite(p))
                print(f"area shadow prices (budget -> -dJ*/db): {pts}")
        else:
            # Continuous co-design: in which direction should the machine
            # move (optionally under an area/power budget)?
            gd = codesign_grad(
                profile, args.grad, lr=args.grad_lr,
                area_budget=args.area_budget,
                power_budget=args.power_budget,
                constraint_mode=args.constraint_mode or "projected",
                opt_links=args.opt_links, area_envelope=envelope,
                sensitivities=args.sensitivities)
            profile.meta["grad_codesign"] = gd
            lines = ", ".join(
                f"{v['name']}: {v['objective_seed']:.4f}->"
                f"{v['objective_final']:.4f}" for v in gd["variants"])
            print(f"grad codesign ({gd['steps']} steps, {gd['mode']}): "
                  f"{lines}; best={gd['best_variant']}")
            if "feasibility" in gd:
                feas = gd["feasibility"]
                print(f"feasibility ({feas['mode']}): "
                      f"area_budget={feas['area_budget']} "
                      f"power_budget={feas['power_budget']} "
                      f"all_feasible={feas['all_feasible']}")
            if "sensitivities" in gd:
                sens = gd["sensitivities"]
                lines = "; ".join(
                    f"{v['name']}: " + ", ".join(
                        f"{c}={v['shadow_prices'][c]:.4f}"
                        for c in sens["constraints"])
                    + (f" (relax {v['best_relaxation']} first)"
                       if v["best_relaxation"] else "")
                    for v in sens["variants"])
                print(f"shadow prices (dJ*/d(budget), sign flipped): "
                      f"{lines}")

    if args.pack > 0:
        # Multi-tenant packing: how should a shared fleet split its
        # silicon across this workload and a generated stress population?
        pk = codesign_pack(profile, args.pack, gen=args.pack_gen,
                           lr=args.grad_lr, area_budget=args.area_budget,
                           power_budget=args.power_budget,
                           area_envelope=envelope)
        profile.meta["pack_codesign"] = pk.to_json(top_k=8)
        feas = ("" if pk.feasible is None
                else f", feasible={bool(pk.feasible)}")
        print(f"pack codesign: {len(pk.app_names)} apps across "
              f"{len(pk.machine_names)} machines ({pk.mode}): objective "
              f"{pk.objective_seed:.4f} -> {pk.objective_final:.4f}, "
              f"fleet area {pk.area_total:.3f}{feas}")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        fname = (f"{cfg.name}__{shape.name}__{mesh_label}__{variant}"
                 f"__{tag}.json")
        profile.save(os.path.join(args.out, fname))
    return 0


if __name__ == "__main__":
    sys.exit(main())
