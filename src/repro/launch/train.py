"""Production training launcher.

Wires the full stack: arch config -> mesh + sharding variant -> sharded
train state -> fault-tolerant Trainer (async checkpoints, restart, straggler
monitor) -> step-indexed data pipeline.  On a real fleet each host runs this
with JAX_COORDINATOR/process-env set and jax.distributed.initialize picks up
the pod topology; on CPU (this container) it runs the same code path on the
local device with the smoke config.

  PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --smoke \
      --steps 50 --seq-len 64 --batch 4
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

from repro import configs as C
from repro.data.pipeline import DataConfig
from repro.distributed import ctx as CTX
from repro.distributed import sharding as SH
from repro.launch import mesh as MESH
from repro.optim import adamw
from repro.training.step import init_state
from repro.training.trainer import Trainer, TrainerConfig


def maybe_init_distributed() -> None:
    """Multi-host init from standard env (no-op single-process)."""
    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        jax.distributed.initialize(
            coordinator_address=os.environ["JAX_COORDINATOR_ADDRESS"],
            num_processes=int(os.environ.get("JAX_NUM_PROCESSES", "1")),
            process_id=int(os.environ.get("JAX_PROCESS_ID", "0")),
        )


def pick_mesh(args):
    n = len(jax.devices())
    if args.mesh == "pod":
        return MESH.make_production_mesh(multi_pod=False), False
    if args.mesh == "multipod":
        return MESH.make_production_mesh(multi_pod=True), True
    # auto: largest (data, model) grid that fits the device count
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return MESH.make_mesh((n // model, model), ("data", "model")), False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="chatglm3-6b", choices=C.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", choices=("auto", "pod", "multipod"),
                    default="auto")
    ap.add_argument("--variant", choices=SH.SHARDING_VARIANTS, default="zero1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    maybe_init_distributed()
    cfg = C.get_config(args.arch, smoke=args.smoke)
    mesh, multi_pod = pick_mesh(args)
    sc = SH.ShardingConfig(variant=args.variant, multi_pod=multi_pod)
    oc = adamw.OptimizerConfig(peak_lr=args.peak_lr,
                               warmup_steps=max(args.steps // 10, 1),
                               total_steps=args.steps)

    # sharded state template for Trainer restore/placement
    state_t, axes = init_state(jax.random.PRNGKey(0), cfg, oc, abstract=True)
    shardings = {
        "params": SH.param_specs(state_t["params"], axes, mesh, sc),
        "opt": {
            "m": SH.opt_state_specs(state_t["opt"]["m"], axes, mesh, sc),
            "v": SH.opt_state_specs(state_t["opt"]["v"], axes, mesh, sc),
            "step": SH.scalar_spec(mesh),
        },
    }
    tc = TrainerConfig(total_steps=args.steps,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir, accum=args.accum)
    dc = DataConfig(seq_len=args.seq_len, global_batch=args.batch,
                    host_index=jax.process_index(),
                    host_count=jax.process_count())

    use_shardings = shardings if mesh.size > 1 else None
    trainer = Trainer(cfg, tc, dc, oc, shardings=use_shardings)
    with MESH.use_mesh(mesh), CTX.use_rules(
            SH.activation_rules(mesh, sc, kind="train")):
        out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"done: {out['steps']} steps, loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}, {out['restarts']} restarts, "
          f"{out['straggler_events']} stragglers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
