"""Compile-and-extract core of the dry-run pipeline (no env side effects).

``launch/dryrun.py`` owns the CLI and the ``XLA_FLAGS`` request for 512
fake host devices; this module owns the actual work -- lower + compile one
(architecture x shape x mesh) cell and extract its ``WorkloadProfile`` --
so in-process callers (``core/model_zoo.py``, tests) can reuse the exact
production extraction path without mutating the process environment at
import time.
"""

import dataclasses
import os
import time

import jax

from repro.core import costs as CO
from repro.core import machine as M
from repro.core import roofline as R
from repro.distributed import ctx as CTX
from repro.distributed import sharding as SH
from repro.launch import mesh as MESH
from repro.launch.specs import input_specs
from repro.models.config import Family


def default_variant(cfg) -> str:
    """Big archs need FSDP-style sharding to fit 16 GB/chip (DESIGN.md §6)."""
    total, _ = cfg.param_counts()
    return "fsdp" if total > 20e9 else "zero1"


# --------------------------------------------------------------------------- #
# Cost calibration (depth-extrapolated unrolled probes)
#
# XLA's cost_analysis counts a while-loop body ONCE, so a scan-over-layers
# model under-reports FLOPs/bytes/collectives by ~n_layers.  Per-layer costs
# are exactly linear in depth for homogeneous stacks, so we compile two (or
# three, for the heterogeneous hybrid) UNROLLED probes at reduced depth and
# full width/batch/mesh, and extrapolate:  total(L) = c(a) + (L-a)*body where
# body = (c(b)-c(a))/(b-a).  The full-depth scanned artifact is still what we
# ship (memory_analysis comes from it); only the cost terms are calibrated.
# Sequential SSM/LRU elementwise scans stay loops even in probes; their FLOPs
# are added analytically (DESIGN.md §2 note; < ~5% of model FLOPs).
# --------------------------------------------------------------------------- #


def _cost_dict(compiled, devices_per_pod) -> dict:
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else (cost_list or {})
    stats = CO.parse_hlo_stats(compiled.as_text(),
                               devices_per_pod=devices_per_pod)
    return {
        "flops": float(cost.get("flops", 0.0) or 0.0),
        "bytes": float(cost.get("bytes accessed", 0.0) or 0.0),
        "hbm": stats.hbm_bytes,
        "transc": float(cost.get("transcendentals", 0.0) or 0.0),
        "dot_flops": stats.dot_flops,
        "coll": dict(stats.collective_bytes),
        "pod_coll": stats.pod_collective_bytes,
    }


def _lincomb(*terms):
    """terms: (scale, cost_dict) pairs -> elementwise linear combination."""

    def comb(key):
        if key == "coll":
            kinds = set()
            for _, d in terms:
                kinds.update(d["coll"])
            return {k: sum(s * d["coll"].get(k, 0.0) for s, d in terms)
                    for k in kinds}
        return sum(s * d[key] for s, d in terms)

    return {k: comb(k) for k in ("flops", "bytes", "hbm", "transc",
                                 "dot_flops", "coll", "pod_coll")}


def _probe_cfg(cfg, depth):
    c = cfg.replace(n_layers=depth, scan_layers=False, logits_chunk=0,
                    attn_q_chunk=0)
    if cfg.family == Family.AUDIO:
        c = c.replace(n_encoder_layers=depth)
    if cfg.ssm is not None:
        c = c.replace(ssm=dataclasses.replace(cfg.ssm, scan_chunk=1 << 30))
    return c


def _analytic_scan_flops(cfg, shape) -> float:
    """FLOPs of the sequential elementwise recurrences (uncountable loops)."""
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd(+bwd recompute)
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    if cfg.family == Family.SSM:
        d_in = cfg.ssm.expand * cfg.d_model
        per_tok_layer = d_in * cfg.ssm.state_dim * 8.0
        return mult * tokens * cfg.n_layers * per_tok_layer
    if cfg.family == Family.HYBRID:
        w = cfg.hybrid.lru_width or cfg.d_model
        n_rec = sum(1 for i in range(cfg.n_layers)
                    if cfg.hybrid.pattern[i % len(cfg.hybrid.pattern)] == "rec")
        return mult * tokens * n_rec * w * 10.0
    return 0.0


def calibrate_costs(cfg, shape, mesh, mesh_label, sc, *, multi_pod,
                    verbose=True, rules_kind=None) -> dict:
    dpp = MESH.DEVICES_PER_POD if multi_pod else 0
    rules_kind = rules_kind or shape.kind

    def probe(depth):
        pcfg = _probe_cfg(cfg, depth)
        cell = input_specs(pcfg, shape, mesh, sc)
        with MESH.use_mesh(mesh), CTX.use_rules(
                SH.activation_rules(mesh, sc, kind=rules_kind)):
            compiled = jax.jit(
                cell.step_fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            ).lower(*cell.args).compile()
        return _cost_dict(compiled, dpp)

    t0 = time.time()
    if cfg.family == Family.HYBRID:
        from repro.models.transformer import hybrid_layout
        c3, c4, c6 = probe(3), probe(4), probe(6)
        n_groups, n_tail = hybrid_layout(cfg)
        # rec body = c4-c3; group body (2 rec + 1 att) = c6-c3
        total = _lincomb((1.0, c3), (float(n_groups - 1), c6),
                         (-float(n_groups - 1), c3),
                         (float(n_tail), c4), (-float(n_tail), c3))
    else:
        a, b = 2, 4
        ca, cb = probe(a), probe(b)
        L = cfg.n_layers
        scale = (L - a) / (b - a)
        total = _lincomb((1.0, ca), (scale, cb), (-scale, ca))
    total["flops"] += _analytic_scan_flops(cfg, shape)
    total["probe_seconds"] = time.time() - t0
    if verbose:
        print(f"  probes done in {total['probe_seconds']:.1f}s "
              f"(calibrated flops/dev {total['flops']:.3e})")
    return total


def run_cell(cfg, shape, mesh, mesh_label, variant, out_dir, *,
             multi_pod: bool, verbose: bool = True, calibrate: bool = True,
             tag: str = "", sp: bool = True):
    sc = SH.ShardingConfig(variant=variant, multi_pod=multi_pod)
    t0 = time.time()
    rules_kind = shape.kind if sp else "decode"  # "decode" = no seq sharding
    cell = input_specs(cfg, shape, mesh, sc)
    with MESH.use_mesh(mesh), CTX.use_rules(
            SH.activation_rules(mesh, sc, kind=rules_kind)):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost_list = compiled.cost_analysis()
    cost = (cost_list[0] if isinstance(cost_list, (list, tuple))
            else (cost_list or {}))
    n_dev = mesh.size
    model_flops = R.model_flops_for(
        params_active=cell.meta["params_active"],
        tokens=cell.meta["tokens"],
        step_kind="train" if shape.kind == "train" else "infer",
    )
    profile = CO.profile_from_compiled(
        f"{cfg.name}/{shape.name}@{mesh_label}",
        compiled,
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_label,
        step_kind=shape.kind,
        num_devices=n_dev,
        model_flops=model_flops,
        tokens=cell.meta["tokens"],
        params=cell.meta["params"],
        params_active=cell.meta["params_active"],
        compile_seconds=compile_s,
        devices_per_pod=MESH.DEVICES_PER_POD if multi_pod else 0,
        meta={"variant": variant},
    )

    if calibrate:
        raw = {"flops": profile.flops, "bytes": profile.bytes_accessed,
               "coll": dict(profile.collective_bytes)}
        cal = calibrate_costs(cfg, shape, mesh, mesh_label, sc,
                              multi_pod=multi_pod, verbose=verbose,
                              rules_kind=rules_kind)
        profile.flops = cal["flops"]
        profile.bytes_accessed = cal["bytes"]
        profile.hbm_bytes = cal["hbm"]
        profile.transcendentals = cal["transc"]
        profile.dot_flops = cal["dot_flops"]
        profile.collective_bytes = dict(cal["coll"])
        profile.pod_collective_bytes = cal["pod_coll"]
        profile.meta["raw_uncalibrated"] = raw
        profile.meta["probe_seconds"] = cal["probe_seconds"]
    if verbose:
        print(f"  memory_analysis: {mem}")
        print("  cost_analysis:", {k: v for k, v in (cost or {}).items()
                                   if k in ("flops", "bytes accessed",
                                            "transcendentals")})
        rep = R.analyze(profile, M.TPU_V5E)
        print("  " + rep.one_liner())
        print(f"  collectives/dev: "
              f"{ {k: f'{v/1e9:.3f}GB' for k, v in profile.collective_bytes.items() if v} }"
              f" pod-crossing: {profile.pod_collective_bytes/1e9:.3f}GB")
        print(f"  peak mem/dev: {profile.peak_memory_bytes/1e9:.2f} GB"
              f"  compile: {compile_s:.1f}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = (f"{cfg.name}__{shape.name}__{mesh_label}__{variant}"
                 f"{('__' + tag) if tag else ''}.json")
        profile.save(os.path.join(out_dir, fname))
    return profile
