"""Production serving launcher: continuous-batching engine over a mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b --smoke \
      --requests 6 --slots 2 --new-tokens 8
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from repro import configs as C
from repro.models import transformer as T
from repro.serving.engine import BatchedEngine, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="falcon-mamba-7b", choices=C.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = C.get_config(args.arch, smoke=args.smoke)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    engine = BatchedEngine(params, cfg, slots=args.slots,
                           max_len=args.max_len)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i, prompt=[(13 * i + j) % cfg.vocab_size for j in range(4)],
            max_new_tokens=args.new_tokens))
    t0 = time.perf_counter()
    engine.run_to_completion()
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests in {dt:.1f}s "
          f"({args.requests * args.new_tokens / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
