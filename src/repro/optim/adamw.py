"""AdamW with warmup-cosine schedule, global-norm clipping, and an optional
error-feedback int8 gradient-compression hook (distributed-optimization
trick; off by default -- see DESIGN.md §6)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False   # int8 quantize + error feedback


def schedule(step: jax.Array, oc: OptimizerConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - oc.warmup_steps)
        / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    decay = oc.min_lr_ratio + (1.0 - oc.min_lr_ratio) * cos
    return oc.peak_lr * warm * decay


def init(params: Any, oc: OptimizerConfig) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if oc.compress_grads:
        state["ef"] = jax.tree.map(zeros, params)  # error-feedback residual
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _compress(g: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 stochastic-free quantization with error feedback.

    Emulates a compressed all-reduce: the value that crosses the wire is the
    dequantized int8 tensor; the quantization error stays local in ``ef``.
    """
    gf = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def update(
    grads: Any,
    state: Dict[str, Any],
    params: Any,
    oc: OptimizerConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    step = state["step"] + 1

    if oc.compress_grads:
        pairs = jax.tree.map(_compress, grads, state["ef"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda pr: pr[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_ef = state.get("ef")

    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if oc.clip_norm else jnp.float32(1.0)
    lr = schedule(step, oc)

    bc1 = 1.0 - oc.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - oc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip_scale
        m = oc.b1 * m + (1.0 - oc.b1) * gf
        v = oc.b2 * v + (1.0 - oc.b2) * jnp.square(gf)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if new_ef is not None:
        new_state["ef"] = new_ef
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, stats
