"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16.  Mamba-1 architecture. [arXiv:2410.05355; unverified]"""

from repro.models.config import Family, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family=Family.SSM,
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    rope_style="none",
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    logits_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="falcon-mamba-smoke", n_layers=2, d_model=64, vocab_size=256,
    remat="none", logits_chunk=0, ssm=SSMConfig(state_dim=4, conv_width=4,
                                                expand=2),
)
