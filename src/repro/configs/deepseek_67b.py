"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400.  llama-style architecture. [arXiv:2401.02954; hf]"""

from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family=Family.DENSE,
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    mlp="swiglu",
    param_dtype="bfloat16",
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=256, remat="none", logits_chunk=0,
    param_dtype="float32",
)
