"""whisper-medium [audio]: 24L d_model=1024 16H d_ff=4096 vocab=51865.
Encoder-decoder; conv frontend is a STUB (input_specs provides precomputed
frame embeddings, (B, 1500, d_model)). [arXiv:2212.04356; unverified]"""

from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family=Family.AUDIO,
    n_layers=24,                # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    rope_style="none",          # whisper uses learned/sinusoidal positions
    norm="layernorm",
    mlp="gelu",
    encoder_seq_len=1500,
    decoder_pos_len=32768,   # sized for the decode_32k assigned shape (real: 448)
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="whisper-smoke", n_layers=2, n_encoder_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, remat="none",
    encoder_seq_len=16,
)
