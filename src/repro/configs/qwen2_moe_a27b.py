"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) d_ff=1408 (per routed
expert) vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.models.config import Family, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family=Family.MOE,
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=151936,
    qkv_bias=True,
    mlp="swiglu",
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared_experts=4, d_ff_shared=1408),
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="qwen2moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    vocab_size=256, remat="none", logits_chunk=0,
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=32,
                  n_shared_experts=2, d_ff_shared=32),
)
