"""Architecture config registry: ``--arch <id>`` resolution.

Each assigned architecture has one module with the exact published config
(``CONFIG``) plus a reduced same-family smoke config (``SMOKE``).
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

from repro.configs import (
    chatglm3_6b,
    deepseek_67b,
    falcon_mamba_7b,
    grok1_314b,
    paligemma_3b,
    qwen15_4b,
    qwen2_moe_a27b,
    qwen3_32b,
    recurrentgemma_9b,
    whisper_medium,
)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, tokens_of
from repro.models.config import ModelConfig

_MODULES = (
    chatglm3_6b,
    qwen3_32b,
    qwen15_4b,
    deepseek_67b,
    whisper_medium,
    recurrentgemma_9b,
    grok1_314b,
    qwen2_moe_a27b,
    paligemma_3b,
    falcon_mamba_7b,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKE_REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.SMOKE for m in _MODULES}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    reg = SMOKE_REGISTRY if smoke else REGISTRY
    try:
        return reg[arch]
    except KeyError as exc:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(REGISTRY)}") from exc


def cells(
    archs: Optional[Tuple[str, ...]] = None,
    shapes: Optional[Tuple[str, ...]] = None,
) -> Iterator[Tuple[ModelConfig, ShapeSpec, bool, Optional[str]]]:
    """All (arch x shape) cells: (config, shape, runnable, skip_reason)."""
    for arch in archs or ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in shapes or tuple(SHAPES):
            shape = SHAPES[shape_name]
            ok, reason = applicable(cfg, shape)
            yield cfg, shape, ok, reason


__all__ = [
    "ARCH_IDS",
    "REGISTRY",
    "SHAPES",
    "SMOKE_REGISTRY",
    "ShapeSpec",
    "applicable",
    "cells",
    "get_config",
    "tokens_of",
]
