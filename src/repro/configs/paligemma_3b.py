"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216.  SigLIP frontend is a STUB (input_specs provides 256
precomputed patch embeddings); gemma-style decoder. [arXiv:2407.07726; hf]"""

from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family=Family.VLM,
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    n_vision_tokens=256,
    logits_chunk=1024,
    attn_q_chunk=256,
)

SMOKE = CONFIG.replace(
    name="paligemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=160, vocab_size=256, remat="none", logits_chunk=0, n_vision_tokens=8,
)
