"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""

from repro.models.config import Family, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family=Family.MOE,
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=131072,
    attn_logit_softcap=30.0,    # grok caps attention logits
    mlp="geglu",                # grok uses gelu-gated expert MLPs
    param_dtype="bfloat16",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="grok-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    vocab_size=256, remat="none", logits_chunk=0, param_dtype="float32",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96),
)
