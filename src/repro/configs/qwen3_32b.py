"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
qk_norm, GQA, explicit head_dim=128. [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family=Family.DENSE,
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    mlp="swiglu",
    param_dtype="bfloat16",
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=160, vocab_size=256, remat="none", logits_chunk=0,
    param_dtype="float32",
)
