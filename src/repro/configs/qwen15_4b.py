"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
QKV bias (MHA: kv == q heads). [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family=Family.DENSE,
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    mlp="swiglu",
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=256, remat="none", logits_chunk=0,
)
