"""Assigned input shapes (identical across the 10 LM-family architectures).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token with a KV cache
of seq_len), not ``train_step``.  ``long_500k`` requires sub-quadratic
sequence mixing: it runs for the SSM/hybrid archs and is skipped (with the
reason recorded) for pure full-attention archs -- see DESIGN.md §5.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# --- model-zoo grid -------------------------------------------------------
#
# The zoo suite (core/model_zoo.py) profiles every registry config under
# three serving scenarios.  Each scenario maps to a step kind plus a small
# (seq_len, global_batch) grid; the full grid gives
# 10 archs x 3 scenarios x 4 shapes = 120 cells, the smoke grid one tiny
# single-device shape per scenario so the fast CI tier can recompile it.

ZOO_SCENARIOS: Tuple[str, ...] = ("train", "serve-prefill", "serve-decode")

_SCENARIO_KIND: Dict[str, str] = {
    "train": "train",
    "serve-prefill": "prefill",
    "serve-decode": "decode",
}

_ZOO_GRID: Dict[str, Tuple[Tuple[int, int], ...]] = {
    # scenario -> ((seq_len, global_batch), ...)
    "train": ((2_048, 64), (2_048, 256), (8_192, 64), (8_192, 256)),
    # prefill batches must split across the 16-way pod data axis
    "serve-prefill": ((4_096, 16), (4_096, 64), (32_768, 16), (32_768, 64)),
    "serve-decode": ((4_096, 32), (4_096, 256), (32_768, 32), (32_768, 256)),
}

_ZOO_SMOKE_GRID: Dict[str, Tuple[Tuple[int, int], ...]] = {
    "train": ((128, 8),),
    "serve-prefill": ((128, 4),),
    "serve-decode": ((128, 8),),
}


def scenario_kind(scenario: str) -> str:
    """Step kind (train|prefill|decode) for a zoo scenario name."""
    try:
        return _SCENARIO_KIND[scenario]
    except KeyError:
        raise ValueError(
            f"unknown zoo scenario {scenario!r}; "
            f"expected one of {sorted(_SCENARIO_KIND)}") from None


def zoo_shapes(scenario: str, *, smoke: bool = False) -> Tuple[ShapeSpec, ...]:
    """ShapeSpecs for one zoo scenario (the batch/seq grid)."""
    kind = scenario_kind(scenario)
    grid = (_ZOO_SMOKE_GRID if smoke else _ZOO_GRID)[scenario]
    prefix = "zoo_smoke" if smoke else "zoo"
    return tuple(
        ShapeSpec(f"{prefix}_{kind}_s{seq}_b{batch}", seq, batch, kind)
        for seq, batch in grid
    )


def resolve_shape(name: str) -> ShapeSpec:
    """Look up a shape by name across SHAPES and the zoo grids."""
    if name in SHAPES:
        return SHAPES[name]
    for smoke in (False, True):
        for scenario in ZOO_SCENARIOS:
            for shape in zoo_shapes(scenario, smoke=smoke):
                if shape.name == name:
                    return shape
    known = sorted(SHAPES) + [
        s.name for sc in ZOO_SCENARIOS
        for smoke in (False, True) for s in zoo_shapes(sc, smoke=smoke)
    ]
    raise KeyError(f"unknown shape {name!r}; known: {', '.join(known)}")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, Optional[str]]:
    """Whether this (arch, shape) cell is runnable, else the skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "full-attention architecture: 500k dense-KV decode is "
            "O(seq) per token with an unbounded window; assigned-shape rules "
            "direct skipping pure full-attention archs"
        )
    return True, None


def tokens_of(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Token count processed by one step (for MODEL_FLOPS)."""
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one new token per sequence
