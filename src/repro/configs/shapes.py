"""Assigned input shapes (identical across the 10 LM-family architectures).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token with a KV cache
of seq_len), not ``train_step``.  ``long_500k`` requires sub-quadratic
sequence mixing: it runs for the SSM/hybrid archs and is skipped (with the
reason recorded) for pure full-attention archs -- see DESIGN.md §5.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, Optional[str]]:
    """Whether this (arch, shape) cell is runnable, else the skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "full-attention architecture: 500k dense-KV decode is "
            "O(seq) per token with an unbounded window; assigned-shape rules "
            "direct skipping pure full-attention archs"
        )
    return True, None


def tokens_of(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Token count processed by one step (for MODEL_FLOPS)."""
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one new token per sequence
