"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
RoPE 2d (partial/interleaved rotary over half the head dim), GQA.
[arXiv:2406.12793; hf]"""

from repro.models.config import Family, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family=Family.DENSE,
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",          # GLM 2d rotary: first half of head_dim, interleaved
    qkv_bias=True,              # chatglm uses qkv bias (add_qkv_bias=True)
    mlp="swiglu",
    norm="rmsnorm",
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="chatglm3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=256, remat="none", logits_chunk=0,
)
