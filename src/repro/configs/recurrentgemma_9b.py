"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000.  RG-LRU + local attention, 1:2 (two recurrent blocks per
local-attention block), window 2048. [arXiv:2402.19427; unverified]"""

from repro.models.config import Family, HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family=Family.HYBRID,
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    attn_window=2048,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rec", "rec", "att"), lru_width=4096,
                        conv_width=4),
    logits_chunk=1024,
    attn_q_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab_size=256, attn_window=8, remat="none",
    logits_chunk=0, hybrid=HybridConfig(pattern=("rec", "rec", "att"),
                                        lru_width=64, conv_width=4),
)
