"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they run in
interpret mode, which executes the kernel body in Python for correctness
validation.  ``interpret=None`` auto-detects.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rms
from repro.kernels import selective_scan as _scan


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv,
        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    return _rms.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-6,
                     block_rows: int = 256,
                     interpret: Optional[bool] = None):
    return _rms.rmsnorm_residual(
        x, residual, scale, eps=eps, block_rows=block_rows,
        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def selective_scan(xi, dt_raw, Bm, Cm, A, h0=None, *, chunk: int = 256,
                   d_block: int = 512, interpret: Optional[bool] = None):
    return _scan.selective_scan(
        xi, dt_raw, Bm, Cm, A, h0, chunk=chunk, d_block=d_block,
        interpret=_auto_interpret(interpret))
