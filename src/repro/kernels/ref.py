"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` is the mathematically transparent implementation the kernels
are validated against (tests sweep shapes/dtypes with assert_allclose).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,                 # (B, H, S, D)
    k: jax.Array,                 # (B, K, T, D)
    v: jax.Array,                 # (B, K, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, S, D = q.shape
    _, K, T, _ = k.shape
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, K, G, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows produce uniform probs in softmax; zero them like the
    # kernel does (l == 0 -> output 0)
    any_live = jnp.any(mask, axis=-1)                    # (S,)
    probs = probs * any_live[:, None]
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, vf)
    return out.reshape(B, H, S, D).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_residual_ref(
    x: jax.Array, residual: jax.Array, scale: jax.Array, *, eps: float = 1e-6
) -> Tuple[jax.Array, jax.Array]:
    h = x.astype(jnp.float32) + residual.astype(jnp.float32)
    return rmsnorm_ref(h.astype(x.dtype), scale, eps=eps), h.astype(x.dtype)


def selective_scan_ref(
    xi: jax.Array,       # (B, S, Din)
    dt_raw: jax.Array,   # (B, S, Din)
    Bm: jax.Array,       # (B, S, N)
    Cm: jax.Array,       # (B, S, N)
    A: jax.Array,        # (Din, N)
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    B, S, Din = xi.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))
    Af = A.astype(jnp.float32)

    def step(h, t):
        dt_t, xi_t, b_t, c_t = t
        dA = jnp.exp(dt_t[..., None] * Af[None])            # (B, Din, N)
        dBx = (dt_t * xi_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (dt.swapaxes(0, 1), xi.astype(jnp.float32).swapaxes(0, 1),
          Bm.astype(jnp.float32).swapaxes(0, 1),
          Cm.astype(jnp.float32).swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(xi.dtype), hT
