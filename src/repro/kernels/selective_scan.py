"""Mamba-1 selective scan as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the CUDA mamba kernel holds per-thread state
in registers and parallelizes over channels within an SM.  On TPU we block
channels (d_inner) across the parallel grid dims and run the sequence as the
*sequential* innermost grid dimension in chunks: the (d_blk, N) state lives
in VMEM scratch across chunk steps, dA/dBx are computed on the fly per chunk
(never materialized in HBM -- the same blocking the XLA fallback uses), and
the chunk loop is a ``fori_loop`` over time steps inside VMEM.

Layout notes: channels-last tiles (chunk, d_blk) keep the lane dimension on
d_inner (128-aligned); the state update is VPU elementwise work, the y
projection a (d_blk, N) x (N,) contraction per step.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    xi_ref,      # (1, chunk, d_blk)
    dt_ref,      # (1, chunk, d_blk)   pre-softplus dt (full-rank, post dt_proj)
    b_ref,       # (1, chunk, N)
    c_ref,       # (1, chunk, N)
    a_ref,       # (d_blk, N)          negative A
    h0_ref,      # (1, d_blk, N)       initial state for this (b, d_blk)
    y_ref,       # (1, chunk, d_blk)
    hT_ref,      # (1, d_blk, N)
    h_scratch,   # VMEM (d_blk, N) f32
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scratch[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                   # (d_blk, N)
    xi = xi_ref[0].astype(jnp.float32)                   # (chunk, d_blk)
    dt = jax.nn.softplus(dt_ref[0].astype(jnp.float32))  # (chunk, d_blk)
    bm = b_ref[0].astype(jnp.float32)                    # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)                    # (chunk, N)

    def step(t, h):
        dt_t = dt[t][:, None]                            # (d_blk, 1)
        dA = jnp.exp(dt_t * a)                           # (d_blk, N)
        dBx = (dt_t * xi[t][:, None]) * bm[t][None, :]   # (d_blk, N)
        h = dA * h + dBx
        y_t = jnp.sum(h * cm[t][None, :], axis=-1)       # (d_blk,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(ic == n_chunks - 1)
    def _done():
        hT_ref[0] = h.astype(hT_ref.dtype)


def selective_scan(
    xi: jax.Array,       # (B, S, Din)  post-conv/silu
    dt_raw: jax.Array,   # (B, S, Din)  pre-softplus dt (dt_proj output + bias)
    Bm: jax.Array,       # (B, S, N)
    Cm: jax.Array,       # (B, S, N)
    A: jax.Array,        # (Din, N) negative
    h0: Optional[jax.Array] = None,   # (B, Din, N)
    *,
    chunk: int = 256,
    d_block: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,Din), hT: (B,Din,N))."""
    B, S, Din = xi.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    n_chunks = S // chunk
    d_block = min(d_block, Din)
    if Din % d_block != 0:
        d_block = Din
    n_dblk = Din // d_block
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (B, n_dblk, n_chunks)  # chunk dim innermost => sequential on TPU

    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((d_block, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, d_block, N), lambda b, d, c: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, d_block, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Din), xi.dtype),
            jax.ShapeDtypeStruct((B, Din, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, N), jnp.float32)],
        interpret=interpret,
    )(xi, dt_raw, Bm, Cm, A, h0)
    return y, hT
