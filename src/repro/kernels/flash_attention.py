"""Flash attention (tiled online-softmax) as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the classic GPU flash attention is re-blocked
for the TPU memory hierarchy -- q/k/v tiles staged HBM->VMEM via BlockSpec,
MXU-aligned tile shapes (multiples of 128 on the lane dim), and the kv-block
loop mapped onto the *sequential* innermost TPU grid dimension so the running
(max, denom, acc) state lives in VMEM scratch across grid steps (no atomics,
no shared-memory banking -- the TPU grid is the reduction loop).

Supports GQA (kv head broadcast), causal masking and sliding windows.
Validated against ``ref.flash_attention_ref`` in interpret mode on CPU.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,          # (1, 1, bq, d), (1, 1, bkv, d) x2
    o_ref,                        # (1, 1, bq, d)
    m_scratch, l_scratch, acc_scratch,
    *,
    causal: bool,
    window: Optional[int],
    scale: float,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_pos = ikv * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)

    # whole-block skip: run the body only if any (q, k) pair is unmasked
    live = jnp.bool_(True)
    if causal:
        # newest q in block vs oldest k in block
        live = jnp.logical_and(live, (iq + 1) * block_q - 1 >= ikv * block_kv)
    if window is not None:
        # oldest q in block vs newest k in block
        live = jnp.logical_and(
            live, iq * block_q - ((ikv + 1) * block_kv - 1) < window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)

        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]                       # (bq, 1)
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ikv == n_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scratch[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                 # (B, H, S, D)
    k: jax.Array,                 # (B, K, T, D)
    v: jax.Array,                 # (B, K, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    _, K, T, _ = k.shape
    assert H % K == 0, (H, K)
    group = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    assert S % block_q == 0 and T % block_kv == 0, (S, block_q, T, block_kv)
    n_q = S // block_q
    n_kv = T // block_kv

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=n_kv,
    )

    grid = (B, H, n_q, n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ikv: (b, h // group, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ikv: (b, h // group, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ikv: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
