"""Fused RMSNorm (+ optional residual add) as a Pallas TPU kernel.

One HBM->VMEM pass: the unfused XLA graph reads x three times (square-mean,
normalize, scale); the fused kernel reads each row tile once and writes once,
cutting HBM traffic ~3x on this memory-bound op.  Rows are tiled on the grid;
the model dim stays whole in VMEM (d_model <= ~8k fits comfortably).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (rows, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, res_ref, scale_ref, o_ref, r_ref,
                             *, eps: float):
    h = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    r_ref[...] = h.astype(r_ref.dtype)
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,                  # (..., d)
    scale: jax.Array,              # (d,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        block_rows = 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)


def rmsnorm_residual(
    x: jax.Array,
    residual: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
):
    """Fused (residual + x) -> rmsnorm.  Returns (normed, new_residual)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    r2 = residual.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        block_rows = 1
    normed, new_res = pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, d), x.dtype),
        ],
        interpret=interpret,
    )(x2, r2, scale)
    return normed.reshape(orig_shape), new_res.reshape(orig_shape)
